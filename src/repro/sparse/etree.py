"""Elimination tree + postorder (Liu's algorithm with path compression)."""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph


def etree(g: Graph, perm: np.ndarray) -> np.ndarray:
    """Elimination tree of the permuted matrix.

    ``perm`` is the *ordering*: perm[k] = original vertex eliminated k-th
    (an inverse-permutation fragment assembly in paper terms gives exactly
    this).  Returns parent[] over elimination positions (−1 = root).
    """
    n = g.n
    iperm = np.empty(n, dtype=np.int64)
    iperm[perm] = np.arange(n)
    parent = -np.ones(n, dtype=np.int64)
    ancestor = -np.ones(n, dtype=np.int64)
    xadj, adjncy = g.xadj, g.adjncy
    for i in range(n):
        v = perm[i]
        for u in adjncy[xadj[v]:xadj[v + 1]]:
            k = iperm[u]
            if k >= i:
                continue
            # walk up from k to the root, path-compressing to i
            j = k
            while ancestor[j] != -1 and ancestor[j] != i:
                nxt = ancestor[j]
                ancestor[j] = i
                j = nxt
            if ancestor[j] == -1:
                ancestor[j] = i
                parent[j] = i
    return parent


def postorder(parent: np.ndarray) -> np.ndarray:
    """Postorder of the elimination forest (iterative DFS)."""
    n = len(parent)
    # build child lists (reversed so DFS pops in ascending order)
    head = -np.ones(n, dtype=np.int64)
    nxt = -np.ones(n, dtype=np.int64)
    for v in range(n - 1, -1, -1):
        p = parent[v]
        if p >= 0:
            nxt[v] = head[p]
            head[p] = v
    post = np.empty(n, dtype=np.int64)
    k = 0
    stack = []
    for root in range(n):
        if parent[root] != -1:
            continue
        stack.append(root)
        while stack:
            v = stack[-1]
            c = head[v]
            if c != -1:
                head[v] = nxt[c]   # consume child
                stack.append(c)
            else:
                post[k] = v
                k += 1
                stack.pop()
    assert k == n
    return post
