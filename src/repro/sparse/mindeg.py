"""Minimum-degree ordering on a quotient graph.

Used exactly as in the paper (§3.1): only in the *sequential* context, to
order the small leaf subgraphs of nested dissection ("eventually ending in a
coupling with minimum degree methods [10]").  Exact external degrees on a
quotient graph (elements + variables); no supervariables — leaf graphs are
small, clarity wins.
"""
from __future__ import annotations

import heapq
from typing import Optional

import numpy as np

from repro.core.graph import Graph


def min_degree(g: Graph, tie_seed: int = 0) -> np.ndarray:
    """Return perm (perm[k] = vertex eliminated k-th)."""
    n = g.n
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    adj = [set(map(int, g.neighbors(v))) for v in range(n)]
    elems: list[set] = [set() for _ in range(n)]   # adjacent elements
    elem_vars: dict[int, set] = {}                 # element -> boundary vars
    alive = np.ones(n, dtype=bool)
    rng = np.random.default_rng(tie_seed)
    tiebreak = rng.permutation(n)

    def ext_degree(v: int) -> int:
        s = set(adj[v])
        for e in elems[v]:
            s |= elem_vars[e]
        s.discard(v)
        return len(s)

    heap = [(len(adj[v]), int(tiebreak[v]), v) for v in range(n)]
    heapq.heapify(heap)
    deg_cache = {v: len(adj[v]) for v in range(n)}
    perm = np.empty(n, dtype=np.int64)
    k = 0
    while k < n:
        d, _, v = heapq.heappop(heap)
        if not alive[v] or d != deg_cache[v]:
            continue                               # stale entry
        # eliminate v -> new element
        lv = set(adj[v])
        for e in elems[v]:
            lv |= elem_vars[e]
            del elem_vars[e]                       # absorbed
        lv.discard(v)
        lv = {u for u in lv if alive[u]}
        alive[v] = False
        perm[k] = v
        k += 1
        elem_vars[v] = lv
        absorbed = set(elems[v])
        for u in lv:
            adj[u].discard(v)
            adj[u] -= lv                           # now covered by element v
            elems[u] -= absorbed
            elems[u].add(v)
            nd = ext_degree(u)
            deg_cache[u] = nd
            heapq.heappush(heap, (nd, int(tiebreak[u]), u))
    return perm
