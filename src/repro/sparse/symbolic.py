"""Symbolic Cholesky: column counts, NNZ and OPC of the factored matrix.

Implements the Gilbert–Ng–Peyton skeleton column-count algorithm (as in
CSparse ``cs_counts``), O(m·α(m,n)).  These are the paper's two quality
metrics (§4): NNZ = Σ_c n_c and OPC = Σ_c n_c² with n_c the nonzeros of
column c of L, diagonal included.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.graph import Graph
from repro.sparse.etree import etree, postorder


def col_counts(g: Graph, perm: np.ndarray) -> np.ndarray:
    n = g.n
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    iperm = np.empty(n, dtype=np.int64)
    iperm[perm] = np.arange(n)
    parent = etree(g, perm)
    post = postorder(parent)

    # first descendant + leaf deltas
    first = -np.ones(n, dtype=np.int64)
    delta = np.zeros(n, dtype=np.int64)
    for k in range(n):
        j = post[k]
        delta[j] = 1 if first[j] == -1 else 0
        while j != -1 and first[j] == -1:
            first[j] = k
            j = parent[j]

    maxfirst = -np.ones(n, dtype=np.int64)
    prevleaf = -np.ones(n, dtype=np.int64)
    ancestor = np.arange(n, dtype=np.int64)
    xadj, adjncy = g.xadj, g.adjncy
    for k in range(n):
        j = post[k]
        if parent[j] != -1:
            delta[parent[j]] -= 1          # j is not a root
        v = perm[j]
        for u in adjncy[xadj[v]:xadj[v + 1]]:
            i = iperm[u]
            if i <= j or first[j] <= maxfirst[i]:
                continue                   # j not a leaf of row subtree T^i
            maxfirst[i] = first[j]
            jprev = prevleaf[i]
            prevleaf[i] = j
            if jprev == -1:
                delta[j] += 1              # first leaf: A(i,j) in skeleton
            else:
                # q = LCA(jprev, j) with path compression
                q = jprev
                while q != ancestor[q]:
                    q = ancestor[q]
                s = jprev
                while s != q:
                    sp = ancestor[s]
                    ancestor[s] = q
                    s = sp
                delta[j] += 1
                delta[q] -= 1
        if parent[j] != -1:
            ancestor[j] = parent[j]

    counts = delta.copy()
    for k in range(n):                     # accumulate in postorder
        j = post[k]
        if parent[j] != -1:
            counts[parent[j]] += counts[j]
    return counts


def nnz_opc(g: Graph, perm: np.ndarray) -> Tuple[int, float]:
    """(NNZ(L), OPC) for ordering ``perm`` (perm[k] = vertex eliminated k-th)."""
    c = col_counts(g, perm).astype(np.float64)
    return int(c.sum()), float((c * c).sum())


def dense_fill_oracle(g: Graph, perm: np.ndarray) -> Tuple[int, float]:
    """O(n³) boolean elimination — oracle for tests (n small)."""
    n = g.n
    a = np.zeros((n, n), dtype=bool)
    iperm = np.empty(n, dtype=np.int64)
    iperm[perm] = np.arange(n)
    src = np.repeat(np.arange(n), np.diff(g.xadj))
    a[iperm[src], iperm[g.adjncy]] = True
    np.fill_diagonal(a, True)
    nnz, opc = 0, 0.0
    for k in range(n):
        below = np.nonzero(a[k + 1:, k])[0] + k + 1
        nc = len(below) + 1
        nnz += nc
        opc += float(nc) ** 2
        if len(below):
            a[np.ix_(below, below)] = True
    return nnz, opc
