"""SLO-aware pump policy: which suspended orderings advance this wave.

The service's ``pump`` loop (DESIGN.md §7) separates *mechanism* from
*policy*: the ``WaveRouter`` can park and resume any ordering between
waves bit-identically (lane purity), and this module decides **which**
orderings advance each pump.  The decision is a ``PumpPlan``:

  * ``admit`` — queued requests to move from the admission queues onto
    the router's frontier this pump, in priority order;
  * ``active`` — tags of in-flight orderings allowed to execute waves
    (the complement is **parked**: their generators stay suspended);
  * ``max_waves`` — this pump's preemption budget, i.e. how many waves
    run before control returns to the policy so newly submitted small
    requests get a scheduling opportunity;
  * ``shed`` — queued requests whose explicit deadlines are infeasible
    even starting now (judged from measured per-class exec estimates);
    the service resolves them terminally as ``status=shed`` instead of
    letting a doomed queue collapse everyone's deadlines (recovery
    ladder rung 5, DESIGN.md §8).

The default ``SchedPolicy`` is strict size-class priority with EDF
within a class, plus two anti-starvation escapes:

  * **deadline rescue** — a parked ordering whose effective deadline is
    within ``rescue_margin_s`` is activated regardless of class (it
    would otherwise miss *because* of the policy);
  * **park aging** — nothing stays parked longer than ``max_park_s``.

Classes in ``preemptible`` (default: the big ``m``/``l`` classes) are
parked whenever a strictly smaller class has live work; ``xs``/``s``
are never parked — that is the whole point: one cage-like graph must
not stall every co-drained small request (the p95 exec pathology of
BENCH_service.json).  Requests without an explicit deadline get their
class's default SLO (``default_slo_s``) as the effective deadline, so
EDF is total.

The policy never returns an empty ``active`` set while work is live —
when only preemptible orderings remain they run (the smallest present
class is always active), so a pump loop is deadlock-free by
construction.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Set, Tuple

#: size classes in strictly ascending priority-relevant order (the
#: admission-queue order and the preemption order; see api.size_class)
CLASS_ORDER: Tuple[str, ...] = ("xs", "s", "m", "l")

#: per-class default SLO in seconds for requests submitted without an
#: explicit deadline: effective_deadline = t_enqueue + default_slo_s
DEFAULT_SLO_S: Dict[str, float] = {
    "xs": 0.25, "s": 1.0, "m": 10.0, "l": 120.0}


def class_rank(cls: str) -> int:
    """Priority rank of a size class (lower = smaller = more urgent)."""
    try:
        return CLASS_ORDER.index(cls)
    except ValueError:
        return len(CLASS_ORDER)         # unknown classes sort last


@dataclasses.dataclass(frozen=True)
class ReqMeta:
    """Scheduling-relevant view of one queued or in-flight request."""
    tag: str                        # router tag (the request fingerprint)
    size_class: str
    t_enqueue: float                # perf_counter at submit
    deadline: Optional[float] = None    # absolute perf_counter, None=SLO
    slo: str = ""                   # freeform tier label ("interactive")

    def effective_deadline(self) -> float:
        if self.deadline is not None:
            return self.deadline
        return self.t_enqueue + DEFAULT_SLO_S.get(self.size_class, 60.0)


@dataclasses.dataclass
class PumpPlan:
    """One pump's scheduling decision (see module docstring)."""
    admit: List[str]                # queued tags to admit, in order
    active: Set[str]                # in-flight + admitted tags that run
    parked: Set[str]                # complement: suspended this pump
    max_waves: int                  # the pump's preemption budget
    #: queued tags shed by feasibility admission control (rung 5,
    #: DESIGN.md §8): the service resolves their riders ``status=shed``
    shed: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class PolicyConfig:
    """Knob surface of the default policy (env-var defaults, the
    ``RouterConfig`` idiom)."""
    #: waves per pump before re-planning (the preemption budget)
    wave_budget: int = dataclasses.field(
        default_factory=lambda: int(os.environ.get("REPRO_PUMP_WAVES",
                                                   "2")))
    #: classes that may be parked while smaller classes have live work
    preemptible: Tuple[str, ...] = ("m", "l")
    #: parked orderings this close to their deadline run anyway
    rescue_margin_s: float = 0.25
    #: hard bound on continuous parking (starvation escape)
    max_park_s: float = 30.0
    #: deadline-feasibility shedding (REPRO_SHED=0 disables): a queued
    #: request with an *explicit* deadline is shed when even an
    #: immediate start could not finish in time, judged from the
    #: service's measured per-class exec percentiles
    shed_infeasible: bool = dataclasses.field(
        default_factory=lambda: os.environ.get("REPRO_SHED", "1") != "0")
    #: slack multiplier on the exec estimate: shed iff
    #: deadline - now < shed_factor * est_exec_s
    shed_factor: float = dataclasses.field(
        default_factory=lambda: float(os.environ.get("REPRO_SHED_FACTOR",
                                                     "1.0")))


class SchedPolicy:
    """Strict size-class priority + EDF + anti-starvation escapes."""

    def __init__(self, cfg: Optional[PolicyConfig] = None):
        self.cfg = cfg or PolicyConfig()
        self._parked_since: Dict[str, float] = {}

    # -------------------------------------------------------------- #
    def plan(self, queued: Sequence[ReqMeta], inflight: Sequence[ReqMeta],
             now: float,
             exec_est: Optional[Dict[str, float]] = None) -> PumpPlan:
        """Decide admissions, sheds, and the active set for one pump.

        ``queued`` are admission-queue heads (not yet on the router);
        ``inflight`` are suspended-or-running orderings.  Everything
        queued and feasible is admitted (admission itself is cheap —
        parking is what throttles execution), ordered (class rank,
        effective deadline, enqueue time); the active set is computed
        over the union.

        ``exec_est`` maps size class → an exec-seconds estimate (the
        service passes its measured per-class p50).  A queued request
        with an **explicit** deadline that even an immediate start
        could not meet (``deadline - now < shed_factor × est``) is shed
        instead of admitted — its riders get a clean terminal
        ``status=shed`` rather than dragging the queue into collapse.
        SLO-defaulted deadlines never shed (the SLO is a target, not a
        contract), and classes with no measurement yet are assumed
        feasible.
        """
        cfg = self.cfg
        shed: List[str] = []
        if cfg.shed_infeasible and exec_est:
            feasible = []
            for m in queued:
                est = exec_est.get(m.size_class)
                if (m.deadline is not None and est is not None
                        and m.deadline - now < cfg.shed_factor * est):
                    shed.append(m.tag)
                else:
                    feasible.append(m)
            queued = feasible
        admit = sorted(
            queued, key=lambda m: (class_rank(m.size_class),
                                   m.effective_deadline(), m.t_enqueue))
        live = list(inflight) + admit
        active: Set[str] = set()
        parked: Set[str] = set()
        if live:
            min_rank = min(class_rank(m.size_class) for m in live)
            for m in live:
                if self._runs(m, min_rank, now):
                    active.add(m.tag)
                else:
                    parked.add(m.tag)
        # park-age bookkeeping: a tag's clock starts when first parked
        # and resets whenever it runs (or finishes and drops out)
        for tag in list(self._parked_since):
            if tag not in parked:
                del self._parked_since[tag]
        for tag in parked:
            self._parked_since.setdefault(tag, now)
        assert not live or active, "policy parked every live ordering"
        return PumpPlan(admit=[m.tag for m in admit], active=active,
                        parked=parked, max_waves=max(cfg.wave_budget, 1),
                        shed=shed)

    # -------------------------------------------------------------- #
    def _runs(self, m: ReqMeta, min_rank: int, now: float) -> bool:
        cfg = self.cfg
        if m.size_class not in cfg.preemptible:
            return True
        if class_rank(m.size_class) <= min_rank:
            return True                 # nothing smaller is live
        if m.effective_deadline() - now <= cfg.rescue_margin_s:
            return True                 # deadline rescue
        since = self._parked_since.get(m.tag)
        if since is not None and now - since >= cfg.max_park_s:
            return True                 # park aging
        return False
