"""Breadth-first nested dissection over many graphs at once (DESIGN.md §3).

``core.nd`` recurses depth-first through one ND tree, dispatching each
subproblem's kernels on its own.  The scheduler instead keeps a *frontier*
of ND nodes across ALL submitted graphs and walks the trees level by
level: every node at the current depth that needs a separator contributes
its pipeline generator, and ``drive_tasks`` executes each wave of
outstanding matching / BFS / FM work as bucketed vmap batches (the
coarsening loop's matchings batch exactly like the band stages — one
``match_batch`` dispatch per ELL bucket per wave, with the host-side
coarse builds grouped in between).  The left/right subgraphs of every
dissection are independent (paper §3.1) — exactly the parallelism the
paper spreads over processes, here spread over the lanes of a batched
kernel dispatch.  ``distributed_nested_dissection`` funnels its deferred
sequential subtrees through ``order_batch`` too, so the endgames of every
ND branch share these waves.

Work items run the same computation whether batched or not, and the tree
bookkeeping mirrors ``core.nd._nd_rec`` exactly (same seeds, same fold
arithmetic, same fallbacks) — so ``order_batch`` returns permutations
identical to looped ``nested_dissection`` calls.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.core.graph import Graph
from repro.core.nd import (NDConfig, child_nprocs, child_seeds,
                           component_seed, effective_nproc, leaf_perm,
                           resolve_separator, separator_perm,
                           separator_task, split_by_separator)
from repro.core.ordering import Ordering
from repro.service.batch import drive_tasks


@dataclasses.dataclass
class _Node:
    """One pending ND tree node of one request."""
    req: int                        # request index
    g: Graph
    gids: np.ndarray
    seed: int
    nproc: int
    node: object                    # OrderNode receiving this subtree
    start: int


def _as_list(x, n: int) -> list:
    if isinstance(x, (list, tuple)):
        assert len(x) == n
        return list(x)
    return [x] * n


def order_batch(graphs: Sequence[Graph],
                seeds: Union[int, Sequence[int]] = 0,
                nprocs: Union[int, Sequence[int]] = 1,
                cfgs: Union[NDConfig, Sequence[NDConfig], None] = None
                ) -> List[np.ndarray]:
    """Order many graphs with bucketed breadth-first nested dissection.

    Returns one permutation per graph, identical to
    ``[nested_dissection(g, seed, nproc, cfg) for ...]``.
    """
    from repro.util import enable_compile_cache
    enable_compile_cache()
    n_req = len(graphs)
    seeds = _as_list(seeds, n_req)
    nprocs = _as_list(nprocs, n_req)
    cfgs = _as_list(cfgs or NDConfig(), n_req)
    orderings = [Ordering(g.n) for g in graphs]

    from repro import obs
    frontier: List[_Node] = [
        _Node(i, g, np.arange(g.n, dtype=np.int64), seeds[i], nprocs[i],
              orderings[i].root, 0)
        for i, g in enumerate(graphs)]

    depth = 0
    while frontier:
        splitters: List[_Node] = []
        # --- host-plane wave: leaves and component splits (cheap, serial)
        work_list = list(frontier)
        while work_list:
            t = work_list.pop()
            cfg = cfgs[t.req]
            ordering = orderings[t.req]
            if t.g.n <= cfg.leaf_size:
                ordering.add_leaf(t.node, t.start,
                                  t.gids[leaf_perm(t.g, t.seed)])
                continue
            comp = t.g.components()
            ncomp = int(comp.max()) + 1
            if ncomp > 1:               # independent parts: no separator
                off = t.start
                for c in range(ncomp):
                    sub, old = t.g.induced_subgraph(comp == c)
                    child = ordering.add_internal(t.node, off, sub.n)
                    work_list.append(_Node(t.req, sub, t.gids[old],
                                           component_seed(t.seed, c),
                                           t.nproc, child, off))
                    off += sub.n
                continue
            splitters.append(t)

        # --- device-plane wave: every separator at this depth, bucketed
        gens = [separator_task(t.g, t.seed,
                               effective_nproc(t.g.n, t.nproc, cfgs[t.req]),
                               cfgs[t.req])
                for t in splitters]
        with obs.span("sched:level", depth=depth, splitters=len(gens)):
            parts = drive_tasks(gens)
        depth += 1

        # --- split into the next depth's frontier
        nxt: List[_Node] = []
        for t, part in zip(splitters, parts):
            cfg = cfgs[t.req]
            ordering = orderings[t.req]
            part = resolve_separator(t.g, t.seed, part, cfg)
            if part is None:            # could not split
                ordering.add_leaf(t.node, t.start,
                                  t.gids[leaf_perm(t.g, t.seed)])
                continue
            (g0, old0), (g1, old1), (gs, olds) = \
                split_by_separator(t.g, part)
            p0, p1 = child_nprocs(t.nproc)
            s0, s1 = child_seeds(t.seed)
            c0 = ordering.add_internal(t.node, t.start, g0.n)
            nxt.append(_Node(t.req, g0, t.gids[old0], s0, p0,
                             c0, t.start))
            c1 = ordering.add_internal(t.node, t.start + g0.n, g1.n)
            nxt.append(_Node(t.req, g1, t.gids[old1], s1, p1,
                             c1, t.start + g0.n))
            sperm = separator_perm(gs, t.seed)
            ordering.add_leaf(t.node, t.start + g0.n + g1.n,
                              t.gids[olds[sperm]], "sep")
        frontier = nxt

    perms = []
    for g, ordering in zip(graphs, orderings):
        perm = ordering.assemble()
        assert np.array_equal(np.sort(perm), np.arange(g.n)), \
            "not a permutation"
        perms.append(perm)
    return perms
