"""Router-fed nested dissection over many graphs at once (DESIGN.md §3).

``core.nd`` recurses depth-first through one ND tree, dispatching each
subproblem's kernels on its own.  The scheduler instead expresses every
request's whole ND recursion as ONE work-yielding task tree
(``_nd_node_task`` — leaves, component splits and the separator-ordering
host steps inline, subtrees spawned as sibling tasks) and submits all
requests to a shared ``service.router.WaveRouter``.  Every router wave
gathers the outstanding matching / BFS / FM work of every live subtree
of every request and executes it bucketed — one vmap dispatch per ELL
bucket per wave, with lanes from different *requests* stacking into the
same launch.  The left/right subgraphs of every dissection are
independent (paper §3.1) — exactly the parallelism the paper spreads
over processes, here spread over the lanes of a batched kernel dispatch.
``distributed_order_batch`` funnels the deferred sequential subtrees of
ALL its requests through one ``order_batch`` call too, so the endgames
of every ND branch of every ordering share these waves.

Work items run the same computation whether batched or not, the helpers
(``leaf_perm`` / ``resolve_separator`` / ``split_by_separator`` /
``separator_perm``) are pure per-subgraph, and ``Ordering.assemble``
sorts fragments by start — so ``order_batch`` returns permutations
identical to looped ``nested_dissection`` calls regardless of wave
composition.
"""
from __future__ import annotations

from typing import List, Sequence, Union

import numpy as np

from repro import obs
from repro.core.dnd import _Spawn
from repro.core.graph import Graph
from repro.core.nd import (NDConfig, child_nprocs, child_seeds,
                           component_seed, effective_nproc, leaf_perm,
                           resolve_separator, separator_perm,
                           separator_task, split_by_separator)
from repro.core.ordering import Ordering


def _as_list(x, n: int) -> list:
    if isinstance(x, (list, tuple)):
        assert len(x) == n
        return list(x)
    return [x] * n


def _nd_node_task(g: Graph, gids: np.ndarray, seed: int, nproc: int,
                  cfg: NDConfig, ordering: Ordering, node, start: int,
                  hints=None, rec=None, path: str = ""):
    """One ND tree node as a router task: order ``g`` into ``ordering``.

    Leaves and connected-component splits are handled inline on the
    host plane; separators run through ``nd.separator_task`` (yielding
    its device works to the router); the two separated halves spawn as
    sibling subtasks, so all of a request's — and all concurrent
    requests' — same-depth subproblems join the same waves.

    ``hints`` / ``rec`` thread the warm-start surface (DESIGN.md §7)
    through the recursion: ``path`` names this node in the ND tree
    (root ``""``, dissection children ``.0``/``.1``, components
    ``.c<k>``); a hint at this path short-circuits the separator
    pipeline through ``separator_task(warm_part=...)`` (re-validated on
    ``g``, so stale hints fall back cold per node), and ``rec`` records
    every *resolved* split so a completed tree can seed later
    structurally identical requests.  Replaying the cached splits
    reproduces the cached recursion shape on any same-topology graph —
    induced subgraphs of equal structure under equal parts are equal
    structures — so paths align between record and replay by
    construction.
    """
    if g.n <= cfg.leaf_size:
        ordering.add_leaf(node, start, gids[leaf_perm(g, seed)])
        return
    comp = g.components()
    ncomp = int(comp.max()) + 1
    if ncomp > 1:                       # independent parts: no separator
        subs = []
        off = start
        for c in range(ncomp):
            sub, old = g.induced_subgraph(comp == c)
            child = ordering.add_internal(node, off, sub.n)
            subs.append(_nd_node_task(sub, gids[old],
                                      component_seed(seed, c), nproc,
                                      cfg, ordering, child, off,
                                      hints, rec, f"{path}.c{c}"))
            off += sub.n
        yield _Spawn(subs)
        return
    part = yield from separator_task(
        g, seed, effective_nproc(g.n, nproc, cfg), cfg,
        warm_part=None if hints is None else hints.get(path))
    part = resolve_separator(g, seed, part, cfg)
    if part is None:                    # could not split
        ordering.add_leaf(node, start, gids[leaf_perm(g, seed)])
        return
    if rec is not None:
        rec[path] = part
    (g0, old0), (g1, old1), (gs, olds) = split_by_separator(g, part)
    p0, p1 = child_nprocs(nproc)
    s0, s1 = child_seeds(seed)
    c0 = ordering.add_internal(node, start, g0.n)
    c1 = ordering.add_internal(node, start + g0.n, g1.n)
    sperm = separator_perm(gs, seed)
    ordering.add_leaf(node, start + g0.n + g1.n, gids[olds[sperm]], "sep")
    yield _Spawn([
        _nd_node_task(g0, gids[old0], s0, p0, cfg, ordering, c0, start,
                      hints, rec, path + ".0"),
        _nd_node_task(g1, gids[old1], s1, p1, cfg, ordering, c1,
                      start + g0.n, hints, rec, path + ".1"),
    ])


def request_task(g: Graph, seed: int, nproc: int, cfg: NDConfig,
                 ordering: Ordering, hints=None, rec=None,
                 path: str = ""):
    """Root ND task of one host-graph request (the service pump's unit).

    The service admits one of these per request onto its persistent
    ``WaveRouter`` and assembles ``ordering`` once the root completes —
    same task tree ``order_batch`` builds, exposed so admission can be
    incremental (and warm-started / recorded via ``hints`` / ``rec``).
    """
    return _nd_node_task(g, np.arange(g.n, dtype=np.int64), seed, nproc,
                         cfg, ordering, ordering.root, 0,
                         hints=hints, rec=rec, path=path)


def order_batch(graphs: Sequence[Graph],
                seeds: Union[int, Sequence[int]] = 0,
                nprocs: Union[int, Sequence[int]] = 1,
                cfgs: Union[NDConfig, Sequence[NDConfig], None] = None,
                tags: Union[Sequence, None] = None
                ) -> List[np.ndarray]:
    """Order many graphs through one shared wave router.

    Returns one permutation per graph, identical to
    ``[nested_dissection(g, seed, nproc, cfg) for ...]``.  ``tags``
    (optional, one per graph) attribute each request's lanes in the
    router's wave summaries — ``distributed_order_batch`` uses it to
    keep its merged endgame attributed to the originating distributed
    requests.
    """
    from repro.service.router import WaveRouter
    from repro.util import enable_compile_cache
    enable_compile_cache()
    n_req = len(graphs)
    seeds = _as_list(seeds, n_req)
    nprocs = _as_list(nprocs, n_req)
    cfgs = _as_list(cfgs or NDConfig(), n_req)
    if tags is not None:
        assert len(tags) == n_req
    orderings = [Ordering(g.n) for g in graphs]

    router = WaveRouter()
    with obs.span("sched:batch", requests=n_req):
        for i, g in enumerate(graphs):
            root = request_task(g, seeds[i], nprocs[i], cfgs[i],
                                orderings[i])
            router.submit(root, tag=i if tags is None else tags[i])
        router.run()

    perms = []
    for g, ordering in zip(graphs, orderings):
        perm = ordering.assemble()
        assert np.array_equal(np.sort(perm), np.arange(g.n)), \
            "not a permutation"
        perms.append(perm)
    return perms
