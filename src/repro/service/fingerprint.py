"""Content fingerprints for ordering requests.

A request is fully determined by (CSR graph content, seed, nproc, NDConfig),
so a collision-resistant hash of exactly those bytes is a sound cache key:
two requests with equal fingerprints produce identical orderings (the whole
pipeline is deterministic given the seed).
"""
from __future__ import annotations

import dataclasses
import hashlib

from repro.core.graph import Graph
from repro.core.nd import NDConfig


def graph_fingerprint(g: Graph) -> str:
    """Hash of the CSR content (structure + vertex/edge weights)."""
    h = hashlib.blake2b(digest_size=16)
    for arr in (g.xadj, g.adjncy, g.vwgt, g.adjwgt):
        # dtype + shape delimiters make the encoding injective: without
        # them, two different boundary splits of the same byte stream
        # could collide and the cache would serve a wrong ordering.
        h.update(f"{arr.dtype}:{arr.shape}|".encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def structural_fingerprint(g: Graph) -> str:
    """Hash of the topology only (CSR structure *modulo weights*).

    Two graphs share this fingerprint iff they have identical vertex
    numbering and adjacency but possibly different vertex/edge weights
    — the "isomorphic modulo weights" cache neighbors of the warm-start
    index (``cache.WarmStartIndex``): their separator splits are
    mutually valid, so one's finished ordering tree can seed the
    other's recursion.  NOT a sound key for exact results (weights
    change the ordering); exact serving always goes through
    ``request_fingerprint``.
    """
    h = hashlib.blake2b(digest_size=16)
    for arr in (g.xadj, g.adjncy):
        h.update(f"{arr.dtype}:{arr.shape}|".encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def dgraph_structural_fingerprint(dg) -> str:
    """Topology-modulo-weights key of a sharded ``DGraph``.

    Hashes the shard layout and adjacency (``vtxdist``, padded neighbor
    table, ghost ids, per-shard valid counts) but neither edge nor
    vertex weights — the distributed analogue of
    ``structural_fingerprint``, keying warm-start reuse of a previous
    ordering tree's centralized-endgame splits.
    """
    h = hashlib.blake2b(digest_size=16)
    for arr in (dg.vtxdist, dg.nbr_gst, dg.ghost_gid, dg.n_loc,
                dg.n_ghost):
        h.update(f"{arr.dtype}:{arr.shape}|".encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def request_fingerprint(g: Graph, seed: int, nproc: int,
                        cfg: NDConfig) -> str:
    """Cache key for a full ordering request."""
    h = hashlib.blake2b(digest_size=16)
    h.update(graph_fingerprint(g).encode())
    h.update(f"|seed={seed}|nproc={nproc}|".encode())
    h.update(repr(dataclasses.astuple(cfg)).encode())
    return h.hexdigest()


def dgraph_fingerprint(dg, seed: int, cfg) -> str:
    """Cache key for a distributed ordering request.

    Hashes the full sharded representation (shard layout included: the
    same global graph distributed differently takes different multilevel
    paths, so layout must be part of the key) plus seed and ``DNDConfig``.
    Equal fingerprints imply bit-identical orderings — the distributed
    pipeline is deterministic given (dg, seed, cfg).
    """
    h = hashlib.blake2b(digest_size=16)
    for arr in (dg.vtxdist, dg.nbr_gst, dg.ewgt_gst, dg.ghost_gid,
                dg.n_loc, dg.n_ghost, dg.vwgt):
        # same injective dtype/shape-delimited encoding as
        # ``graph_fingerprint``
        h.update(f"{arr.dtype}:{arr.shape}|".encode())
        h.update(arr.tobytes())
    h.update(f"|seed={seed}|".encode())
    h.update(repr(dataclasses.astuple(cfg)).encode())
    return h.hexdigest()
