"""Content fingerprints for ordering requests.

A request is fully determined by (CSR graph content, seed, nproc, NDConfig),
so a collision-resistant hash of exactly those bytes is a sound cache key:
two requests with equal fingerprints produce identical orderings (the whole
pipeline is deterministic given the seed).
"""
from __future__ import annotations

import dataclasses
import hashlib

from repro.core.graph import Graph
from repro.core.nd import NDConfig


def graph_fingerprint(g: Graph) -> str:
    """Hash of the CSR content (structure + vertex/edge weights)."""
    h = hashlib.blake2b(digest_size=16)
    for arr in (g.xadj, g.adjncy, g.vwgt, g.adjwgt):
        # dtype + shape delimiters make the encoding injective: without
        # them, two different boundary splits of the same byte stream
        # could collide and the cache would serve a wrong ordering.
        h.update(f"{arr.dtype}:{arr.shape}|".encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def request_fingerprint(g: Graph, seed: int, nproc: int,
                        cfg: NDConfig) -> str:
    """Cache key for a full ordering request."""
    h = hashlib.blake2b(digest_size=16)
    h.update(graph_fingerprint(g).encode())
    h.update(f"|seed={seed}|nproc={nproc}|".encode())
    h.update(repr(dataclasses.astuple(cfg)).encode())
    return h.hexdigest()


def dgraph_fingerprint(dg, seed: int, cfg) -> str:
    """Cache key for a distributed ordering request.

    Hashes the full sharded representation (shard layout included: the
    same global graph distributed differently takes different multilevel
    paths, so layout must be part of the key) plus seed and ``DNDConfig``.
    Equal fingerprints imply bit-identical orderings — the distributed
    pipeline is deterministic given (dg, seed, cfg).
    """
    h = hashlib.blake2b(digest_size=16)
    for arr in (dg.vtxdist, dg.nbr_gst, dg.ewgt_gst, dg.ghost_gid,
                dg.n_loc, dg.n_ghost, dg.vwgt):
        # same injective dtype/shape-delimited encoding as
        # ``graph_fingerprint``
        h.update(f"{arr.dtype}:{arr.shape}|".encode())
        h.update(arr.tobytes())
    h.update(f"|seed={seed}|".encode())
    h.update(repr(dataclasses.astuple(cfg)).encode())
    return h.hexdigest()
