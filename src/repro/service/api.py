"""Ordering service front end: submit / pump / drain / poll / stats.

Usage (see examples/serve_orderings.py):

    svc = OrderingService()
    rids = [svc.submit(g, seed=0, nproc=16, deadline_s=0.5)
            for g in graphs]
    svc.drain()                       # pump until every queue is empty
    perm = svc.poll(rids[0]).perm
    print(svc.stats())                # hit rate, per-class p50/p95, misses

``submit`` fingerprints the request (CSR content + seed + nproc + config)
and tags it with a **size class** (``size_class()``), an optional
**deadline** (``deadline_s``, relative seconds) and a freeform ``slo``
tier label; a cache hit resolves immediately and duplicate fingerprints
— queued *or already in flight* — are coalesced so each unique problem
is ordered once.  ``submit_distributed`` does the same for sharded
``DGraph`` requests (fingerprinted over the full shard layout + seed +
``DNDConfig``).

**The control plane is an incremental ``pump`` loop** (DESIGN.md §7),
not a monolithic drain: requests wait in per-size-class admission
queues; each ``pump`` asks ``sched_policy.SchedPolicy`` which queued
requests to admit and which in-flight orderings may advance, then runs
a *bounded* number of router waves (the preemption budget) before
re-planning.  In-flight orderings are suspendable task trees parked
between waves with their full lane state, so a small-class request
submitted mid-flight preempts a long cage-like ordering *between its
waves* instead of queuing behind it — and the parked ordering later
resumes bit-identically (lane purity; asserted by the preemption
tests).  ``drain()`` simply pumps until everything resolves.

**Cross-fingerprint warm starts** (opt-in, ``warm_starts=True``): a
second structural index maps topology-modulo-weights fingerprints to
completed ordering trees; a near-hit replays the cached tree's
separator splits (re-validated per node) instead of running full
multilevel, and the result is OPC-guarded against the cached tree's
recorded quality — degradation triggers an exact cold re-run.  Warm
starts trade the bit-exact "equal (graph, seed, nproc, cfg) imply
identical permutations" contract for latency, which is why they are
off by default and never affect the exact fingerprint cache.

Contracts: graphs are ``core.graph.Graph`` (symmetric CSR, host numpy);
results carry ``perm`` with perm[k] = vertex eliminated k-th, always a
permutation of [0, n).  With warm starts off the pipeline is
deterministic given (graph, seed, nproc, cfg) — equal fingerprints
imply identical permutations, which is what makes the exact cache
sound.  The service is single-process; pumps are serialized by an
internal lock while ``submit`` / ``poll`` / ``stats`` stay responsive
on other threads.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from typing import Callable, Dict, List, Optional

import numpy as np

from repro import obs
from repro.core.graph import Graph
from repro.core.nd import NDConfig
from repro.core.ordering import Ordering
from repro.service import faults
from repro.service.cache import FingerprintCache, WarmStartIndex
from repro.service.fingerprint import (dgraph_fingerprint,
                                       dgraph_structural_fingerprint,
                                       request_fingerprint,
                                       structural_fingerprint)
from repro.service.router import TaskFailure, WaveRouter
from repro.service.scheduler import request_task
from repro.service.sched_policy import CLASS_ORDER, ReqMeta, SchedPolicy

#: size-class boundaries (vertex count → class label); the classes key
#: the per-class admission queues, the scheduling policy's preemption
#: order, the per-class latency percentiles of ``stats()["by_class"]``
#: and BENCH_service.json's ``exec_ms_by_class``
_SIZE_CLASSES = ((256, "xs"), (1024, "s"), (8192, "m"))


def size_class(n: int) -> str:
    """Bucket a graph size into the service's latency size classes."""
    for bound, label in _SIZE_CLASSES:
        if n < bound:
            return label
    return "l"


def _is_permutation(perm, n: int) -> bool:
    """Rung 4's service-side gate: exactly the integers [0, n) once.

    O(n) bincount check on every computed result — cheap next to the
    ordering itself, and the last line of the never-cache-corrupt
    invariant (``cache.put`` re-checks as defense in depth).
    """
    p = np.asarray(perm)
    if p.ndim != 1 or p.shape[0] != n or not np.issubdtype(
            p.dtype, np.integer):
        return False
    if n == 0:
        return True
    if p.min() < 0 or p.max() >= n:
        return False
    return bool((np.bincount(p, minlength=n) == 1).all())


@dataclasses.dataclass
class OrderResult:
    request_id: int
    perm: Optional[np.ndarray]      # None unless ``status == "ok"``
    cached: bool                    # served from the fingerprint cache
    latency_s: float                # submit → resolve (wait + execution)
    queue_wait_s: float             # submit → admission (0 on cache hits)
    exec_s: float                   # THIS request's attributed wave share
    fingerprint: str
    size_class: str = ""            # see ``size_class()``
    deadline_missed: Optional[bool] = None  # None: no deadline given
    warm: bool = False              # resolved via a warm-started tree
    #: terminal status (DESIGN.md §8): every submitted request reaches
    #: exactly one of ``ok`` (valid permutation), ``shed`` (deadline
    #: infeasible — never started), ``failed`` (recovery ladder
    #: exhausted) — there is no fourth state and no silent hang
    status: str = "ok"
    retries: int = 0                # transient retries billed to this fp
    degraded: bool = False          # kernel path degraded below default


@dataclasses.dataclass
class _PendingReq:
    request_id: int
    t_submit: float
    graph: Graph
    seed: int
    nproc: int
    cfg: NDConfig
    deadline: Optional[float] = None    # absolute perf_counter time
    slo: str = ""


@dataclasses.dataclass
class _PendingDistReq:
    request_id: int
    t_submit: float
    dg: object                      # core.dgraph.DGraph
    seed: int
    cfg: object                     # core.dnd.DNDConfig
    deadline: Optional[float] = None
    slo: str = ""


@dataclasses.dataclass
class _Admission:
    """One unique fingerprint waiting in an admission queue."""
    fp: str
    kind: str                       # "host" | "dist"
    meta: ReqMeta
    reqs: List                      # coalesced _PendingReq / _PendingDistReq
    struct_fp: str                  # topology-modulo-weights key
    n: int
    fault_readmits: int = 0         # cold re-admissions after failures


@dataclasses.dataclass
class _Inflight:
    """One admitted fingerprint living on the router."""
    adm: _Admission
    t_admit: float
    assemble: Callable              # result -> perm (host ignores result)
    rec: Optional[dict]             # recorded splits (path -> part)
    warm_tree: object               # cache.WarmTree or None
    warm_used: bool
    exec_acc: float = 0.0           # exec carried across warm fallback


class OrderingService:
    """SLO-aware batched nested-dissection ordering service."""

    def __init__(self, cfg: Optional[NDConfig] = None,
                 cache_capacity: int = 1024,
                 result_capacity: int = 4096,
                 latency_window: int = 4096,
                 policy: Optional[SchedPolicy] = None,
                 warm_starts: bool = False,
                 warm_capacity: int = 256,
                 warm_opc_ratio_max: float = 1.03,
                 warm_record: Optional[bool] = None):
        self.default_cfg = cfg or NDConfig()
        self.cache = FingerprintCache(cache_capacity)
        self.policy = policy or SchedPolicy()
        # warm starts are OPT-IN: replaying a structural near-hit's
        # splits changes the permutation an exact (graph, seed, nproc,
        # cfg) tuple resolves to depending on index state, so services
        # that rely on the bit-exact determinism contract keep this off
        self.warm_starts = warm_starts
        self.warm = WarmStartIndex(warm_capacity)
        self.warm_opc_ratio_max = warm_opc_ratio_max
        # recording defaults to following warm_starts: a service that
        # never warm-starts should not pay the per-request OPC and
        # split-copy bookkeeping of building an index it will not read
        self._warm_record = warm_starts if warm_record is None \
            else warm_record
        self._next_rid = 0
        # resolved results are retained FIFO-bounded: a long-running
        # service must not grow per served request (perms live on in the
        # LRU cache; old request ids just stop polling successfully)
        self._result_capacity = result_capacity
        self._results: "OrderedDict[int, OrderResult]" = OrderedDict()
        #: per-size-class admission queues: class -> fp -> _Admission
        self._queues: Dict[str, "OrderedDict[str, _Admission]"] = {
            cls: OrderedDict() for cls in CLASS_ORDER}
        self._inflight: Dict[str, _Inflight] = {}
        self._router = WaveRouter()
        self._latencies: deque = deque(maxlen=latency_window)
        # queue-wait and execution components recorded separately: the
        # end-to-end latency of a pumped request is dominated by how
        # long it sat in the queue, which says nothing about how fast
        # its waves executed — reporting one conflated percentile made
        # the service look 10000× slower than its compute (the old
        # p95_latency_ms of BENCH_service.json)
        self._queue_waits: deque = deque(maxlen=latency_window)
        self._execs: deque = deque(maxlen=latency_window)
        self._execs_by_class: Dict[str, deque] = {}
        self._qwaits_by_class: Dict[str, deque] = {}
        #: per-class [met, missed] deadline counters (explicit deadlines)
        self._deadline_by_class: Dict[str, List[int]] = {}
        self._latency_window = latency_window
        self._n_submitted = 0
        self._n_computed = 0
        self._n_pumps = 0
        self._n_warm_hits = 0
        self._n_warm_fallbacks = 0
        self._drain_time_s = 0.0
        self._n_drained = 0
        #: terminal-status counters (every request ends in exactly one)
        self._n_shed = 0
        self._n_failed = 0
        self._n_retries = 0
        self._n_degraded = 0
        # chaos harness: REPRO_FAULT_PLAN installs a process-global
        # injector once (no-op when unset or already active)
        faults.maybe_install_from_env()
        # submit / poll / stats run on the caller's thread while pumps
        # may run on a worker: every mutation of the queues, result map
        # and latency deques happens under this lock.  RLock because the
        # submit cache-hit path resolves inline while already holding it.
        self._lock = threading.RLock()
        # pumps are serialized separately: the router and in-flight
        # generators are single-pumper state, but submits must never
        # block on an executing wave
        self._pump_lock = threading.Lock()

    # ------------------------------------------------------------------ #
    def submit(self, g: Graph, seed: int = 0, nproc: int = 1,
               cfg: Optional[NDConfig] = None,
               deadline_s: Optional[float] = None,
               slo: str = "") -> int:
        """Enqueue an ordering request; returns a request id.

        ``deadline_s`` (relative seconds from now) and ``slo`` (freeform
        tier label) feed the pump policy: requests are admitted in
        (size-class, deadline) priority order and can preempt in-flight
        larger-class orderings between waves.  Cache hits resolve
        immediately (poll right away); misses resolve across subsequent
        ``pump`` calls (``drain`` pumps to completion).
        """
        cfg = cfg or self.default_cfg
        t0 = time.perf_counter()
        fp = request_fingerprint(g, seed, nproc, cfg)   # pure: no lock
        deadline = None if deadline_s is None else t0 + deadline_s
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self._n_submitted += 1
            perm = self.cache.get(fp)
            if perm is not None:
                obs.REGISTRY.inc("repro_service_requests_total",
                                 result="hit")
                self._resolve(rid, perm, True, t0, fp, queue_wait=0.0,
                              n=g.n, deadline=deadline)
                return rid
            obs.REGISTRY.inc("repro_service_requests_total", result="miss")
            req = _PendingReq(rid, t0, g, seed, nproc, cfg, deadline, slo)
            self._enqueue(fp, "host", req, g.n, slo,
                          lambda: structural_fingerprint(g))
            return rid

    def submit_distributed(self, dg, seed: int = 0, cfg=None,
                           deadline_s: Optional[float] = None,
                           slo: str = "") -> int:
        """Enqueue a distributed (sharded ``DGraph``) ordering request.

        Same cache/coalescing/SLO semantics as ``submit``; the task
        tree (top sharded dissection plus its centralized endgame) is
        one suspendable unit on the shared router, so distributed
        orderings park and resume between waves exactly like host ones.
        """
        from repro.core.dnd import DNDConfig
        cfg = cfg or DNDConfig()
        t0 = time.perf_counter()
        fp = dgraph_fingerprint(dg, seed, cfg)          # pure: no lock
        deadline = None if deadline_s is None else t0 + deadline_s
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self._n_submitted += 1
            perm = self.cache.get(fp)
            if perm is not None:
                obs.REGISTRY.inc("repro_service_requests_total",
                                 result="hit")
                self._resolve(rid, perm, True, t0, fp, queue_wait=0.0,
                              n=dg.n_global, deadline=deadline)
                return rid
            obs.REGISTRY.inc("repro_service_requests_total", result="miss")
            req = _PendingDistReq(rid, t0, dg, seed, cfg, deadline, slo)
            self._enqueue(fp, "dist", req, dg.n_global, slo,
                          lambda: dgraph_structural_fingerprint(dg))
            return rid

    def _enqueue(self, fp: str, kind: str, req, n: int, slo: str,
                 struct_fp_fn) -> None:
        """Coalesce a missed request into its admission queue (or onto
        the already in-flight computation of the same fingerprint)."""
        live = self._inflight.get(fp)
        if live is not None:
            live.adm.reqs.append(req)
            return
        cls = size_class(n)
        adm = self._queues[cls].get(fp)
        if adm is not None:
            adm.reqs.append(req)
            # the earliest deadline among coalesced requests drives EDF
            if (req.deadline is not None
                    and (adm.meta.deadline is None
                         or req.deadline < adm.meta.deadline)):
                adm.meta = dataclasses.replace(adm.meta,
                                               deadline=req.deadline)
            return
        meta = ReqMeta(tag=fp, size_class=cls, t_enqueue=req.t_submit,
                       deadline=req.deadline, slo=slo)
        self._queues[cls][fp] = _Admission(
            fp, kind, meta, [req], struct_fp_fn(), n)

    def poll(self, rid: int) -> Optional[OrderResult]:
        """Result for a request id, or None while still queued."""
        with self._lock:
            return self._results.get(rid)

    def queue_depth(self) -> int:
        with self._lock:
            return (sum(len(a.reqs) for q in self._queues.values()
                        for a in q.values())
                    + sum(len(f.adm.reqs)
                          for f in self._inflight.values()))

    # ------------------------------------------------------------------ #
    def pump(self, max_waves: Optional[int] = None) -> Dict[int, OrderResult]:
        """One scheduling iteration of the serving control plane.

        Admits queued requests per the policy, advances the *selected*
        in-flight orderings by at most the pump's wave budget (parking
        the rest with their lane state intact), and resolves whatever
        completed.  Returns {request_id: OrderResult} for the requests
        resolved by this call.  Wave execution runs *outside* the
        service lock, so submits on other threads stay responsive
        mid-pump (they queue for the next pump).
        """
        resolved: Dict[int, OrderResult] = {}
        with self._pump_lock:
            t0 = time.perf_counter()
            with self._lock:
                queued = [adm.meta for cls in CLASS_ORDER
                          for adm in self._queues[cls].values()]
                inflight = [f.adm.meta for f in self._inflight.values()]
                # measured per-class exec medians feed the policy's
                # deadline-feasibility check (ladder rung 5)
                est = {cls: float(np.percentile(np.asarray(dq), 50))
                       for cls, dq in self._execs_by_class.items()
                       if len(dq)}
                plan = self.policy.plan(queued, inflight, t0,
                                        exec_est=est)
                adms = []
                for tag in plan.admit:
                    for cls in CLASS_ORDER:
                        adm = self._queues[cls].pop(tag, None)
                        if adm is not None:
                            adms.append(adm)
                            break
                shed_adms = []
                for tag in plan.shed:
                    for cls in CLASS_ORDER:
                        adm = self._queues[cls].pop(tag, None)
                        if adm is not None:
                            shed_adms.append(adm)
                            break
                for adm in shed_adms:
                    with obs.span("recover:shed", tag=adm.fp[:16],
                                  size_class=adm.meta.size_class):
                        pass
                    for req in adm.reqs:
                        resolved[req.request_id] = self._resolve(
                            req.request_id, None, False, req.t_submit,
                            adm.fp,
                            queue_wait=max(0.0, t0 - req.t_submit),
                            exec_s=0.0, n=adm.n, deadline=req.deadline,
                            status="shed")
                self._n_pumps += 1
            obs.REGISTRY.inc("repro_service_pumps_total")
            if plan.parked:
                obs.REGISTRY.inc("repro_service_parked_total",
                                 len(plan.parked))
            for adm in adms:
                self._admit(adm, t0)
            waves = 0
            if self._inflight:
                budget = (max_waves if max_waves is not None
                          else plan.max_waves)
                with obs.span("sched:pump", admitted=len(adms),
                              inflight=len(self._inflight),
                              parked=len(plan.parked), budget=budget):
                    waves = self._router.pump(budget, select=plan.active)
            for tag, result in self._router.pop_completed():
                resolved.update(self._finish(tag, result))
            with self._lock:
                self._drain_time_s += time.perf_counter() - t0
                self._n_drained += len(resolved)
        return resolved

    def drain(self) -> Dict[int, OrderResult]:
        """Pump until every queued and in-flight request resolves.

        Returns {request_id: OrderResult} for the requests resolved by
        this call — the batch-serving surface on top of the incremental
        pump loop (duplicate fingerprints computed once and fanned out,
        same-bucket lanes of concurrent requests sharing launches).
        """
        resolved: Dict[int, OrderResult] = {}
        with self._lock:
            busy = self.queue_depth() > 0 or bool(self._inflight)
        if not busy:
            return resolved
        with obs.span("drain"):
            while True:
                resolved.update(self.pump())
                with self._lock:
                    if not (self.queue_depth() > 0 or self._inflight):
                        break
        return resolved

    # ------------------------------------------------------------------ #
    def _admit(self, adm: _Admission, now: float,
               cold: bool = False) -> None:
        """Move one admission onto the router (warm-started if indexed).

        ``cold`` forces the exact path regardless of the warm index —
        the OPC-guard fallback re-admits through it.
        """
        hints = None
        warm_tree = None
        if self.warm_starts and not cold:
            warm_tree = self.warm.get(adm.struct_fp)
            if warm_tree is not None:
                hints = warm_tree.parts
                self._n_warm_hits += 1
                obs.REGISTRY.inc("repro_service_warm_total", result="hit")
            else:
                obs.REGISTRY.inc("repro_service_warm_total", result="miss")
        rec = {} if self._warm_record else None
        if adm.kind == "host":
            head = adm.reqs[0]
            ordering = Ordering(head.graph.n)
            gen = request_task(head.graph, head.seed, head.nproc,
                               head.cfg, ordering, hints=hints, rec=rec)
            assemble = lambda result, o=ordering: o.assemble()  # noqa: E731
        else:
            from repro.core.dnd import distributed_order_task
            head = adm.reqs[0]
            gen = distributed_order_task(head.dg, head.seed, head.cfg,
                                         hints=hints, rec=rec)
            assemble = lambda result: result.assemble()         # noqa: E731
        self._router.submit(gen, tag=adm.fp)
        with self._lock:
            self._inflight[adm.fp] = _Inflight(
                adm, now, assemble, rec, warm_tree,
                warm_used=hints is not None)

    def _finish(self, fp: str, result) -> Dict[int, OrderResult]:
        """Resolve one completed fingerprint — or recover.

        Before anything resolves ``ok`` the result passes rung 4's
        validation gates: an excised tree (``TaskFailure``) or a failing
        assembly goes to ``_fail_or_readmit``; the assembled permutation
        is checked for validity (after the ``result``-site injection
        point), and warm starts keep their OPC guard.  A corrupt result
        is **never** written to the fingerprint cache and **never**
        resolves ``ok`` — it re-runs cold or fans out ``failed``.
        """
        resolved: Dict[int, OrderResult] = {}
        with self._lock:
            inflight = self._inflight.pop(fp)
            adm = inflight.adm
            exec_s = (inflight.exec_acc
                      + self._router.exec_s_by_tag.pop(fp, 0.0))
            if isinstance(result, TaskFailure):
                return self._fail_or_readmit(fp, inflight, exec_s,
                                             result.error)
            t_chk = time.perf_counter()
            try:
                perm = inflight.assemble(result)
            except Exception as err:
                return self._fail_or_readmit(fp, inflight, exec_s, err)
            inj = faults.active()
            if inj is not None:
                perm = inj.corrupt_result(fp, perm)
            if not _is_permutation(perm, adm.n):
                return self._fail_or_readmit(
                    fp, inflight, exec_s, faults.CorruptResult(
                        f"assembled result for {fp[:16]} is not a "
                        f"permutation of [0, {adm.n})"))
            if inflight.warm_used and adm.kind == "host":
                # OPC guard: a warm-started tree must match the recorded
                # quality of its source (OPC is structure+perm only, so
                # the comparison is exact across weight changes);
                # degradation triggers the exact-parity fallback —
                # re-run cold.
                from repro.sparse.symbolic import nnz_opc
                opc = float(nnz_opc(adm.reqs[0].graph, perm)[1])
                exec_s += time.perf_counter() - t_chk
                src = inflight.warm_tree
                if (src.opc >= 0
                        and opc > self.warm_opc_ratio_max * src.opc):
                    self._n_warm_fallbacks += 1
                    obs.REGISTRY.inc("repro_service_warm_total",
                                     result="fallback")
                    self._admit(adm, inflight.t_admit, cold=True)
                    self._inflight[fp].exec_acc = exec_s
                    return {}
            self.cache.put(fp, perm)
            if (self._warm_record and inflight.rec is not None
                    and not inflight.warm_used):
                # record the cold tree's splits for future structural
                # near-hits; OPC recorded for host graphs only (the
                # distributed guard would need a centralizing gather —
                # dist entries rely on per-node split validation)
                if adm.kind == "host":
                    from repro.sparse.symbolic import nnz_opc
                    opc = float(nnz_opc(adm.reqs[0].graph, perm)[1])
                else:
                    opc = -1.0
                self.warm.put(adm.struct_fp, inflight.rec, opc, adm.n, fp)
            retries, degraded = self._router.recovery.pop_tag(fp)
            for k, req in enumerate(adm.reqs):
                res = self._resolve(
                    req.request_id, perm, k > 0, req.t_submit, fp,
                    queue_wait=max(0.0, inflight.t_admit - req.t_submit),
                    exec_s=exec_s, n=adm.n, deadline=req.deadline,
                    warm=inflight.warm_used, retries=retries,
                    degraded=degraded)
                resolved[req.request_id] = res
            self._n_computed += 1
        return resolved

    def _fail_or_readmit(self, fp: str, inflight: _Inflight,
                         exec_s: float, error: BaseException
                         ) -> Dict[int, OrderResult]:
        """Ladder rung 3's service half: one failed/invalid computation
        re-admits **cold** through the normal queue path (the warm
        fallback's shape) up to ``max_readmits`` times; past the budget
        every coalesced rider — queued or in flight — resolves
        ``status=failed`` so none can hang in ``poll()``.
        """
        adm = inflight.adm
        if adm.fault_readmits < self._router.recovery.cfg.max_readmits:
            adm.fault_readmits += 1
            obs.REGISTRY.inc("repro_service_readmits_total")
            with obs.span("recover:readmit", tag=fp[:16],
                          error=type(error).__name__,
                          attempt=adm.fault_readmits):
                pass
            self._admit(adm, inflight.t_admit, cold=True)
            self._inflight[fp].exec_acc = exec_s
            return {}
        retries, degraded = self._router.recovery.pop_tag(fp)
        resolved: Dict[int, OrderResult] = {}
        for req in adm.reqs:
            resolved[req.request_id] = self._resolve(
                req.request_id, None, False, req.t_submit, fp,
                queue_wait=max(0.0, inflight.t_admit - req.t_submit),
                exec_s=exec_s, n=adm.n, deadline=req.deadline,
                status="failed", retries=retries, degraded=degraded)
        return resolved

    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, float]:
        """Service counters: dedup/cache/warm effectiveness, latency,
        deadline compliance, throughput.

        End-to-end latency is reported alongside its two components so
        queue pressure and execution speed are visible separately:
        ``queue_wait_ms`` percentiles measure how long requests sat in
        the admission queues (a function of pump cadence and policy),
        and ``exec_ms`` percentiles measure each request's *own
        attributed* share of the waves it rode — both pooled and per
        size class (``by_class``), where each class also carries its
        explicit-deadline met/missed counts.
        """
        def pcts(values, suffix):
            arr = np.asarray(list(values)) if values else np.zeros(1)
            return {
                f"p50_{suffix}_ms":
                    round(float(np.percentile(arr, 50)) * 1e3, 3),
                f"p95_{suffix}_ms":
                    round(float(np.percentile(arr, 95)) * 1e3, 3),
            }
        with self._lock:
            by_class = {}
            for cls in sorted(set(self._execs_by_class)
                              | set(self._qwaits_by_class)):
                execs = self._execs_by_class.get(cls, ())
                met, missed = self._deadline_by_class.get(cls, (0, 0))
                by_class[cls] = {
                    "count": len(execs),
                    **pcts(execs, "exec"),
                    **pcts(self._qwaits_by_class.get(cls, ()),
                           "queue_wait"),
                    "deadline_total": met + missed,
                    "deadline_misses": missed,
                    "deadline_miss_rate": round(
                        missed / (met + missed), 4) if met + missed
                        else 0.0,
                }
            return {
                "requests": self._n_submitted,
                "computed": self._n_computed,
                "cache_hits": self.cache.hits,
                "cache_hit_rate": round(self.cache.hit_rate, 4),
                "cache_size": len(self.cache),
                "queue_depth": self.queue_depth(),
                "inflight": len(self._inflight),
                "pumps": self._n_pumps,
                "warm_hits": self._n_warm_hits,
                "warm_fallbacks": self._n_warm_fallbacks,
                "warm_size": len(self.warm),
                "shed": self._n_shed,
                "failed": self._n_failed,
                "fault_retries": self._n_retries,
                "degraded": self._n_degraded,
                "router": self._router.stats(),
                **pcts(self._latencies, "latency"),
                **pcts(self._queue_waits, "queue_wait"),
                **pcts(self._execs, "exec"),
                "by_class": by_class,
                "deadline_miss_rate": round(
                    sum(m for _, m in self._deadline_by_class.values())
                    / max(sum(t + m for t, m in
                              self._deadline_by_class.values()), 1), 4),
                "orderings_per_sec": round(
                    self._n_drained / self._drain_time_s, 3)
                    if self._drain_time_s else 0.0,
            }

    # ------------------------------------------------------------------ #
    def _resolve(self, rid: int, perm: Optional[np.ndarray],
                 cached: bool,
                 t_submit: float, fp: str, queue_wait: float = 0.0,
                 exec_s: Optional[float] = None,
                 n: Optional[int] = None,
                 deadline: Optional[float] = None,
                 warm: bool = False, status: str = "ok",
                 retries: int = 0,
                 degraded: bool = False) -> OrderResult:
        t_now = time.perf_counter()
        lat = t_now - t_submit
        if exec_s is None:              # cache hit: the lookup IS the work
            exec_s = lat
        cls = size_class(n) if n is not None else ""
        # shed/failed requests never count against SLO compliance (they
        # have their own terminal accounting) nor into the latency/exec
        # percentiles that feed the feasibility estimator
        missed = (None if deadline is None or status != "ok"
                  else bool(t_now > deadline))
        res = OrderResult(rid, perm, cached, lat, float(queue_wait),
                          float(exec_s), fp, cls, missed, warm,
                          status, int(retries), bool(degraded))
        self._results[rid] = res
        while len(self._results) > self._result_capacity:
            self._results.popitem(last=False)
        self._n_retries += int(retries)
        self._n_degraded += bool(degraded)
        if status != "ok":
            if status == "shed":
                self._n_shed += 1
                obs.REGISTRY.inc("repro_service_shed_total",
                                 size_class=cls)
            else:
                self._n_failed += 1
                obs.REGISTRY.inc("repro_service_failed_total",
                                 size_class=cls)
            tracer = obs.current()
            if tracer is not None:
                tracer.add_span("request", t_submit, t_now,
                                attrs={"rid": rid, "status": status,
                                       "fingerprint": fp[:16],
                                       "size_class": cls})
            return res
        self._latencies.append(lat)
        self._queue_waits.append(float(queue_wait))
        self._execs.append(float(exec_s))
        if cls:
            self._execs_by_class.setdefault(
                cls, deque(maxlen=self._latency_window)).append(
                    float(exec_s))
            self._qwaits_by_class.setdefault(
                cls, deque(maxlen=self._latency_window)).append(
                    float(queue_wait))
            obs.REGISTRY.observe("repro_service_exec_seconds",
                                 float(exec_s), size_class=cls)
            obs.REGISTRY.observe("repro_service_queue_wait_seconds",
                                 float(queue_wait), size_class=cls)
            if missed is not None:
                counters = self._deadline_by_class.setdefault(cls, [0, 0])
                counters[1 if missed else 0] += 1
                obs.REGISTRY.inc(
                    "repro_service_deadline_total", size_class=cls,
                    result="missed" if missed else "met")
        tracer = obs.current()
        if tracer is not None:
            # retrospective request span tree: the latency breakdown is
            # only known at resolve time (queue_wait then exec)
            root = tracer.add_span(
                "request", t_submit, t_now,
                attrs={"rid": rid, "fingerprint": fp[:16],
                       "size_class": cls, "cached": cached})
            if queue_wait > 0.0:
                tracer.add_span("queue_wait", t_submit,
                                t_submit + queue_wait,
                                parent_id=root.span_id)
            tracer.add_span("exec", t_now - float(exec_s), t_now,
                            parent_id=root.span_id)
        return res
