"""Ordering service front end: submit / poll / drain / stats.

Usage (see examples/serve_orderings.py):

    svc = OrderingService()
    rids = [svc.submit(g, seed=0, nproc=16) for g in graphs]
    svc.drain()                       # one bucketed batch over the queue
    perm = svc.poll(rids[0]).perm
    print(svc.stats())                # hit rate, p50/p95 latency, thru-put

``submit`` fingerprints the request (CSR content + seed + nproc + config);
a cache hit resolves immediately and duplicate *pending* fingerprints are
coalesced so each unique problem is ordered once per drain.
``submit_distributed`` does the same for sharded ``DGraph`` requests
(fingerprinted over the full shard layout + seed + ``DNDConfig``).
``drain`` feeds ALL unique pending requests — distributed trees through
``distributed_order_batch``, host graphs through ``order_batch`` — into
the shared wave router, which executes each wave's separator work —
matching, band BFS and FM, centralized and lane-stacked distributed —
bucketed across the whole queue: one launch per shape bucket per wave,
regardless of how many requests contributed lanes.

Contracts: graphs are ``core.graph.Graph`` (symmetric CSR, host numpy);
results carry ``perm`` with perm[k] = vertex eliminated k-th, always a
permutation of [0, n).  The pipeline is deterministic given (graph, seed,
nproc, cfg) — equal fingerprints imply identical permutations, which is
what makes the cache sound.  The service is single-process; one ``drain``
call runs everything on the local device set.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from typing import Dict, Optional

import numpy as np

from repro import obs
from repro.core.graph import Graph
from repro.core.nd import NDConfig
from repro.service.cache import FingerprintCache
from repro.service.fingerprint import (dgraph_fingerprint,
                                       request_fingerprint)
from repro.service.scheduler import order_batch

#: size-class boundaries (vertex count → class label); the classes key
#: the per-class latency percentiles of ``stats()["by_class"]`` and
#: BENCH_service.json's ``exec_ms_by_class``
_SIZE_CLASSES = ((256, "xs"), (1024, "s"), (8192, "m"))


def size_class(n: int) -> str:
    """Bucket a graph size into the service's latency size classes."""
    for bound, label in _SIZE_CLASSES:
        if n < bound:
            return label
    return "l"


@dataclasses.dataclass
class OrderResult:
    request_id: int
    perm: np.ndarray
    cached: bool                    # served from the fingerprint cache
    latency_s: float                # submit → resolve (wait + execution)
    queue_wait_s: float             # submit → drain start (0 on cache hits)
    exec_s: float                   # batched-execution share of the latency
    fingerprint: str
    size_class: str = ""            # see ``size_class()``


@dataclasses.dataclass
class _PendingReq:
    request_id: int
    t_submit: float
    graph: Graph
    seed: int
    nproc: int
    cfg: NDConfig


@dataclasses.dataclass
class _PendingDistReq:
    request_id: int
    t_submit: float
    dg: object                      # core.dgraph.DGraph
    seed: int
    cfg: object                     # core.dnd.DNDConfig


class OrderingService:
    """Batched nested-dissection ordering service (single-process)."""

    def __init__(self, cfg: Optional[NDConfig] = None,
                 cache_capacity: int = 1024,
                 result_capacity: int = 4096,
                 latency_window: int = 4096):
        self.default_cfg = cfg or NDConfig()
        self.cache = FingerprintCache(cache_capacity)
        self._next_rid = 0
        # resolved results are retained FIFO-bounded: a long-running
        # service must not grow per served request (perms live on in the
        # LRU cache; old request ids just stop polling successfully)
        self._result_capacity = result_capacity
        self._results: "OrderedDict[int, OrderResult]" = OrderedDict()
        self._pending: Dict[str, list] = {}
        self._pending_dist: Dict[str, list] = {}
        self._latencies: deque = deque(maxlen=latency_window)
        # queue-wait and execution components recorded separately: the
        # end-to-end latency of a drained request is dominated by how
        # long it sat in the queue, which says nothing about how fast
        # the batch executed — reporting one conflated percentile made
        # the service look 10000× slower than its compute (the old
        # p95_latency_ms of BENCH_service.json)
        self._queue_waits: deque = deque(maxlen=latency_window)
        self._execs: deque = deque(maxlen=latency_window)
        self._execs_by_class: Dict[str, deque] = {}
        self._latency_window = latency_window
        self._n_submitted = 0
        self._n_computed = 0
        self._drain_time_s = 0.0
        self._n_drained = 0
        # submit / poll / stats run on the caller's thread while drain
        # may run on a worker: every mutation of the queues, result map
        # and latency deques happens under this lock.  RLock because the
        # submit cache-hit path resolves inline while already holding it.
        self._lock = threading.RLock()

    # ------------------------------------------------------------------ #
    def submit(self, g: Graph, seed: int = 0, nproc: int = 1,
               cfg: Optional[NDConfig] = None) -> int:
        """Enqueue an ordering request; returns a request id.

        Cache hits resolve immediately (poll right away); misses resolve
        at the next ``drain``.
        """
        cfg = cfg or self.default_cfg
        t0 = time.perf_counter()
        fp = request_fingerprint(g, seed, nproc, cfg)   # pure: no lock
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self._n_submitted += 1
            perm = self.cache.get(fp)
            if perm is not None:
                obs.REGISTRY.inc("repro_service_requests_total",
                                 result="hit")
                self._resolve(rid, perm, True, t0, fp, queue_wait=0.0,
                              n=g.n)
                return rid
            obs.REGISTRY.inc("repro_service_requests_total", result="miss")
            req = _PendingReq(rid, t0, g, seed, nproc, cfg)
            self._pending.setdefault(fp, []).append(req)
            return rid

    def submit_distributed(self, dg, seed: int = 0, cfg=None) -> int:
        """Enqueue a distributed (sharded ``DGraph``) ordering request.

        Same cache/coalescing semantics as ``submit``; misses resolve at
        the next ``drain``, where ALL queued distributed trees drain
        through one shared wave router (``distributed_order_batch``) —
        their same-bucket subproblems stack into shared launches.
        """
        from repro.core.dnd import DNDConfig
        cfg = cfg or DNDConfig()
        t0 = time.perf_counter()
        fp = dgraph_fingerprint(dg, seed, cfg)          # pure: no lock
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self._n_submitted += 1
            perm = self.cache.get(fp)
            if perm is not None:
                obs.REGISTRY.inc("repro_service_requests_total",
                                 result="hit")
                self._resolve(rid, perm, True, t0, fp, queue_wait=0.0,
                              n=dg.n_global)
                return rid
            obs.REGISTRY.inc("repro_service_requests_total", result="miss")
            req = _PendingDistReq(rid, t0, dg, seed, cfg)
            self._pending_dist.setdefault(fp, []).append(req)
            return rid

    def poll(self, rid: int) -> Optional[OrderResult]:
        """Result for a request id, or None while still queued."""
        with self._lock:
            return self._results.get(rid)

    def queue_depth(self) -> int:
        with self._lock:
            return (sum(len(v) for v in self._pending.values())
                    + sum(len(v) for v in self._pending_dist.values()))

    # ------------------------------------------------------------------ #
    def drain(self) -> Dict[int, OrderResult]:
        """Order every queued request through the shared wave router.

        Duplicate fingerprints are computed once and fanned out.
        Distributed requests drain first — all their task trees share one
        ``WaveRouter`` (same-bucket lanes of different requests stack
        into shared launches, and their centralized endgames merge into
        one ``order_batch``) — then the host-graph queue drains through
        its own shared router.  Returns {request_id: OrderResult} for the
        requests resolved by this call.  The batched execution itself
        runs *outside* the service lock, so submits on other threads stay
        responsive during a drain (they queue for the next one).
        """
        with self._lock:
            if not (self._pending or self._pending_dist):
                return {}
            pending, self._pending = self._pending, {}
            pending_dist, self._pending_dist = self._pending_dist, {}
        fps = list(pending)
        heads = [pending[fp][0] for fp in fps]
        dfps = list(pending_dist)
        dheads = [pending_dist[fp][0] for fp in dfps]
        t0 = time.perf_counter()
        with obs.span("drain", batches=len(fps), dist_batches=len(dfps)):
            dperms = []
            if dheads:
                from repro.core.dnd import distributed_order_batch
                dperms = distributed_order_batch(
                    [r.dg for r in dheads], [r.seed for r in dheads],
                    [r.cfg for r in dheads])
            perms = []
            if heads:
                perms = order_batch([r.graph for r in heads],
                                    [r.seed for r in heads],
                                    [r.nproc for r in heads],
                                    [r.cfg for r in heads])
        dt = time.perf_counter() - t0
        resolved: Dict[int, OrderResult] = {}
        n_resolved = 0
        with self._lock:
            for fp, perm, head, n in (
                    [(f, p, h, h.graph.n)
                     for f, p, h in zip(fps, perms, heads)]
                    + [(f, p, h, h.dg.n_global)
                       for f, p, h in zip(dfps, dperms, dheads)]):
                self.cache.put(fp, perm)
                reqs = pending.get(fp) or pending_dist[fp]
                for k, req in enumerate(reqs):
                    res = self._resolve(req.request_id, perm, k > 0,
                                        req.t_submit, fp,
                                        queue_wait=t0 - req.t_submit,
                                        exec_s=dt, n=n)
                    resolved[req.request_id] = res
                    n_resolved += 1
            self._n_computed += len(fps) + len(dfps)
            self._drain_time_s += dt
            self._n_drained += n_resolved
        return resolved

    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, float]:
        """Service counters: dedup/cache effectiveness, latency, throughput.

        End-to-end latency is reported alongside its two components so
        queue pressure and execution speed are visible separately:
        ``queue_wait_ms`` percentiles measure how long requests sat in
        the drain queue (a function of the caller's drain cadence), and
        ``exec_ms`` percentiles measure the batched-execution time a
        resolved request actually shared in.
        """
        def pcts(values, suffix):
            arr = np.asarray(list(values)) if values else np.zeros(1)
            return {
                f"p50_{suffix}_ms":
                    round(float(np.percentile(arr, 50)) * 1e3, 3),
                f"p95_{suffix}_ms":
                    round(float(np.percentile(arr, 95)) * 1e3, 3),
            }
        with self._lock:
            by_class = {
                cls: {"count": len(vals), **pcts(vals, "exec")}
                for cls, vals in sorted(self._execs_by_class.items())}
            return {
                "requests": self._n_submitted,
                "computed": self._n_computed,
                "cache_hits": self.cache.hits,
                "cache_hit_rate": round(self.cache.hit_rate, 4),
                "cache_size": len(self.cache),
                "queue_depth": (
                    sum(len(v) for v in self._pending.values())
                    + sum(len(v) for v in self._pending_dist.values())),
                **pcts(self._latencies, "latency"),
                **pcts(self._queue_waits, "queue_wait"),
                **pcts(self._execs, "exec"),
                "by_class": by_class,
                "orderings_per_sec": round(
                    self._n_drained / self._drain_time_s, 3)
                    if self._drain_time_s else 0.0,
            }

    # ------------------------------------------------------------------ #
    def _resolve(self, rid: int, perm: np.ndarray, cached: bool,
                 t_submit: float, fp: str, queue_wait: float = 0.0,
                 exec_s: Optional[float] = None,
                 n: Optional[int] = None) -> OrderResult:
        t_now = time.perf_counter()
        lat = t_now - t_submit
        if exec_s is None:              # cache hit: the lookup IS the work
            exec_s = lat
        cls = size_class(n) if n is not None else ""
        res = OrderResult(rid, perm, cached, lat, float(queue_wait),
                          float(exec_s), fp, cls)
        self._results[rid] = res
        while len(self._results) > self._result_capacity:
            self._results.popitem(last=False)
        self._latencies.append(lat)
        self._queue_waits.append(float(queue_wait))
        self._execs.append(float(exec_s))
        if cls:
            self._execs_by_class.setdefault(
                cls, deque(maxlen=self._latency_window)).append(
                    float(exec_s))
            obs.REGISTRY.observe("repro_service_exec_seconds",
                                 float(exec_s), size_class=cls)
        tracer = obs.current()
        if tracer is not None:
            # retrospective request span tree: the latency breakdown is
            # only known at resolve time (queue_wait then exec)
            root = tracer.add_span(
                "request", t_submit, t_now,
                attrs={"rid": rid, "fingerprint": fp[:16],
                       "size_class": cls, "cached": cached})
            if queue_wait > 0.0:
                tracer.add_span("queue_wait", t_submit,
                                t_submit + queue_wait,
                                parent_id=root.span_id)
            tracer.add_span("exec", t_now - float(exec_s), t_now,
                            parent_id=root.span_id)
        return res
