"""LRU caches of finished work: exact orderings and warm-start trees.

``FingerprintCache`` maps *exact* request fingerprints (content + seed
+ nproc + cfg) to permutations — equal keys imply identical orderings,
so a hit is the answer.  ``WarmStartIndex`` is the second, structural
index (DESIGN.md §7): it maps topology-modulo-weights fingerprints to
the *separator splits* of a completed ordering tree, so a near-hit —
same adjacency, different weights (or seed) — can seed a new recursion
from the cached splits instead of running full multilevel per node.  A
warm entry is a hint, never an answer: every split is re-validated on
the new graph and the warm result is OPC-guarded against the entry's
recorded quality (``service.api``), falling back to the exact cold
path when it degrades.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Optional

import numpy as np


class FingerprintCache:
    """Bounded LRU mapping request fingerprints to permutations.

    Values are stored read-only (the same ordering may be handed to many
    requesters); hit/miss/eviction counters feed the service stats.
    """

    def __init__(self, capacity: int = 1024):
        assert capacity > 0
        self.capacity = capacity
        self._d: "OrderedDict[str, np.ndarray]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key: str) -> bool:
        return key in self._d

    def get(self, key: str) -> Optional[np.ndarray]:
        val = self._d.get(key)
        if val is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return val

    def put(self, key: str, perm: np.ndarray) -> None:
        # Freeze a private copy: np.asarray aliases an existing ndarray, so
        # setflags on it would freeze the *caller's* array in place.
        perm = np.array(perm, copy=True)
        # Never-cache-corrupt invariant (DESIGN.md §8): the service
        # validates before calling, but a cache serves every future
        # duplicate — re-check here so no caller can poison it.
        n = perm.shape[0] if perm.ndim == 1 else -1
        if (perm.ndim != 1 or not np.issubdtype(perm.dtype, np.integer)
                or (n and not (np.bincount(
                    perm.clip(0, max(n - 1, 0)), minlength=n) == 1).all())
                or (n and (perm.min() < 0 or perm.max() >= n))):
            raise ValueError(
                f"refusing to cache a non-permutation for {key[:16]}")
        perm.setflags(write=False)
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = perm
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


# ------------------------------------------------------------------ #
# structural warm-start index
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class WarmTree:
    """Separator splits of one completed ordering tree.

    ``parts`` maps ND-tree node paths (root ``""``, children ``.0`` /
    ``.1``, components ``.c<k>``, distributed-endgame subtrees prefixed
    ``n<node>``) to the resolved part vector (0/1/2 per vertex, local
    indices) actually used at that node.  ``opc`` is the recorded
    operation count of the source ordering — OPC is a function of
    topology + permutation only, so it is directly comparable with a
    warm-started result on any same-structure graph (the fallback
    guard).  ``source_fp`` names the exact request that produced the
    tree (observability only).
    """
    parts: Dict[str, np.ndarray]
    opc: float
    n: int
    source_fp: str


class WarmStartIndex:
    """Bounded LRU: structural fingerprint → ``WarmTree``.

    Same LRU/counter discipline as ``FingerprintCache``; part vectors
    are frozen private copies (one tree may seed many requests).
    ``put`` keeps the *first* tree per structure unless ``replace`` —
    later re-records of the same topology would otherwise churn the
    entry without improving it (OPC is structure-determined to within
    seed noise).
    """

    def __init__(self, capacity: int = 256):
        assert capacity > 0
        self.capacity = capacity
        self._d: "OrderedDict[str, WarmTree]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key: str) -> bool:
        return key in self._d

    def get(self, key: str) -> Optional[WarmTree]:
        tree = self._d.get(key)
        if tree is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return tree

    def put(self, key: str, parts: Dict[str, np.ndarray], opc: float,
            n: int, source_fp: str, replace: bool = False) -> None:
        if key in self._d and not replace:
            self._d.move_to_end(key)
            return
        frozen = {}
        for path, part in parts.items():
            part = np.array(part, copy=True)
            part.setflags(write=False)
            frozen[path] = part
        self._d[key] = WarmTree(frozen, float(opc), int(n), source_fp)
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
