"""LRU fingerprint cache: CSR content hash → finished ordering."""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional

import numpy as np


class FingerprintCache:
    """Bounded LRU mapping request fingerprints to permutations.

    Values are stored read-only (the same ordering may be handed to many
    requesters); hit/miss/eviction counters feed the service stats.
    """

    def __init__(self, capacity: int = 1024):
        assert capacity > 0
        self.capacity = capacity
        self._d: "OrderedDict[str, np.ndarray]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key: str) -> bool:
        return key in self._d

    def get(self, key: str) -> Optional[np.ndarray]:
        val = self._d.get(key)
        if val is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return val

    def put(self, key: str, perm: np.ndarray) -> None:
        # Freeze a private copy: np.asarray aliases an existing ndarray, so
        # setflags on it would freeze the *caller's* array in place.
        perm = np.array(perm, copy=True)
        perm.setflags(write=False)
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = perm
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
