"""Batched ordering service (DESIGN.md §3).

High-throughput front end over the PT-Scotch reproduction: a request
queue with a graph fingerprint cache, a breadth-first nested-dissection
scheduler, and bucketed vmap execution of all separator subproblems that
share a padded ELL shape.
"""
from repro.service.api import OrderingService, OrderResult
from repro.service.cache import FingerprintCache
from repro.service.fingerprint import graph_fingerprint, request_fingerprint
from repro.service.scheduler import order_batch

__all__ = ["OrderingService", "OrderResult", "FingerprintCache",
           "graph_fingerprint", "request_fingerprint", "order_batch"]
