"""Batched ordering service (DESIGN.md §3, §5).

High-throughput front end over the PT-Scotch reproduction: a request
queue with a graph fingerprint cache, the unified wave router — ONE
shared lane stack across all concurrently-submitted orderings,
centralized and distributed — and bucketed execution of every wave's
subproblems that share a padded ELL shape.
"""
from repro.service.api import OrderingService, OrderResult
from repro.service.cache import FingerprintCache
from repro.service.fingerprint import (dgraph_fingerprint,
                                       graph_fingerprint,
                                       request_fingerprint)
from repro.service.router import (RouterConfig, WaveRouter, execute_wave,
                                  global_config)
from repro.service.scheduler import order_batch

__all__ = ["OrderingService", "OrderResult", "FingerprintCache",
           "RouterConfig", "WaveRouter", "dgraph_fingerprint",
           "execute_wave", "global_config", "graph_fingerprint",
           "order_batch", "request_fingerprint"]
