"""Deterministic fault injection + recovery configuration (DESIGN.md §8).

PT-Scotch's fold-dup already embraces redundancy — duplicate separator
instances race and the best wins — but the serving stack had no failure
story: one raised dispatch or one NaN-corrupted kernel output took down
a whole ``pump()`` and every co-riding request in the shared lane
stacks.  This module is the *chaos half* of the failure model: a seeded
``FaultPlan`` describes typed faults to inject at the existing dispatch
boundaries, and a ``FaultInjector`` fires them deterministically.  The
*recovery half* — retry, degrade, excise, validate, shed — lives in
``service/router.py`` and ``service/api.py`` and is configured by
``RecoveryConfig`` here.

Injection sites (one per existing dispatch boundary):

  * the ``obs.timed_dispatch`` kinds — ``fm`` / ``bfs`` / ``match``
    (centralized bucketed executors, incl. ``kernels/ops
    .fm_refine_batch`` behind the ``fm`` dispatch) and ``dhalo`` /
    ``dbfs`` / ``dmatch`` (the stacked collectives of
    ``core/dgraph.py``) — hooked through ``obs.set_fault_hook`` so the
    core layers stay service-free;
  * ``wave`` — checked by ``WaveRouter.pump`` before each wave executes;
  * ``result`` — checked by the service before a completed ordering is
    validated/cached (corrupts the assembled permutation).

Typed faults:

  * ``transient``  — raises ``TransientFault`` (retryable);
  * ``persistent`` — raises ``PersistentFault`` (never retried: the
    ladder degrades, isolates, or excises);
  * ``nan``        — corrupts the dispatch output in place of raising
    (``fm`` only: NaN separator weights + out-of-range parts), so the
    *validation* rungs are exercised, not the exception path;
  * ``corrupt_perm`` — corrupts the assembled permutation (``result``
    site only) so the never-cache-corrupt invariant is exercised;
  * ``delay``      — sleeps ``delay_s`` (a straggler; observable via the
    router's ``StragglerMonitor`` wave EWMA).

Decisions are pure functions of ``(plan.seed, site, invocation index)``
— equal plans against equal workloads inject identically, which is what
lets the chaos bench assert that every ``ok`` result is bit-identical
to the fault-free run.  ``REPRO_FAULT_PLAN`` (a JSON plan, or ``@path``
to one) configures a process-global injector at service construction.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs


# ------------------------------------------------------------------ #
# fault taxonomy
# ------------------------------------------------------------------ #
class FaultError(RuntimeError):
    """Base of all injected faults (never raised by real code paths)."""


class TransientFault(FaultError):
    """A fault worth retrying (the injected stand-in for a flaky
    dispatch: preempted device, dropped collective, OOM race)."""


class PersistentFault(FaultError):
    """A fault retries cannot fix — the ladder must degrade the kernel
    path, isolate lanes, or excise the ordering."""


class CorruptResult(RuntimeError):
    """Raised by the *validators* (not injected) when a dispatch output
    or an assembled permutation fails its invariant check."""


def is_transient(exc: BaseException) -> bool:
    """Ladder rung 1 classification: only explicitly-transient faults
    are retried; everything else escalates (degrade/isolate/excise)."""
    return isinstance(exc, TransientFault)


#: dispatch-boundary sites reachable through the obs hook
DISPATCH_SITES = ("fm", "bfs", "match", "dhalo", "dbfs", "dmatch")
#: all valid sites, with the kinds each may inject
_SITE_KINDS: Dict[str, Tuple[str, ...]] = {
    **{s: ("transient", "persistent", "delay") for s in DISPATCH_SITES},
    "fm": ("transient", "persistent", "delay", "nan"),
    "wave": ("transient", "persistent", "delay"),
    "result": ("corrupt_perm", "delay"),
}


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection rule of a plan.

    Fires at explicit site-invocation indices (``at``) or with a seeded
    per-invocation probability (``rate``); ``count`` caps total fires
    (None = unbounded).  ``tag`` restricts the rule to dispatches that
    carry the given request tag — the handle for poisoning ONE ordering
    in a shared wave (the lane-excision scenario) without touching its
    co-riders.  Tag-filtered rules only apply at sites where tags are
    known (``wave`` / ``result``, and any dispatch the router attributes).
    """
    site: str
    kind: str                       # transient|persistent|nan|corrupt_perm|delay
    at: Tuple[int, ...] = ()
    rate: float = 0.0
    count: Optional[int] = None
    delay_s: float = 0.05
    tag: Optional[str] = None

    def __post_init__(self):
        kinds = _SITE_KINDS.get(self.site)
        if kinds is None:
            raise ValueError(f"unknown fault site {self.site!r} (valid: "
                             f"{sorted(_SITE_KINDS)})")
        if self.kind not in kinds:
            raise ValueError(
                f"fault kind {self.kind!r} not valid at site "
                f"{self.site!r} (valid: {kinds})")
        if not self.at and self.rate <= 0.0:
            raise ValueError("FaultSpec needs explicit `at` indices or "
                             "a positive `rate`")


class FaultPlan:
    """A seeded, serializable schedule of ``FaultSpec`` rules."""

    def __init__(self, seed: int = 0,
                 specs: Sequence[FaultSpec] = ()):
        self.seed = int(seed)
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)

    # ---------------------------------------------------------------- #
    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "specs": [dataclasses.asdict(s) for s in self.specs]})

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        doc = json.loads(text)
        specs = []
        for d in doc.get("specs", []):
            d = dict(d)
            d["at"] = tuple(d.get("at") or ())
            specs.append(FaultSpec(**d))
        return cls(seed=doc.get("seed", 0), specs=specs)

    @classmethod
    def from_env(cls) -> Optional["FaultPlan"]:
        """Plan from ``REPRO_FAULT_PLAN`` (JSON, or ``@path`` to a JSON
        file); None when unset/empty."""
        raw = os.environ.get("REPRO_FAULT_PLAN", "").strip()
        if not raw:
            return None
        if raw.startswith("@"):
            with open(raw[1:]) as f:
                raw = f.read()
        return cls.from_json(raw)


# ------------------------------------------------------------------ #
# recovery-ladder configuration (the mechanism lives in router/api)
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class RecoveryConfig:
    """Knob surface of the recovery ladder (env-var defaults, the
    ``RouterConfig`` idiom)."""
    #: rung 1 — per-dispatch retries for transient faults
    max_retries: int = dataclasses.field(
        default_factory=lambda: int(os.environ.get(
            "REPRO_FAULT_RETRIES", "2")))
    #: capped exponential backoff between retries (train/fault.py's
    #: ``RestartPolicy`` shape: base * 2^(attempt-1), capped)
    backoff_s: float = dataclasses.field(
        default_factory=lambda: float(os.environ.get(
            "REPRO_FAULT_BACKOFF_S", "0.01")))
    backoff_cap_s: float = dataclasses.field(
        default_factory=lambda: float(os.environ.get(
            "REPRO_FAULT_BACKOFF_CAP_S", "0.25")))
    #: rung 3 — cold re-admissions of an excised/invalid ordering before
    #: its riders resolve ``status=failed``
    max_readmits: int = dataclasses.field(
        default_factory=lambda: int(os.environ.get(
            "REPRO_FAULT_READMITS", "1")))

    def backoff(self, attempt: int) -> float:
        return min(self.backoff_s * (2 ** max(attempt - 1, 0)),
                   self.backoff_cap_s)


# ------------------------------------------------------------------ #
# the injector
# ------------------------------------------------------------------ #
def _draw(seed: int, site: str, idx: int, rule: int) -> float:
    """Deterministic uniform in [0, 1): a pure function of the plan
    seed and the site invocation, independent of process state."""
    h = hashlib.blake2b(f"{seed}|{site}|{idx}|{rule}".encode(),
                        digest_size=8).digest()
    return int.from_bytes(h, "big") / 2.0 ** 64


class FaultInjector:
    """Active injection state: plan + thread-safe per-site counters.

    ``check(site, tags)`` is called at every boundary; it may sleep
    (``delay``), raise (``transient``/``persistent``), or return a
    corruption directive the *caller* applies (``nan`` /
    ``corrupt_perm``) — corruption must flow through the normal return
    path so the validation rungs, not the exception rungs, catch it.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._counts: Dict[str, int] = {}
        self._left: Dict[int, Optional[int]] = {
            r: s.count for r, s in enumerate(plan.specs)}
        self.injected = 0
        self.injected_by: Dict[Tuple[str, str], int] = {}

    # ---------------------------------------------------------------- #
    def check(self, site: str, tags: Optional[Sequence] = None
              ) -> Optional[str]:
        with self._lock:
            idx = self._counts.get(site, 0)
            self._counts[site] = idx + 1
            fired = None
            for r, spec in enumerate(self.plan.specs):
                if spec.site != site:
                    continue
                if spec.tag is not None and (
                        tags is None or spec.tag not in tags):
                    continue
                if self._left[r] == 0:
                    continue
                hit = (idx in spec.at if spec.at
                       else _draw(self.plan.seed, site, idx, r) < spec.rate)
                if hit:
                    fired = spec
                    if self._left[r] is not None:
                        self._left[r] -= 1
                    break
            if fired is None:
                return None
            self.injected += 1
            key = (site, fired.kind)
            self.injected_by[key] = self.injected_by.get(key, 0) + 1
        obs.REGISTRY.inc("repro_service_faults_injected_total",
                         site=site, kind=fired.kind)
        with obs.span(f"fault:{fired.kind}", site=site, idx=idx):
            if fired.kind == "delay":
                time.sleep(fired.delay_s)
                return None
        if fired.kind == "transient":
            raise TransientFault(f"injected transient at {site}[{idx}]")
        if fired.kind == "persistent":
            raise PersistentFault(f"injected persistent at {site}[{idx}]")
        return fired.kind               # "nan" | "corrupt_perm"

    # ---------------------------------------------------------------- #
    def dispatch_hook(self, kind: str, thunk):
        """The ``obs.timed_dispatch`` wrapper: inject, run, corrupt."""
        directive = self.check(kind)
        out = thunk()
        if directive == "nan":
            out = _corrupt_dispatch(kind, out)
        return out

    def corrupt_result(self, tag, perm: np.ndarray) -> np.ndarray:
        """``result``-site check: possibly return an invalid 'perm'."""
        if self.check("result", tags=(tag,)) == "corrupt_perm":
            perm = np.array(perm, copy=True)
            if perm.size >= 2:          # duplicate an entry: not a perm
                perm[1] = perm[0]
            else:
                perm[:] = -1
        return perm

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {f"{site}:{kind}": n
                    for (site, kind), n in sorted(self.injected_by.items())}


def _corrupt_dispatch(kind: str, out):
    """NaN-corrupt a dispatch output (``fm`` only, see ``_SITE_KINDS``):
    out-of-range parts + NaN weights, certain to fail validation."""
    assert kind == "fm", kind
    parts, sep_w, imb = out
    parts = np.full_like(np.asarray(parts), 7)
    sep_w = np.full_like(np.asarray(sep_w, dtype=np.float64), np.nan)
    imb = np.full_like(np.asarray(imb, dtype=np.float64), np.nan)
    return parts, sep_w, imb


# ------------------------------------------------------------------ #
# installation (process-global, or scoped via ``fault_injection``)
# ------------------------------------------------------------------ #
_ACTIVE: Optional[FaultInjector] = None


def active() -> Optional[FaultInjector]:
    return _ACTIVE


def install(plan: Optional[FaultPlan]) -> Optional[FaultInjector]:
    """Install (or, with None, remove) the process-global injector."""
    global _ACTIVE
    if plan is None:
        _ACTIVE = None
        obs.set_fault_hook(None)
        return None
    inj = FaultInjector(plan)
    _ACTIVE = inj
    obs.set_fault_hook(inj.dispatch_hook)
    return inj


def maybe_install_from_env() -> Optional[FaultInjector]:
    """Install from ``REPRO_FAULT_PLAN`` once (no-op when unset or when
    an injector is already active) — called at service construction."""
    if _ACTIVE is not None:
        return _ACTIVE
    plan = FaultPlan.from_env()
    if plan is None:
        return None
    return install(plan)


@contextlib.contextmanager
def fault_injection(plan: FaultPlan):
    """Scoped injection: install for the block, restore after."""
    global _ACTIVE
    prev = _ACTIVE
    inj = FaultInjector(plan)
    _ACTIVE = inj
    prev_hook = obs.set_fault_hook(inj.dispatch_hook)
    try:
        yield inj
    finally:
        _ACTIVE = prev
        obs.set_fault_hook(prev_hook)
