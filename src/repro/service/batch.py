"""Bucketed execution of outstanding pipeline work (DESIGN.md §3).

``run_works`` takes the mixed list of device-work items that a wave of
separator tasks is blocked on, splits it by kind, and hands each kind to
its bucketed executor: ``execute_fm_works`` / ``execute_bfs_works`` /
``execute_match_works`` group by padded ELL shape and run ONE vmapped
dispatch per bucket.  Per-lane results are independent of batch
composition, so driving N subproblems through here is result-identical to
driving them one at a time — just with O(bucket) fewer dispatches.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from repro import obs
from repro.core.band import BFSWork, execute_bfs_works
from repro.core.coarsen import MatchWork, execute_match_works
from repro.core.fm import FMWork, execute_fm_works


def run_works(works: Sequence[object]) -> List[object]:
    """Execute a heterogeneous batch of works; results in input order."""
    fm_idx = [i for i, w in enumerate(works) if isinstance(w, FMWork)]
    bfs_idx = [i for i, w in enumerate(works) if isinstance(w, BFSWork)]
    mt_idx = [i for i, w in enumerate(works) if isinstance(w, MatchWork)]
    assert len(fm_idx) + len(bfs_idx) + len(mt_idx) == len(works), \
        "unknown work kind"
    out: Dict[int, object] = {}
    if fm_idx:
        for i, res in zip(fm_idx,
                          execute_fm_works([works[i] for i in fm_idx])):
            out[i] = res
    if bfs_idx:
        for i, res in zip(bfs_idx,
                          execute_bfs_works([works[i] for i in bfs_idx])):
            out[i] = res
    if mt_idx:
        for i, res in zip(mt_idx,
                          execute_match_works([works[i] for i in mt_idx])):
            out[i] = res
    return [out[i] for i in range(len(works))]


def drive_tasks(generators: Sequence) -> List[object]:
    """Drive work-yielding generators in lockstep waves.

    Each round gathers the current outstanding work of every live
    generator, executes it bucketed, and resumes them.  Generators finish
    at different depths (different multilevel level counts); the wave
    simply shrinks.  Returns each generator's return value, in order.
    """
    results: Dict[int, object] = {}
    pending: Dict[int, object] = {}
    for i, gen in enumerate(generators):
        try:
            pending[i] = next(gen)
        except StopIteration as stop:
            results[i] = stop.value
    while pending:
        idxs = sorted(pending)
        with obs.span("sched:round", works=len(idxs)):
            outs = run_works([pending[i] for i in idxs])
        nxt: Dict[int, object] = {}
        for i, res in zip(idxs, outs):
            try:
                nxt[i] = generators[i].send(res)
            except StopIteration as stop:
                results[i] = stop.value
        pending = nxt
    return [results[i] for i in range(len(generators))]
