"""Compat wrappers over the router's stage table (DESIGN.md §3).

The wave execution that used to live here — split a mixed work list by
kind, hand each kind to its bucketed executor — is now one stage table
in ``service.router.execute_wave``, shared with the distributed plane.
``run_works`` and ``drive_tasks`` remain as thin adapters for callers
that hold bare host-side work lists or generators: same contract
(per-lane results independent of batch composition, so batched execution
is result-identical to one-at-a-time), same bucketed dispatch counts.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.core.band import BFSWork
from repro.core.coarsen import MatchWork
from repro.core.fm import FMWork
from repro.service.router import WaveRouter, execute_wave


def run_works(works: Sequence[object]) -> List[object]:
    """Execute a heterogeneous batch of works; results in input order."""
    assert all(isinstance(w, (FMWork, BFSWork, MatchWork))
               for w in works), "unknown work kind"
    results, _ = execute_wave(list(works))
    return results


def drive_tasks(generators: Sequence) -> List[object]:
    """Drive work-yielding generators through one shared router.

    Each wave gathers the current outstanding work of every live
    generator, executes it bucketed, and resumes them.  Generators
    finish at different depths (different multilevel level counts); the
    wave simply shrinks.  Returns each generator's return value, in
    order.
    """
    router = WaveRouter()
    for gen in generators:
        router.submit(gen)
    return router.run()
