"""Unified wave router: one shared lane stack for the whole service.

The PR 5 frontier driver lane-stacked same-bucket subgraphs of ONE
distributed ordering into single ``shard_map`` dispatches, but the
service still drained each request through its own private frontier —
concurrent requests never shared a launch, and the wave logic lived
twice (``core/dnd`` for distributed trees, ``service/batch`` for
centralized ones).  This module is the merge (DESIGN.md §5): one
**WaveRouter** owns the frontier of *all* concurrently-submitted task
trees and executes every wave through one stage table —

  * centralized work (``FMWork`` — bare or in per-phase lists —
    ``BFSWork``, ``MatchWork``) runs through the bucketed executors,
    one dispatch per ELL bucket; FM buckets key on
    ``(n_pad, d_pad, passes, pos_only)`` only — move budgets are
    per-lane data of the fused pass-loop kernel (``kernels.fm_fused``),
    so works with different ``max_moves`` stack into one launch and the
    wave summaries count correspondingly fewer, wider fm buckets;
  * distributed work (``DMatchWork`` / ``DBFSWork`` / ``DHaloWork``)
    groups by ``dgraph_bucket`` (plus rounds / width / dtype) and each
    group runs as ONE lane-stacked ``shard_map`` launch, regardless of
    how many *requests* contributed lanes.

Launches per wave are therefore bounded by live shape buckets, not by
requests.  Per-lane results are pure functions of each lane's own
inputs (the stacked collectives' bit-parity contract), so routing N
trees through shared waves is bit-identical to draining them one at a
time — asserted by ``tests/test_router.py``.

``RouterConfig`` (alpa ``global_env``-style: one plain object, grouped
options, env-var defaults) is the single surface for wave policy —
lane stacking, the bounded jit-builder cache, the matching
proposal-gather compaction, and the future mesh/device-group and
preemption knobs.  ``global_config`` is the process default; a
``WaveRouter`` applies its config's data-plane knobs on construction.

Tasks are generators yielding typed work descriptors (or ``_Spawn``
lists of subtasks) and receiving results — the same protocol
``nd.separator_task`` and every ``core/dnd`` task already speak.  The
depth-first oracle (``dnd._drive_depth_first``) is unchanged and stays
the bit-parity reference.
"""
from __future__ import annotations

import dataclasses
import os
import time
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core import dgraph as _dg
from repro.core.band import BFSWork, execute_bfs_works
from repro.core.coarsen import MatchWork, execute_match_works
from repro.core.dgraph import (dgraph_bucket, distributed_bfs_stacked,
                               distributed_matching_stacked,
                               halo_exchange_stacked)
from repro.core.dnd import DBFSWork, DHaloWork, DMatchWork, _Spawn
from repro.core.fm import FMWork, execute_fm_works
from repro.service import faults as _faults
from repro.train.fault import StragglerMonitor


# ------------------------------------------------------------------ #
# configuration (exemplar: alpa's global_env.py)
# ------------------------------------------------------------------ #
class RouterConfig:
    """Global wave-router configuration.

    One plain object with grouped options and env-var defaults, shared
    by every layer that used to carry its own knobs (``DNDConfig``'s
    driver switch, the scheduler's implicit wave policy, ``dgraph``'s
    unbounded jit caches).  Mutate ``global_config`` for process-wide
    policy, or hand a private instance to one ``WaveRouter``.
    """

    def __init__(self):
        ########## wave scheduling ##########
        # advance all live tasks until blocked, then execute one
        # bucketed lane-stacked wave (False is only meaningful through
        # the depth-first oracle, which bypasses the router entirely)
        self.frontier_waves = True
        # reserved finer-grained preemption surface: a wave executes at
        # most this many works (None = unbounded; the implemented
        # preemption granularity is whole waves via ``pump``)
        self.max_wave_works: Optional[int] = None

        ########## SLO pump / preemption ##########
        # default wave budget of one ``WaveRouter.pump`` call: how many
        # waves a pump may execute before handing control back to the
        # admission policy (the per-pump preemption budget — small
        # requests submitted mid-flight wait at most this many waves
        # before the policy can park a long ordering between waves)
        self.pump_wave_budget = int(
            os.environ.get("REPRO_PUMP_WAVES", "2"))

        ########## mesh / device groups ##########
        # device group serving distributed buckets; None = the default
        # host-local mesh built by dgraph.make_parts_mesh (a
        # jax.distributed multi-host mesh is the planned extension)
        self.mesh = None

        ########## jit-builder cache (core/dgraph) ##########
        # bounded LRU over the stacked-collective jit builders, keyed
        # (kind, bucket, lanes, ...); evictions rebill the next
        # dispatch as a compile via obs.forget_use
        self.jit_cache_capacity = int(
            os.environ.get("REPRO_JIT_CACHE_CAP", "64"))

        ########## matching proposal-gather compaction ##########
        # gather proposals capped at the true per-shard proposer bound
        # instead of the dense n_loc_max width (lossless; see
        # dgraph.distributed_matching_stacked)
        self.match_compact = os.environ.get(
            "REPRO_MATCH_COMPACT", "1") != "0"

        ########## robustness (DESIGN.md §8) ##########
        # straggler flagging: a wave slower than this factor × the
        # running wave-time EWMA is counted in ``WaveRouter.stats()``
        # and ``repro_router_straggler_waves_total`` (the router-side
        # adoption of train/fault.py's StragglerMonitor contract); the
        # factor is loose by default because compile waves legitimately
        # dwarf steady-state waves
        self.straggler_factor = float(
            os.environ.get("REPRO_STRAGGLER_FACTOR", "4.0"))

    def apply(self) -> None:
        """Push the data-plane knobs down into ``core/dgraph``.

        ``repro.core`` never imports the service layer, so the router
        applies its config through dgraph's setter surface instead of
        dgraph reading this object.
        """
        _dg.set_jit_cache_capacity(self.jit_cache_capacity)
        _dg.set_match_compact(self.match_compact)


global_config = RouterConfig()


# ------------------------------------------------------------------ #
# work typing (the router's stage table)
# ------------------------------------------------------------------ #
def work_kind(work) -> str:
    """Stage-table kind of one yielded work descriptor."""
    if isinstance(work, (list, FMWork)):
        return "fm"
    if isinstance(work, BFSWork):
        return "bfs"
    if isinstance(work, MatchWork):
        return "match"
    if isinstance(work, DMatchWork):
        return "dmatch"
    if isinstance(work, DBFSWork):
        return "dbfs"
    if isinstance(work, DHaloWork):
        return "dhalo"
    raise TypeError(f"unknown work kind: {type(work).__name__}")


# ------------------------------------------------------------------ #
# recovery ladder (DESIGN.md §8) — rungs 1–3 live at the wave level
# ------------------------------------------------------------------ #
#: the kernel-path degrade ladder (rung 2): every rung is bit-identical
#: (tests/test_fm_fused.py), so degrading trades only speed for
#: independence from the suspect code path — fused Pallas kernel →
#: hoisted per-pass XLA loop → pure-jnp oracle (kernels.ref)
_FM_MODES = ("fused", "hoisted", "oracle")


def _fm_base_level() -> int:
    """Ladder level of the process-default FM mode (REPRO_FM_MODE)."""
    from repro.kernels.ops import fm_mode_default
    mode = fm_mode_default()
    return _FM_MODES.index(mode) if mode in _FM_MODES else 0


class _WorkFailed:
    """Sentinel result of ONE work whose dispatch failed beyond the
    ladder — co-riding works of the same wave keep their real results."""
    __slots__ = ("error",)

    def __init__(self, error: BaseException):
        self.error = error


class TaskFailure:
    """Terminal result of an excised task tree: the root was removed
    from the frontier after its work failed beyond the ladder.  The
    service resolves (or cold-readmits) its riders; ``run()`` re-raises
    for non-service callers."""
    __slots__ = ("error",)

    def __init__(self, error: BaseException):
        self.error = error

    def __repr__(self):
        return f"TaskFailure({self.error!r})"


def _failure_of(result) -> Optional[BaseException]:
    """The failure carried by one wave result (list works fail if any
    of their slots failed), or None for a clean result."""
    if isinstance(result, _WorkFailed):
        return result.error
    if isinstance(result, list):
        for r in result:
            if isinstance(r, _WorkFailed):
                return r.error
    return None


class _Recovery:
    """Per-router recovery state: retry budgets (rung 1), the sticky
    per-request kernel degrade level (rung 2), and isolation counters
    (rung 3's group→singleton split).  Degrade is keyed by request tag —
    never process-global: co-riders of an un-degraded request keep the
    fast path, and ``pop_tag`` hands the per-request totals to the
    service for ``OrderResult.retries`` / ``.degraded``."""

    def __init__(self, cfg: Optional[_faults.RecoveryConfig] = None):
        self.cfg = cfg or _faults.RecoveryConfig()
        self.base_level = _fm_base_level()
        self.degrade_by_tag: Dict = {}
        self.retries_by_tag: Dict = defaultdict(int)
        self.isolations = 0

    def level_of(self, tag) -> int:
        return self.degrade_by_tag.get(tag, self.base_level)

    def note_retry(self, kind: str, tags, attempt: int) -> None:
        """Bill one transient retry and sleep its capped backoff."""
        obs.REGISTRY.inc("repro_service_retries_total", kind=kind)
        for tg in set(tags):
            if tg is not None:
                self.retries_by_tag[tg] += 1
        with obs.span("recover:retry", kind=kind, attempt=attempt):
            time.sleep(self.cfg.backoff(attempt))

    def retry_loop(self, kind: str, tags, run):
        """Rung 1: re-run transient failures with capped backoff; any
        other failure (or an exhausted budget) escalates to the caller."""
        attempt = 0
        while True:
            try:
                return run()
            except Exception as err:
                if not (_faults.is_transient(err)
                        and attempt < self.cfg.max_retries):
                    raise
                attempt += 1
                self.note_retry(kind, tags, attempt)

    def note_degrade(self, tags, level: int, err: BaseException) -> None:
        obs.REGISTRY.inc("repro_service_degraded_total",
                         mode=_FM_MODES[level])
        for tg in set(tags):
            if tg is not None:
                self.degrade_by_tag[tg] = max(self.level_of(tg), level)
        with obs.span("recover:degrade", mode=_FM_MODES[level],
                      error=type(err).__name__):
            pass

    def note_isolate(self, kind: str, tags, err: BaseException) -> None:
        self.isolations += 1
        with obs.span("recover:isolate", kind=kind,
                      error=type(err).__name__):
            pass

    def pop_tag(self, tag) -> Tuple[int, bool]:
        """(retries, degraded) accumulated for one finished request."""
        retries = int(self.retries_by_tag.pop(tag, 0))
        degraded = (self.degrade_by_tag.pop(tag, self.base_level)
                    > self.base_level)
        return retries, degraded


def _validate_fm_outs(works: Sequence[FMWork], outs) -> None:
    """Rung 4's kernel-side half: a selected FM result must be finite
    with in-range parts, else the wave treats the dispatch as failed
    (``CorruptResult``) and the ladder degrades — so NaN-corrupted
    outputs take the same recovery path as raised faults."""
    for w, (part, sep_w, imb) in zip(works, outs):
        p = np.asarray(part)
        if (not np.isfinite(sep_w) or not np.isfinite(imb)
                or (p.size and (p.min() < 0 or p.max() > 2))):
            raise _faults.CorruptResult(
                f"fm output failed validation (sep_w={sep_w!r}, "
                f"parts in [{p.min() if p.size else 0}, "
                f"{p.max() if p.size else 0}])")


def _fm_ladder(rec: _Recovery, works: Sequence[FMWork], tags,
               level: int):
    """Run one FM group with retry (rung 1) + degrade (rung 2): on a
    non-transient failure or invalid output, step the mode ladder and
    re-dispatch; raises only once the oracle rung itself fails."""
    lv = max(level, rec.base_level)
    while True:
        mode = _FM_MODES[lv]
        try:
            outs = rec.retry_loop(
                "fm", tags, lambda: execute_fm_works(works, mode=mode))
            _validate_fm_outs(works, outs)
            return outs
        except Exception as err:
            if lv + 1 >= len(_FM_MODES):
                raise
            lv += 1
            rec.note_degrade(tags, lv, err)


def execute_wave(works: List, level: Optional[int] = None,
                 tags: Optional[Sequence] = None,
                 recovery: Optional[_Recovery] = None
                 ) -> Tuple[List, dict]:
    """Execute one wave of mixed works, bucketed + lane-stacked.

    Centralized works (``FMWork`` — bare or in per-phase lists —
    ``BFSWork``, ``MatchWork``) run through the bucketed vmap
    executors; distributed works group by ``dgraph_bucket`` (plus
    rounds / width / dtype) and each group runs as ONE lane-stacked
    ``shard_map`` launch.  Per-lane results are independent of wave
    composition, so wave execution is bit-identical to singleton
    execution.

    ``tags`` (optional, aligned with ``works``) attributes each work to
    its originating request: the wave summary then carries ``requests``
    (distinct tags present) and ``shared_launches`` (bucket groups that
    received lanes from ≥ 2 requests — the cross-request sharing the
    router exists for), and each distributed launch records its lanes'
    tags (``dgraph`` launch metadata).

    Returns (results in input order, wave summary with per-kind works /
    buckets / launches plus the wave's wall-clock ``t_s`` and per-stage
    ``stage_s`` rollup).  When tracing is enabled the wave runs under a
    ``router:wave`` span whose children are the bucket dispatch spans.

    ``recovery`` (a router's ``_Recovery``, None for bare callers)
    activates the wave-level recovery ladder: transient dispatch faults
    retry with capped backoff, failing/corrupt FM groups degrade down
    the mode ladder, and a group that fails beyond the ladder is
    *isolated* — each of its works re-runs as a singleton dispatch so
    one poisoned lane cannot fail its co-riders; works that still fail
    come back as ``_WorkFailed`` results (the router excises their task
    trees) while every other result slot stays valid.
    """
    for w in works:
        work_kind(w)                    # reject unknown kinds up front
    results: List = [None] * len(works)
    summary: Dict[str, dict] = {"works": {}, "buckets": {},
                                "launches": {}}
    t_wave = time.perf_counter()
    tag_of = (lambda i: None) if tags is None else (lambda i: tags[i])
    group_tags: Dict[Tuple, set] = defaultdict(set)
    rec = recovery

    def guarded(kind: str, idxs: List[int], run_all, run_one) -> List:
        """Rungs 1+3 around one bucket-group dispatch: retry the whole
        group, then isolate per-work on terminal failure."""
        if rec is None:
            return run_all()
        tags_l = [tag_of(i) for i in idxs]
        try:
            return rec.retry_loop(kind, tags_l, run_all)
        except Exception as err:
            rec.note_isolate(kind, tags_l, err)
            outs: List = []
            for i in idxs:
                try:
                    outs.append(rec.retry_loop(
                        kind, [tag_of(i)], lambda i=i: run_one(i)))
                except Exception as e1:
                    outs.append(_WorkFailed(e1))
            return outs

    def guarded_fm(items: List[Tuple[int, Optional[int], FMWork]]
                   ) -> List:
        """FM groups additionally split by each request's sticky
        degrade level and run through the mode ladder (rung 2)."""
        if rec is None:
            return execute_fm_works([w for _, _, w in items])
        by_level: Dict[int, List[int]] = defaultdict(list)
        for pos, (i, _, _w) in enumerate(items):
            by_level[rec.level_of(tag_of(i))].append(pos)
        outs: List = [None] * len(items)
        for level in sorted(by_level):
            poss = by_level[level]
            g_works = [items[p][2] for p in poss]
            g_tags = [tag_of(items[p][0]) for p in poss]
            try:
                g_outs = _fm_ladder(rec, g_works, g_tags, level)
            except Exception as err:
                rec.note_isolate("fm", g_tags, err)
                g_outs = []
                for p in poss:
                    i, _, w = items[p]
                    try:
                        g_outs.append(_fm_ladder(
                            rec, [w], [tag_of(i)],
                            rec.level_of(tag_of(i)))[0])
                    except Exception as e1:
                        g_outs.append(_WorkFailed(e1))
            for p, r in zip(poss, g_outs):
                outs[p] = r
        return outs

    def note(kind: str, n_works: int, n_buckets: int) -> None:
        summary["works"][kind] = summary["works"].get(kind, 0) + n_works
        summary["buckets"][kind] = (summary["buckets"].get(kind, 0)
                                    + n_buckets)

    # --- centralized device plane: flatten FM lists, bucket by kind
    fm_items: List[Tuple[int, Optional[int], FMWork]] = []
    bfs_items: List[Tuple[int, BFSWork]] = []
    mt_items: List[Tuple[int, MatchWork]] = []
    for i, w in enumerate(works):
        if isinstance(w, list):
            assert all(isinstance(s, FMWork) for s in w)
            results[i] = [None] * len(w)
            fm_items.extend((i, j, s) for j, s in enumerate(w))
        elif isinstance(w, FMWork):
            fm_items.append((i, None, w))
        elif isinstance(w, BFSWork):
            bfs_items.append((i, w))
        elif isinstance(w, MatchWork):
            mt_items.append((i, w))

    # the wave's launch counts are *measured*: every executor below
    # notes its real dispatches into the active instrument blocks, and
    # this nested block captures exactly this wave's records — so the
    # launches == buckets budget assertions compare against what
    # actually ran, not against the wave's own bookkeeping
    n_requests = (len({tags[i] for i in range(len(works))})
                  if tags is not None and works else 1)
    with _dg.instrument() as wave_ins, \
            obs.span("router:wave", level=level, works=len(works),
                     requests=n_requests):
        if fm_items:
            outs = guarded_fm(fm_items)
            for (i, j, _), r in zip(fm_items, outs):
                if j is None:
                    results[i] = r
                else:
                    results[i][j] = r
            note("fm", len(fm_items),
                 len({w.bucket_key() for _, _, w in fm_items}))
            for i, _, w in fm_items:
                group_tags[("fm", w.bucket_key())].add(tag_of(i))
        if bfs_items:
            outs = guarded(
                "bfs", [i for i, _ in bfs_items],
                lambda: execute_bfs_works([w for _, w in bfs_items]),
                lambda i: execute_bfs_works([works[i]])[0])
            for (i, _), r in zip(bfs_items, outs):
                results[i] = r
            note("bfs", len(bfs_items),
                 len({w.bucket_key() for _, w in bfs_items}))
            for i, w in bfs_items:
                group_tags[("bfs", w.bucket_key())].add(tag_of(i))
        if mt_items:
            outs = guarded(
                "match", [i for i, _ in mt_items],
                lambda: execute_match_works([w for _, w in mt_items]),
                lambda i: execute_match_works([works[i]])[0])
            for (i, _), r in zip(mt_items, outs):
                results[i] = r
            note("match", len(mt_items),
                 len({w.bucket_key() for _, w in mt_items}))
            for i, w in mt_items:
                group_tags[("match", w.bucket_key())].add(tag_of(i))

        # --- distributed data plane: lane-stack per bucket, ONE launch
        groups: Dict[Tuple, List[int]] = defaultdict(list)
        for i, w in enumerate(works):
            if isinstance(w, DMatchWork):
                groups[("dmatch", dgraph_bucket(w.dg), w.rounds)].append(i)
            elif isinstance(w, DBFSWork):
                groups[("dbfs", dgraph_bucket(w.dg), w.width)].append(i)
            elif isinstance(w, DHaloWork):
                groups[("dhalo", dgraph_bucket(w.dg),
                        str(np.asarray(w.x).dtype))].append(i)
        counts: Dict[str, List[int]] = defaultdict(list)
        for key, idxs in groups.items():
            kind = key[0]
            counts[kind].append(len(idxs))

            def launch(sub: List[int], kind=kind, key=key) -> List:
                lane_tags = (None if tags is None
                             else [tags[i] for i in sub])
                if kind == "dmatch":
                    return distributed_matching_stacked(
                        [works[i].dg for i in sub],
                        [works[i].seed for i in sub], key[2],
                        tags=lane_tags)
                if kind == "dbfs":
                    return distributed_bfs_stacked(
                        [works[i].dg for i in sub],
                        [works[i].src for i in sub], key[2],
                        tags=lane_tags)
                return halo_exchange_stacked(
                    [works[i].dg for i in sub],
                    [works[i].x for i in sub], tags=lane_tags)

            outs = guarded(kind, idxs,
                           lambda idxs=idxs: launch(idxs),
                           lambda i: launch([i])[0])
            for i, r in zip(idxs, outs):
                results[i] = r
            group_tags[key].update(tag_of(i) for i in idxs)
        for kind, ns in counts.items():
            note(kind, sum(ns), len(ns))
    for rec in wave_ins.launches:
        summary["launches"][rec["kind"]] = \
            summary["launches"].get(rec["kind"], 0) + 1
    # per-wave rollups: the wave's wall-clock, its per-stage share, and
    # the cross-request attribution (BENCH_dnd.json aggregates these
    # into ``waves`` alongside the existing launch budgets)
    summary["t_s"] = time.perf_counter() - t_wave
    summary["stage_s"] = {k: round(v, 6)
                          for k, v in wave_ins.stage_s.items()}
    summary["requests"] = n_requests
    summary["shared_launches"] = sum(
        1 for s in group_tags.values() if len(s) >= 2)
    return results, summary


# ------------------------------------------------------------------ #
# the router: shared frontier over many task trees
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class _Task:
    """Frontier bookkeeping of one live generator."""
    gen: object
    parent: Optional["_Task"]
    slot: int
    tag: object = None              # originating request (inherited)
    reported: bool = False          # root surfaced by pop_completed()
    started: bool = False
    n_pending: int = 0
    child_results: List = dataclasses.field(default_factory=list)
    done: bool = False
    result: object = None


def _advance(task: _Task, value, blocked: List[Tuple[_Task, object]]
             ) -> None:
    """Run a task until it blocks on device work, spawns, or finishes.

    Finishing delivers the return value to the parent's result slot;
    the parent resumes (recursively) once its last child finishes.
    Spawned subtasks inherit the task's request tag.
    """
    while True:
        try:
            if task.started:
                item = task.gen.send(value)
            else:
                task.started = True
                item = next(task.gen)
        except StopIteration as stop:
            task.result, task.done = stop.value, True
            parent = task.parent
            if parent is not None:
                parent.child_results[task.slot] = stop.value
                parent.n_pending -= 1
                if parent.n_pending == 0:
                    _advance(parent, list(parent.child_results), blocked)
            return
        if isinstance(item, _Spawn):
            if not item.tasks:
                value = []
                continue
            task.n_pending = len(item.tasks)
            task.child_results = [None] * len(item.tasks)
            for k, sub in enumerate(item.tasks):
                _advance(_Task(sub, task, k, tag=task.tag), None, blocked)
            return
        blocked.append((task, item))
        return


def _root_of(task: _Task) -> _Task:
    while task.parent is not None:
        task = task.parent
    return task


class WaveRouter:
    """Shared frontier driver over any number of submitted task trees.

    ``submit`` registers a task-tree generator under a request tag and
    advances it until it blocks; ``run`` then walks ALL submitted trees
    in readiness waves — every wave gathers the outstanding works of
    every live task (siblings at any depth, fold-dup duplicates,
    different *requests*) and executes them through ``execute_wave``,
    so same-bucket lanes share launches across request boundaries.
    Wave summaries are recorded into the active ``dgraph.instrument()``
    blocks as ``waves`` (where BENCH_dnd.json's ``launches_by_level``
    and the launch-budget tests read them).

    Per-lane results are independent of wave composition, so the
    results are bit-identical to driving each tree alone (or
    depth-first).  ``submit`` after a ``run`` is allowed: the router is
    reusable drain-to-drain.

    **Preemption surface** (the SLO control plane, DESIGN.md §7):
    ``pump(max_waves, select)`` advances the frontier by a *bounded*
    number of waves, and each wave executes only the outstanding works
    of the *selected* request tags — everything else stays **parked**:
    the suspended generators keep their host state and their yielded
    work descriptors verbatim, so a later pump resumes them
    bit-identically (parking changes only wave composition, which the
    lane-purity contract makes result-invariant).  New submits between
    pumps simply join the frontier, which is what lets a small request
    preempt a long ordering *between* waves.  ``run()`` is the
    unbounded, select-everything special case.

    Per-request execution attribution: every executed wave's wall clock
    is split across the request tags that contributed works to it,
    proportional to their work counts, and accumulated into
    ``exec_s_by_tag`` — the service bills each request its own share of
    the waves it actually rode, not the whole drain's wall.
    """

    def __init__(self, cfg: Optional[RouterConfig] = None,
                 recovery_cfg: Optional[_faults.RecoveryConfig] = None):
        self.cfg = cfg or global_config
        self.cfg.apply()
        self._roots: List[_Task] = []
        self._blocked: List[Tuple[_Task, object]] = []
        self._level = 0
        self.exec_s_by_tag: Dict = defaultdict(float)
        self.recovery = _Recovery(recovery_cfg)
        self._stragglers = StragglerMonitor(
            factor=self.cfg.straggler_factor)
        self._waves = 0

    def submit(self, gen, tag=None) -> int:
        """Register one task tree; returns its index into ``run()``."""
        idx = len(self._roots)
        task = _Task(gen, None, 0, tag=idx if tag is None else tag)
        self._roots.append(task)
        _advance(task, None, self._blocked)
        return idx

    # -------------------------------------------------------------- #
    def pump(self, max_waves: Optional[int] = None,
             select=None) -> int:
        """Advance the frontier by at most ``max_waves`` waves.

        ``select`` (a container of tags, or None for all) gates which
        blocked works may execute: works of unselected tags stay parked
        — their generators are not resumed and their lane state is
        untouched until a later pump selects them.  Returns the number
        of waves executed (0 when nothing selected is blocked, so a
        pump loop can detect quiescence).
        """
        waves = 0
        wave_retries = 0
        while self._blocked and (max_waves is None or waves < max_waves):
            if select is None:
                active, parked = self._blocked, []
            else:
                active = [e for e in self._blocked if e[0].tag in select]
                parked = [e for e in self._blocked
                          if e[0].tag not in select]
            if not active:
                break
            self._blocked = []
            tags = [t.tag for t, _ in active]
            t0 = time.perf_counter()
            try:
                inj = _faults.active()
                if inj is not None:
                    inj.check("wave", tags=tags)
                results, summary = execute_wave(
                    [w for _, w in active], level=self._level, tags=tags,
                    recovery=self.recovery)
            except BaseException as err:
                # exception-safe unwind: active and parked entries go
                # back on the frontier *before* anything propagates, so
                # the suspended generators stay resumable and the next
                # drain does not trip the live-tasks assertion
                self._blocked = active + parked
                if (_faults.is_transient(err) and wave_retries
                        < self.recovery.cfg.max_retries):
                    wave_retries += 1
                    self.recovery.note_retry("wave", tags, wave_retries)
                    continue
                raise
            wave_retries = 0
            if self._stragglers.observe(time.perf_counter() - t0):
                obs.REGISTRY.inc("repro_router_straggler_waves_total")
                summary["straggler"] = True
            summary["level"] = self._level
            summary["parked"] = len(parked)
            _dg._note_wave(summary)
            # proportional wall attribution: each tag's share of this
            # wave is its fraction of the executed works
            share = summary["t_s"] / len(tags)
            for tag in tags:
                self.exec_s_by_tag[tag] += share
            dead: set = set()
            for (t, _), r in zip(active, results):
                root = _root_of(t)
                if id(root) in dead:
                    continue            # tree already excised this wave
                err = _failure_of(r)
                if err is None:
                    try:
                        _advance(t, r, self._blocked)
                        continue
                    except Exception as adv_err:
                        # a generator choking on its (possibly faulted)
                        # result fails only its own tree
                        err = adv_err
                dead.add(id(root))
                self._excise(root, err)
            self._blocked.extend(parked)
            self._waves += 1
            self._level += 1
            waves += 1
        return waves

    def _excise(self, root: _Task, error: BaseException) -> None:
        """Rung 3: terminally fail ONE task tree mid-drain.

        The root completes with a ``TaskFailure`` result and every
        blocked entry of its tree leaves the frontier — co-riding
        requests keep their lanes and their pending works untouched.
        The service decides what a ``TaskFailure`` means (cold
        re-admission or ``status=failed`` fan-out).
        """
        root.done = True
        root.result = TaskFailure(error)
        self._blocked = [(t, w) for (t, w) in self._blocked
                         if _root_of(t) is not root]
        with obs.span("recover:excise", tag=str(root.tag),
                      error=type(error).__name__):
            pass

    def stats(self) -> dict:
        """Wave-level robustness counters (service ``stats()`` surfaces
        these as ``router``)."""
        return {"waves": self._waves,
                "straggler_waves": self._stragglers.flagged,
                "wave_ewma_s": float(self._stragglers.ewma or 0.0),
                "isolations": self.recovery.isolations}

    def live_tags(self) -> List:
        """Tags of submitted roots that have not finished yet."""
        return [t.tag for t in self._roots if not t.done]

    def pop_completed(self) -> List[Tuple[object, object]]:
        """(tag, result) of roots completed since the last call.

        Each root reports exactly once, in submission order — the
        service maps tags back to in-flight requests and resolves them.
        """
        out = []
        for t in self._roots:
            if t.done and not t.reported:
                t.reported = True
                out.append((t.tag, t.result))
        return out

    def run(self) -> List:
        """Drive all submitted trees to completion; results in order.

        A tree excised by the recovery ladder re-raises its failure
        here — bare callers (``drive_frontier``, the dnd entry points)
        see the real error; only the service, which drains through
        ``pump``/``pop_completed``, handles ``TaskFailure`` results.
        """
        self.pump()
        assert all(t.done for t in self._roots), \
            "router finished with live tasks"
        for t in self._roots:
            if isinstance(t.result, TaskFailure):
                raise t.result.error
        return [t.result for t in self._roots]


def drive_frontier(root_gen, cfg: Optional[RouterConfig] = None):
    """Drive ONE task tree through a private router (compat surface for
    ``dnd``'s single-ordering entry points and the frontier tests)."""
    router = WaveRouter(cfg)
    router.submit(root_gen)
    return router.run()[0]
