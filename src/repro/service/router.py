"""Unified wave router: one shared lane stack for the whole service.

The PR 5 frontier driver lane-stacked same-bucket subgraphs of ONE
distributed ordering into single ``shard_map`` dispatches, but the
service still drained each request through its own private frontier —
concurrent requests never shared a launch, and the wave logic lived
twice (``core/dnd`` for distributed trees, ``service/batch`` for
centralized ones).  This module is the merge (DESIGN.md §5): one
**WaveRouter** owns the frontier of *all* concurrently-submitted task
trees and executes every wave through one stage table —

  * centralized work (``FMWork`` — bare or in per-phase lists —
    ``BFSWork``, ``MatchWork``) runs through the bucketed executors,
    one dispatch per ELL bucket; FM buckets key on
    ``(n_pad, d_pad, passes, pos_only)`` only — move budgets are
    per-lane data of the fused pass-loop kernel (``kernels.fm_fused``),
    so works with different ``max_moves`` stack into one launch and the
    wave summaries count correspondingly fewer, wider fm buckets;
  * distributed work (``DMatchWork`` / ``DBFSWork`` / ``DHaloWork``)
    groups by ``dgraph_bucket`` (plus rounds / width / dtype) and each
    group runs as ONE lane-stacked ``shard_map`` launch, regardless of
    how many *requests* contributed lanes.

Launches per wave are therefore bounded by live shape buckets, not by
requests.  Per-lane results are pure functions of each lane's own
inputs (the stacked collectives' bit-parity contract), so routing N
trees through shared waves is bit-identical to draining them one at a
time — asserted by ``tests/test_router.py``.

``RouterConfig`` (alpa ``global_env``-style: one plain object, grouped
options, env-var defaults) is the single surface for wave policy —
lane stacking, the bounded jit-builder cache, the matching
proposal-gather compaction, and the future mesh/device-group and
preemption knobs.  ``global_config`` is the process default; a
``WaveRouter`` applies its config's data-plane knobs on construction.

Tasks are generators yielding typed work descriptors (or ``_Spawn``
lists of subtasks) and receiving results — the same protocol
``nd.separator_task`` and every ``core/dnd`` task already speak.  The
depth-first oracle (``dnd._drive_depth_first``) is unchanged and stays
the bit-parity reference.
"""
from __future__ import annotations

import dataclasses
import os
import time
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core import dgraph as _dg
from repro.core.band import BFSWork, execute_bfs_works
from repro.core.coarsen import MatchWork, execute_match_works
from repro.core.dgraph import (dgraph_bucket, distributed_bfs_stacked,
                               distributed_matching_stacked,
                               halo_exchange_stacked)
from repro.core.dnd import DBFSWork, DHaloWork, DMatchWork, _Spawn
from repro.core.fm import FMWork, execute_fm_works


# ------------------------------------------------------------------ #
# configuration (exemplar: alpa's global_env.py)
# ------------------------------------------------------------------ #
class RouterConfig:
    """Global wave-router configuration.

    One plain object with grouped options and env-var defaults, shared
    by every layer that used to carry its own knobs (``DNDConfig``'s
    driver switch, the scheduler's implicit wave policy, ``dgraph``'s
    unbounded jit caches).  Mutate ``global_config`` for process-wide
    policy, or hand a private instance to one ``WaveRouter``.
    """

    def __init__(self):
        ########## wave scheduling ##########
        # advance all live tasks until blocked, then execute one
        # bucketed lane-stacked wave (False is only meaningful through
        # the depth-first oracle, which bypasses the router entirely)
        self.frontier_waves = True
        # reserved finer-grained preemption surface: a wave executes at
        # most this many works (None = unbounded; the implemented
        # preemption granularity is whole waves via ``pump``)
        self.max_wave_works: Optional[int] = None

        ########## SLO pump / preemption ##########
        # default wave budget of one ``WaveRouter.pump`` call: how many
        # waves a pump may execute before handing control back to the
        # admission policy (the per-pump preemption budget — small
        # requests submitted mid-flight wait at most this many waves
        # before the policy can park a long ordering between waves)
        self.pump_wave_budget = int(
            os.environ.get("REPRO_PUMP_WAVES", "2"))

        ########## mesh / device groups ##########
        # device group serving distributed buckets; None = the default
        # host-local mesh built by dgraph.make_parts_mesh (a
        # jax.distributed multi-host mesh is the planned extension)
        self.mesh = None

        ########## jit-builder cache (core/dgraph) ##########
        # bounded LRU over the stacked-collective jit builders, keyed
        # (kind, bucket, lanes, ...); evictions rebill the next
        # dispatch as a compile via obs.forget_use
        self.jit_cache_capacity = int(
            os.environ.get("REPRO_JIT_CACHE_CAP", "64"))

        ########## matching proposal-gather compaction ##########
        # gather proposals capped at the true per-shard proposer bound
        # instead of the dense n_loc_max width (lossless; see
        # dgraph.distributed_matching_stacked)
        self.match_compact = os.environ.get(
            "REPRO_MATCH_COMPACT", "1") != "0"

    def apply(self) -> None:
        """Push the data-plane knobs down into ``core/dgraph``.

        ``repro.core`` never imports the service layer, so the router
        applies its config through dgraph's setter surface instead of
        dgraph reading this object.
        """
        _dg.set_jit_cache_capacity(self.jit_cache_capacity)
        _dg.set_match_compact(self.match_compact)


global_config = RouterConfig()


# ------------------------------------------------------------------ #
# work typing (the router's stage table)
# ------------------------------------------------------------------ #
def work_kind(work) -> str:
    """Stage-table kind of one yielded work descriptor."""
    if isinstance(work, (list, FMWork)):
        return "fm"
    if isinstance(work, BFSWork):
        return "bfs"
    if isinstance(work, MatchWork):
        return "match"
    if isinstance(work, DMatchWork):
        return "dmatch"
    if isinstance(work, DBFSWork):
        return "dbfs"
    if isinstance(work, DHaloWork):
        return "dhalo"
    raise TypeError(f"unknown work kind: {type(work).__name__}")


def execute_wave(works: List, level: Optional[int] = None,
                 tags: Optional[Sequence] = None) -> Tuple[List, dict]:
    """Execute one wave of mixed works, bucketed + lane-stacked.

    Centralized works (``FMWork`` — bare or in per-phase lists —
    ``BFSWork``, ``MatchWork``) run through the bucketed vmap
    executors; distributed works group by ``dgraph_bucket`` (plus
    rounds / width / dtype) and each group runs as ONE lane-stacked
    ``shard_map`` launch.  Per-lane results are independent of wave
    composition, so wave execution is bit-identical to singleton
    execution.

    ``tags`` (optional, aligned with ``works``) attributes each work to
    its originating request: the wave summary then carries ``requests``
    (distinct tags present) and ``shared_launches`` (bucket groups that
    received lanes from ≥ 2 requests — the cross-request sharing the
    router exists for), and each distributed launch records its lanes'
    tags (``dgraph`` launch metadata).

    Returns (results in input order, wave summary with per-kind works /
    buckets / launches plus the wave's wall-clock ``t_s`` and per-stage
    ``stage_s`` rollup).  When tracing is enabled the wave runs under a
    ``router:wave`` span whose children are the bucket dispatch spans.
    """
    for w in works:
        work_kind(w)                    # reject unknown kinds up front
    results: List = [None] * len(works)
    summary: Dict[str, dict] = {"works": {}, "buckets": {},
                                "launches": {}}
    t_wave = time.perf_counter()
    tag_of = (lambda i: None) if tags is None else (lambda i: tags[i])
    group_tags: Dict[Tuple, set] = defaultdict(set)

    def note(kind: str, n_works: int, n_buckets: int) -> None:
        summary["works"][kind] = summary["works"].get(kind, 0) + n_works
        summary["buckets"][kind] = (summary["buckets"].get(kind, 0)
                                    + n_buckets)

    # --- centralized device plane: flatten FM lists, bucket by kind
    fm_items: List[Tuple[int, Optional[int], FMWork]] = []
    bfs_items: List[Tuple[int, BFSWork]] = []
    mt_items: List[Tuple[int, MatchWork]] = []
    for i, w in enumerate(works):
        if isinstance(w, list):
            assert all(isinstance(s, FMWork) for s in w)
            results[i] = [None] * len(w)
            fm_items.extend((i, j, s) for j, s in enumerate(w))
        elif isinstance(w, FMWork):
            fm_items.append((i, None, w))
        elif isinstance(w, BFSWork):
            bfs_items.append((i, w))
        elif isinstance(w, MatchWork):
            mt_items.append((i, w))

    # the wave's launch counts are *measured*: every executor below
    # notes its real dispatches into the active instrument blocks, and
    # this nested block captures exactly this wave's records — so the
    # launches == buckets budget assertions compare against what
    # actually ran, not against the wave's own bookkeeping
    n_requests = (len({tags[i] for i in range(len(works))})
                  if tags is not None and works else 1)
    with _dg.instrument() as wave_ins, \
            obs.span("router:wave", level=level, works=len(works),
                     requests=n_requests):
        if fm_items:
            outs = execute_fm_works([w for _, _, w in fm_items])
            for (i, j, _), r in zip(fm_items, outs):
                if j is None:
                    results[i] = r
                else:
                    results[i][j] = r
            note("fm", len(fm_items),
                 len({w.bucket_key() for _, _, w in fm_items}))
            for i, _, w in fm_items:
                group_tags[("fm", w.bucket_key())].add(tag_of(i))
        if bfs_items:
            outs = execute_bfs_works([w for _, w in bfs_items])
            for (i, _), r in zip(bfs_items, outs):
                results[i] = r
            note("bfs", len(bfs_items),
                 len({w.bucket_key() for _, w in bfs_items}))
            for i, w in bfs_items:
                group_tags[("bfs", w.bucket_key())].add(tag_of(i))
        if mt_items:
            outs = execute_match_works([w for _, w in mt_items])
            for (i, _), r in zip(mt_items, outs):
                results[i] = r
            note("match", len(mt_items),
                 len({w.bucket_key() for _, w in mt_items}))
            for i, w in mt_items:
                group_tags[("match", w.bucket_key())].add(tag_of(i))

        # --- distributed data plane: lane-stack per bucket, ONE launch
        groups: Dict[Tuple, List[int]] = defaultdict(list)
        for i, w in enumerate(works):
            if isinstance(w, DMatchWork):
                groups[("dmatch", dgraph_bucket(w.dg), w.rounds)].append(i)
            elif isinstance(w, DBFSWork):
                groups[("dbfs", dgraph_bucket(w.dg), w.width)].append(i)
            elif isinstance(w, DHaloWork):
                groups[("dhalo", dgraph_bucket(w.dg),
                        str(np.asarray(w.x).dtype))].append(i)
        counts: Dict[str, List[int]] = defaultdict(list)
        for key, idxs in groups.items():
            kind = key[0]
            counts[kind].append(len(idxs))
            lane_tags = (None if tags is None
                         else [tags[i] for i in idxs])
            if kind == "dmatch":
                outs = distributed_matching_stacked(
                    [works[i].dg for i in idxs],
                    [works[i].seed for i in idxs], key[2],
                    tags=lane_tags)
            elif kind == "dbfs":
                outs = distributed_bfs_stacked(
                    [works[i].dg for i in idxs],
                    [works[i].src for i in idxs], key[2],
                    tags=lane_tags)
            else:
                outs = halo_exchange_stacked(
                    [works[i].dg for i in idxs],
                    [works[i].x for i in idxs], tags=lane_tags)
            for i, r in zip(idxs, outs):
                results[i] = r
            group_tags[key].update(tag_of(i) for i in idxs)
        for kind, ns in counts.items():
            note(kind, sum(ns), len(ns))
    for rec in wave_ins.launches:
        summary["launches"][rec["kind"]] = \
            summary["launches"].get(rec["kind"], 0) + 1
    # per-wave rollups: the wave's wall-clock, its per-stage share, and
    # the cross-request attribution (BENCH_dnd.json aggregates these
    # into ``waves`` alongside the existing launch budgets)
    summary["t_s"] = time.perf_counter() - t_wave
    summary["stage_s"] = {k: round(v, 6)
                          for k, v in wave_ins.stage_s.items()}
    summary["requests"] = n_requests
    summary["shared_launches"] = sum(
        1 for s in group_tags.values() if len(s) >= 2)
    return results, summary


# ------------------------------------------------------------------ #
# the router: shared frontier over many task trees
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class _Task:
    """Frontier bookkeeping of one live generator."""
    gen: object
    parent: Optional["_Task"]
    slot: int
    tag: object = None              # originating request (inherited)
    reported: bool = False          # root surfaced by pop_completed()
    started: bool = False
    n_pending: int = 0
    child_results: List = dataclasses.field(default_factory=list)
    done: bool = False
    result: object = None


def _advance(task: _Task, value, blocked: List[Tuple[_Task, object]]
             ) -> None:
    """Run a task until it blocks on device work, spawns, or finishes.

    Finishing delivers the return value to the parent's result slot;
    the parent resumes (recursively) once its last child finishes.
    Spawned subtasks inherit the task's request tag.
    """
    while True:
        try:
            if task.started:
                item = task.gen.send(value)
            else:
                task.started = True
                item = next(task.gen)
        except StopIteration as stop:
            task.result, task.done = stop.value, True
            parent = task.parent
            if parent is not None:
                parent.child_results[task.slot] = stop.value
                parent.n_pending -= 1
                if parent.n_pending == 0:
                    _advance(parent, list(parent.child_results), blocked)
            return
        if isinstance(item, _Spawn):
            if not item.tasks:
                value = []
                continue
            task.n_pending = len(item.tasks)
            task.child_results = [None] * len(item.tasks)
            for k, sub in enumerate(item.tasks):
                _advance(_Task(sub, task, k, tag=task.tag), None, blocked)
            return
        blocked.append((task, item))
        return


class WaveRouter:
    """Shared frontier driver over any number of submitted task trees.

    ``submit`` registers a task-tree generator under a request tag and
    advances it until it blocks; ``run`` then walks ALL submitted trees
    in readiness waves — every wave gathers the outstanding works of
    every live task (siblings at any depth, fold-dup duplicates,
    different *requests*) and executes them through ``execute_wave``,
    so same-bucket lanes share launches across request boundaries.
    Wave summaries are recorded into the active ``dgraph.instrument()``
    blocks as ``waves`` (where BENCH_dnd.json's ``launches_by_level``
    and the launch-budget tests read them).

    Per-lane results are independent of wave composition, so the
    results are bit-identical to driving each tree alone (or
    depth-first).  ``submit`` after a ``run`` is allowed: the router is
    reusable drain-to-drain.

    **Preemption surface** (the SLO control plane, DESIGN.md §7):
    ``pump(max_waves, select)`` advances the frontier by a *bounded*
    number of waves, and each wave executes only the outstanding works
    of the *selected* request tags — everything else stays **parked**:
    the suspended generators keep their host state and their yielded
    work descriptors verbatim, so a later pump resumes them
    bit-identically (parking changes only wave composition, which the
    lane-purity contract makes result-invariant).  New submits between
    pumps simply join the frontier, which is what lets a small request
    preempt a long ordering *between* waves.  ``run()`` is the
    unbounded, select-everything special case.

    Per-request execution attribution: every executed wave's wall clock
    is split across the request tags that contributed works to it,
    proportional to their work counts, and accumulated into
    ``exec_s_by_tag`` — the service bills each request its own share of
    the waves it actually rode, not the whole drain's wall.
    """

    def __init__(self, cfg: Optional[RouterConfig] = None):
        self.cfg = cfg or global_config
        self.cfg.apply()
        self._roots: List[_Task] = []
        self._blocked: List[Tuple[_Task, object]] = []
        self._level = 0
        self.exec_s_by_tag: Dict = defaultdict(float)

    def submit(self, gen, tag=None) -> int:
        """Register one task tree; returns its index into ``run()``."""
        idx = len(self._roots)
        task = _Task(gen, None, 0, tag=idx if tag is None else tag)
        self._roots.append(task)
        _advance(task, None, self._blocked)
        return idx

    # -------------------------------------------------------------- #
    def pump(self, max_waves: Optional[int] = None,
             select=None) -> int:
        """Advance the frontier by at most ``max_waves`` waves.

        ``select`` (a container of tags, or None for all) gates which
        blocked works may execute: works of unselected tags stay parked
        — their generators are not resumed and their lane state is
        untouched until a later pump selects them.  Returns the number
        of waves executed (0 when nothing selected is blocked, so a
        pump loop can detect quiescence).
        """
        waves = 0
        while self._blocked and (max_waves is None or waves < max_waves):
            if select is None:
                active, parked = self._blocked, []
            else:
                active = [e for e in self._blocked if e[0].tag in select]
                parked = [e for e in self._blocked
                          if e[0].tag not in select]
            if not active:
                break
            self._blocked = []
            tags = [t.tag for t, _ in active]
            results, summary = execute_wave(
                [w for _, w in active], level=self._level, tags=tags)
            summary["level"] = self._level
            summary["parked"] = len(parked)
            _dg._note_wave(summary)
            # proportional wall attribution: each tag's share of this
            # wave is its fraction of the executed works
            share = summary["t_s"] / len(tags)
            for tag in tags:
                self.exec_s_by_tag[tag] += share
            for (t, _), r in zip(active, results):
                _advance(t, r, self._blocked)
            self._blocked.extend(parked)
            self._level += 1
            waves += 1
        return waves

    def live_tags(self) -> List:
        """Tags of submitted roots that have not finished yet."""
        return [t.tag for t in self._roots if not t.done]

    def pop_completed(self) -> List[Tuple[object, object]]:
        """(tag, result) of roots completed since the last call.

        Each root reports exactly once, in submission order — the
        service maps tags back to in-flight requests and resolves them.
        """
        out = []
        for t in self._roots:
            if t.done and not t.reported:
                t.reported = True
                out.append((t.tag, t.result))
        return out

    def run(self) -> List:
        """Drive all submitted trees to completion; results in order."""
        self.pump()
        assert all(t.done for t in self._roots), \
            "router finished with live tasks"
        return [t.result for t in self._roots]


def drive_frontier(root_gen, cfg: Optional[RouterConfig] = None):
    """Drive ONE task tree through a private router (compat surface for
    ``dnd``'s single-ordering entry points and the frontier tests)."""
    router = WaveRouter(cfg)
    router.submit(root_gen)
    return router.run()[0]
