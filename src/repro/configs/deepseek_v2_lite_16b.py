"""deepseek-v2-lite-16b — MoE with MLA (kv_lora=512), 64 routed experts
top-6 + 2 shared, first layer dense.  [arXiv:2405.04434; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=10944, vocab=102400, head_dim=128,
    moe=True, n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
    first_dense=1, mla=True, kv_lora=512, rope_head_dim=64,
    source="arXiv:2405.04434; hf",
)
