"""whisper-small — encoder–decoder; conv/audio frontend is a STUB
(input_specs provides precomputed frame embeddings).  [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865, head_dim=64,
    enc_dec=True, n_enc_layers=12, enc_len=1500, frontend="frames",
    source="arXiv:2212.04356; unverified",
)
