"""Architecture config system: one dataclass, one registry.

Each assigned architecture gets its own ``src/repro/configs/<id>.py`` holding
the exact published config; ``reduced()`` derives the CPU-smoke variant of the
same family (small widths/layers/experts, tiny vocab).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                       # per-expert hidden dim
    moe_every: int = 1                      # MoE layer stride
    first_dense: int = 0                    # leading dense layers
    dense_residual: bool = False            # arctic: dense MLP ∥ MoE
    capacity_factor: float = 1.25
    # --- MLA (DeepSeek-V2) ---
    mla: bool = False
    kv_lora: int = 0
    rope_head_dim: int = 64
    # --- SSM (Mamba-2 SSD) ---
    ssm: bool = False
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    attn_every: int = 1                     # 1 = every layer, 8 = jamba, 0 = never
    # --- encoder/decoder (whisper) ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_len: int = 1500                     # frame embeddings (frontend stub)
    # --- modality frontend stubs ---
    frontend: str = "none"                  # none | frames | patches
    n_patches: int = 256
    # --- misc ---
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixer kind: 'attn' or 'ssm'."""
        if self.attn_every == 0:
            return tuple("ssm" for _ in range(self.n_layers))
        if self.attn_every == 1:
            return tuple("attn" for _ in range(self.n_layers))
        return tuple("attn" if i % self.attn_every == 0 else "ssm"
                     for i in range(self.n_layers))

    def layer_ffn(self) -> Tuple[str, ...]:
        """Per-layer FFN kind: 'dense' or 'moe'."""
        out = []
        for i in range(self.n_layers):
            if self.moe and i >= self.first_dense and \
                    (i - self.first_dense) % self.moe_every == 0:
                out.append("moe")
            else:
                out.append("dense")
        return tuple(out)

    def param_count(self) -> int:
        """Approximate total parameters (embeddings included)."""
        d, hd = self.d_model, self.hd
        total = self.vocab * d * 2              # embed + unembed (untied)
        kinds, ffns = self.layer_kinds(), self.layer_ffn()
        for kind, ffn in zip(kinds, ffns):
            if kind == "attn":
                if self.mla:
                    total += d * (self.n_heads * (hd + self.rope_head_dim))
                    total += d * (self.kv_lora + self.rope_head_dim)
                    total += self.kv_lora * self.n_heads * hd * 2
                    total += self.n_heads * hd * d
                else:
                    total += d * self.n_heads * hd          # q
                    total += 2 * d * self.n_kv_heads * hd   # k, v
                    total += self.n_heads * hd * d          # o
            else:
                inner = self.ssm_expand * d
                nheads = inner // self.ssm_headdim
                total += d * (2 * inner + 2 * self.ssm_state + nheads)
                total += inner * d
            if ffn == "moe":
                total += d * self.n_experts                  # router
                total += 3 * d * self.moe_d_ff * self.n_experts
                total += 3 * d * self.moe_d_ff * self.n_shared_experts
                if self.dense_residual:
                    total += 3 * d * self.d_ff
            else:
                total += 3 * d * self.d_ff
            total += 2 * d                                   # norms
        if self.enc_dec:
            enc = self.n_enc_layers * (4 * d * d + 3 * d * self.d_ff)
            total += enc + self.n_layers * 4 * d * d         # cross-attn
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        n_moe = sum(1 for f in self.layer_ffn() if f == "moe")
        unused = n_moe * 3 * d * self.moe_d_ff * \
            max(self.n_experts - self.top_k, 0)
        return full - unused

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if self.attn_every <= 1 else
                         self.attn_every),
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)
                           if self.n_kv_heads < self.n_heads else 4),
            head_dim=32,
            d_ff=256,
            moe_d_ff=64 if self.moe else 0,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            vocab=256,
            kv_lora=64 if self.mla else 0,
            rope_head_dim=16 if self.mla else self.rope_head_dim,
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=32 if self.ssm else 64,
            enc_len=32,
            n_patches=8,
            first_dense=min(self.first_dense, 1),
        )


ARCH_IDS = (
    "granite-34b", "yi-6b", "stablelm-3b", "mistral-large-123b",
    "deepseek-v2-lite-16b", "arctic-480b", "whisper-small",
    "phi-3-vision-4.2b", "mamba2-130m", "jamba-v0.1-52b",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


#: assigned input shapes (shared by all LM archs)
SHAPES: Dict[str, dict] = {
    "train_4k":    dict(kind="train",   seq_len=4096,    global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768,   global_batch=32),
    "decode_32k":  dict(kind="decode",  seq_len=32768,   global_batch=128),
    "long_500k":   dict(kind="decode",  seq_len=524288,  global_batch=1),
}

#: archs allowed to run long_500k (sub-quadratic sequence mixers)
SUBQUADRATIC = ("mamba2-130m", "jamba-v0.1-52b")


def cell_is_runnable(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, "pure full-attention arch: 500k decode skipped (DESIGN.md)"
    return True, ""
