"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, 16-expert
top-2 MoE every other layer.  [arXiv:2403.19887; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=65536, head_dim=128,
    moe=True, n_experts=16, top_k=2, moe_d_ff=14336, moe_every=2,
    ssm=True, ssm_state=16, ssm_expand=2, ssm_headdim=64, attn_every=8,
    source="arXiv:2403.19887; hf",
)
