"""Deterministic synthetic token pipeline, sharded per host.

Production shape: each host materializes only its shard of the global batch
(`host_slice`), prefetches ahead of the step loop, and supports *hedged*
reads (straggler mitigation: issue a duplicate read for the slowest shard
and take the first to arrive — here simulated, interface real).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2
    hedge: bool = False          # straggler mitigation (duplicate reads)


def _batch_at(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Deterministic batch as a function of (seed, step) only — any host can
    regenerate any shard, which is what makes hedged/elastic reads trivial."""
    rng = np.random.default_rng((cfg.seed, step))
    B, S = cfg.global_batch, cfg.seq_len
    # Markov-ish synthetic stream with local structure (so loss can fall)
    base = rng.integers(0, cfg.vocab, (B, 1), dtype=np.int32)
    drift = rng.integers(-3, 4, (B, S), dtype=np.int32)
    toks = (base + np.cumsum(drift, 1)) % cfg.vocab
    tokens = toks.astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = -1                       # masked
    return {"tokens": tokens, "labels": labels}


def host_slice(cfg: DataConfig, batch: Dict[str, np.ndarray]
               ) -> Dict[str, np.ndarray]:
    per = cfg.global_batch // cfg.n_hosts
    lo = cfg.host_id * per
    return {k: v[lo:lo + per] for k, v in batch.items()}


class Pipeline:
    """Background-thread prefetching iterator over deterministic batches."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _produce_one(self, step: int) -> Dict[str, np.ndarray]:
        full = _batch_at(self.cfg, step)
        if self.cfg.hedge:
            # hedged read: regenerate the shard through the alternate path
            # and take the first result (identical by determinism)
            alt = host_slice(self.cfg, _batch_at(self.cfg, step))
            return alt
        return host_slice(self.cfg, full)

    def _producer(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self._q.put((s, self._produce_one(s)), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def close(self):
        self._stop.set()
