"""ShapeDtypeStruct input stand-ins for every (arch × shape) dry-run cell.

No device allocation: everything here is shape metadata, the same pattern a
launcher uses to lower programs before the job lands on real chips.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SHAPES
from repro.models import lm
from repro.optim import adamw

SDS = jax.ShapeDtypeStruct
PyTree = Any


def batch_specs_for(cfg: ArchConfig, shape_name: str) -> Dict[str, SDS]:
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    if kind == "decode":
        batch = {"tokens": SDS((B, 1), jnp.int32)}
    else:
        batch = {"tokens": SDS((B, S), jnp.int32)}
        if kind == "train":
            batch["labels"] = SDS((B, S), jnp.int32)
    if cfg.enc_dec and kind != "decode":
        batch["frames"] = SDS((B, cfg.enc_len, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "patches" and kind != "decode":
        batch["patches"] = SDS((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    return batch


def param_structs(cfg: ArchConfig) -> PyTree:
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: lm.init_params(k, cfg), key)


def opt_structs(params: PyTree) -> PyTree:
    return jax.eval_shape(adamw.init, params)


def cache_structs(cfg: ArchConfig, B: int, S_max: int) -> PyTree:
    return jax.eval_shape(lambda: lm.init_caches(cfg, B, S_max))


def input_specs(cfg: ArchConfig, shape_name: str) -> Dict[str, PyTree]:
    """Everything the step function of this cell consumes."""
    sh = SHAPES[shape_name]
    out: Dict[str, PyTree] = {
        "params": param_structs(cfg),
        "batch": batch_specs_for(cfg, shape_name),
    }
    if sh["kind"] == "train":
        out["opt"] = opt_structs(out["params"])
    if sh["kind"] == "decode":
        out["caches"] = cache_structs(cfg, sh["global_batch"], sh["seq_len"])
        out["pos"] = SDS((), jnp.int32)
    return out
