"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 per-pod mesh (256 chips), or 2×16×16 across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a (1, n) data×model mesh (examples/CI)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
