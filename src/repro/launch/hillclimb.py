import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration runner: lower one cell under knob variants, print the
three roofline terms per variant (EXPERIMENTS.md §Perf Track B).

  python -m repro.launch.hillclimb --arch deepseek-v2-lite-16b \
      --shape train_4k --variants base,nosp,dots,nozero1,fsdp,moeshard
"""
import argparse
import json
import time

from repro.configs.base import get_config, SHAPES
from repro.flopcount import cell_flops
from repro.roofline import PEAK_FLOPS, analyze_compiled

VARIANTS = {
    "base":     dict(),
    "nosp":     dict(seq_shard=False),
    "dots":     dict(remat="dots"),
    "nozero1":  dict(zero1=False),
    "fsdp":     dict(fsdp=True),
    "fsdp_dots": dict(fsdp=True, remat="dots"),
    "moeshard": dict(moe_shard=True),
    "moeshard_nosp": dict(moe_shard=True, seq_shard=False),
}


def run_variant(arch, shape, multi_pod, name, knobs):
    from repro.launch import dryrun as D
    from repro.models import layers as Lmod
    moe_shard = knobs.pop("moe_shard", False)
    Lmod.MOE_SHARD_DISPATCH = moe_shard
    t0 = time.time()
    try:
        _, compiled, _ = D.lower_cell_cfg(get_config(arch), shape,
                                          multi_pod, **knobs)
        r = analyze_compiled(compiled)
        extr = D.depth_extrapolated_costs(arch, shape, multi_pod,
                                          knobs.get("seq_shard", True),
                                          knobs.get("zero1", True),
                                          knobs.get("remat", "full"),
                                          knobs.get("fsdp", False))
        r.bytes_per_chip = max(extr["bytes_per_chip"], r.bytes_per_chip)
        r.coll_bytes_per_chip = max(extr["coll_bytes_per_chip"],
                                    r.coll_bytes_per_chip)
        cfg = get_config(arch)
        n_dev = 512 if multi_pod else 256
        remat = knobs.get("remat", "full")
        tc = cell_flops(cfg, shape, remat=remat) / n_dev / PEAK_FLOPS
        mem = compiled.memory_analysis()
        peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 2**30
        out = {
            "variant": name, "t_compute": round(tc, 3),
            "t_memory": round(r.t_memory, 3),
            "t_collective": round(r.t_collective, 3),
            "bound": round(max(tc, r.t_memory, r.t_collective), 3),
            "peak_gib": round(peak, 1),
            "coll_detail": {k: f"{v:.2e}" for k, v in
                            sorted(r.coll_detail.items())},
            "compile_s": round(time.time() - t0, 1),
        }
    finally:
        Lmod.MOE_SHARD_DISPATCH = False
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--variants", default="base")
    args = ap.parse_args()
    for name in args.variants.split(","):
        knobs = dict(VARIANTS[name])
        try:
            out = run_variant(args.arch, args.shape, args.multi, name, knobs)
        except Exception as e:  # noqa: BLE001
            out = {"variant": name, "error": f"{type(e).__name__}: {e}"[:300]}
        print(json.dumps(out), flush=True)


if __name__ == "__main__":
    main()
