"""Runnable training driver (CPU example scale; same code path as pods).

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt /tmp/ck

Features exercised end-to-end: config selection, sharded data pipeline,
AdamW+ZeRO, checkpoint/restart (``--resume``), straggler monitor, simulated
failure injection (``--fail-at``).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, Pipeline
from repro.launch.mesh import make_host_mesh, dp_axes
from repro.models import sharding as shd
from repro.models.lm import init_params
from repro.optim import adamw
from repro.train import checkpoint as ckpt
from repro.train.fault import RestartPolicy, StragglerMonitor
from repro.train.step import make_train_step
from repro.util import enable_compile_cache


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a simulated failure at this step")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()
    enable_compile_cache()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    shard = shd.ShardCfg(mesh=mesh, dp=dp_axes(mesh))
    print(f"arch={cfg.name} params≈{cfg.param_count():,} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    start = 0
    if args.resume and args.ckpt and ckpt.latest_step(args.ckpt) is not None:
        start, (params, opt) = ckpt.restore(args.ckpt, (params, opt))
        print(f"resumed from step {start}")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup=20)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, shard))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    pipe = Pipeline(dcfg, start_step=start)
    mon = StragglerMonitor()
    policy = RestartPolicy()

    losses = []
    t_start = time.time()
    for step, batch in pipe:
        if step >= args.steps:
            break
        if step == args.fail_at and policy.should_restart():
            print(f"[fault] simulated host failure at step {step}; "
                  f"restarting from checkpoint")
            policy.record()
            assert args.ckpt, "--fail-at needs --ckpt"
            start, (params, opt) = ckpt.restore(args.ckpt, (params, opt))
            pipe.close()
            pipe = Pipeline(dcfg, start_step=start)
            continue
        t0 = time.time()
        batch_j = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.enc_dec:
            batch_j["frames"] = jnp.zeros(
                (args.batch, cfg.enc_len, cfg.d_model), jnp.bfloat16)
        if cfg.frontend == "patches":
            batch_j["patches"] = jnp.zeros(
                (args.batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        params, opt, metrics = step_fn(params, opt, batch_j)
        dt = time.time() - t0
        straggle = mon.observe(dt)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"xent {float(metrics['xent']):.4f} {dt*1e3:.0f}ms"
                  + (" [straggler]" if straggle else ""), flush=True)
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt, step + 1, (params, opt),
                      extra={"arch": cfg.name})
    pipe.close()
    n = max(len(losses) // 5, 1)
    print(f"done: steps={len(losses)} loss {np.mean(losses[:n]):.4f} -> "
          f"{np.mean(losses[-n:]):.4f}  wall {time.time()-t_start:.0f}s "
          f"stragglers={mon.flagged}")


if __name__ == "__main__":
    main()
