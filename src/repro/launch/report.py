"""Render dryrun_report.json into the EXPERIMENTS.md §Dry-run / §Roofline
markdown tables.

    PYTHONPATH=src python -m repro.launch.report dryrun_report.json
"""
from __future__ import annotations

import json
import sys
from typing import List


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def roofline_table(records: List[dict], mesh: str = "single") -> str:
    rows = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
            "bound | 6ND/analytic | roofline frac | peak mem/chip |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in records:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "SKIP":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"SKIP | — | — | — |")
            continue
        if r["status"] != "OK":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"**FAIL** | — | — | — |")
            continue
        f = r["roofline"]
        mem = r.get("memory_analysis", {})
        peak = (mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0))
        tc = f.get("t_compute_analytic_s", f["t_compute_s"])
        rows.append(
            f"| {r['arch']} | {r['shape']} | {tc:.3f} | "
            f"{f['t_memory_s']:.3f} | {f['t_collective_s']:.3f} | "
            f"{f.get('bottleneck_analytic', f['bottleneck'])} | "
            f"{r.get('useful_flops_ratio_analytic', 0):.2f} | "
            f"{f.get('roofline_fraction', 0):.2f} | "
            f"{fmt_bytes(peak)} |")
    return "\n".join(rows)


def dryrun_table(records: List[dict]) -> str:
    ok_s = sum(r["status"] == "OK" and r["mesh"] == "single"
               for r in records)
    ok_m = sum(r["status"] == "OK" and r["mesh"] == "multi"
               for r in records)
    sk = sum(r["status"] == "SKIP" for r in records) // 2
    fails = [r for r in records if r["status"] == "FAIL"]
    lines = [f"single-pod (16×16): {ok_s} OK; multi-pod (2×16×16): "
             f"{ok_m} OK; {sk} documented skips per mesh."]
    if fails:
        lines.append("FAILURES:")
        for r in fails:
            lines.append(f"  {r['arch']}×{r['shape']}×{r['mesh']}: "
                         f"{r['error'][:160]}")
    # collective inventory for the most collective-bound cells
    lines.append("")
    lines.append("| arch | shape | mesh | collectives (count) | "
                 "ring-bytes/chip | compile (s) |")
    lines.append("|---|---|---|---|---|---|")
    for r in records:
        if r["status"] != "OK":
            continue
        f = r["roofline"]
        cc = ", ".join(f"{k}:{v}" for k, v in
                       sorted(f["coll_counts"].items()))
        lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {cc} | "
                     f"{fmt_bytes(f['coll_bytes_per_chip'])} | "
                     f"{r['compile_s']} |")
    return "\n".join(lines)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_report.json"
    records = json.load(open(path))
    print("## §Dry-run\n")
    print(dryrun_table(records))
    print("\n## §Roofline (single-pod 16×16 = 256 chips)\n")
    print(roofline_table(records, "single"))
    print("\n## §Roofline (multi-pod 2×16×16 = 512 chips)\n")
    print(roofline_table(records, "multi"))


if __name__ == "__main__":
    main()
