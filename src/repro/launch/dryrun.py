import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without real hardware:
``.lower().compile()`` must succeed on the 16×16 single-pod mesh and the
2×16×16 multi-pod mesh for every assigned architecture × input shape; the
compiled artifact yields memory_analysis (fits?) and cost_analysis + HLO
collectives (roofline terms, §Roofline).

Usage:
  python -m repro.launch.dryrun [--arch yi-6b] [--shape train_4k]
      [--mesh single|multi|both] [--out report.json] [--seq-shard 0|1]
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import numpy as np

from repro.configs.base import (ARCH_IDS, SHAPES, cell_is_runnable,
                                get_config)
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.launch import specs as S
from repro.models import sharding as shd
from repro.models.lm import decode_step
from repro.optim import adamw
from repro.roofline import analyze_compiled, model_flops
from repro.serve.engine import prefill
from repro.train.step import make_train_step


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               seq_shard: bool = True):
    """Returns (lowered, compiled, shard_cfg) for one cell."""
    return lower_cell_cfg(get_config(arch), shape_name, multi_pod, seq_shard)


def lower_cell_cfg(cfg, shape_name: str, multi_pod: bool,
                   seq_shard: bool = True, zero1: bool = True,
                   remat: str = "full", fsdp: bool = False):
    from repro.models import lm as lm_mod
    from repro.models import layers as layers_mod
    lm_mod.REMAT_POLICY = remat
    mesh = make_production_mesh(multi_pod=multi_pod)
    shard = shd.ShardCfg(mesh=mesh, dp=dp_axes(mesh), seq_shard=seq_shard)
    # dispatch-capacity sharding (§Perf B-1/B-3): helps when capacity per
    # expert is large (top_k/E above ~1/tp), hurts when experts are many
    # and capacity small — auto-default from the measured regime rule.
    auto_moe = bool(cfg.moe and cfg.n_experts
                    and cfg.top_k / cfg.n_experts > 1.0 / shard.tp_size)
    if getattr(layers_mod, "MOE_SHARD_DISPATCH", False) or auto_moe:
        layers_mod.MOE_DISPATCH_SPEC = shard.named(
            shd.P(shard.tp, shard.dp, None))
        layers_mod.MOE_SHARD_DISPATCH = True
    else:
        layers_mod.MOE_DISPATCH_SPEC = None
    sh = SHAPES[shape_name]
    ins = S.input_specs(cfg, shape_name)
    pspecs = shd.param_specs(ins["params"], shard)
    if fsdp:   # ZeRO-3-ish: shard a replicated weight dim over data axes
        pspecs = shd.zero1_specs(ins["params"], pspecs, shard)
    pshard = jax.tree_util.tree_map(shard.named, pspecs)
    bshard = jax.tree_util.tree_map(
        shard.named, shd.batch_specs(ins["batch"], shard))

    with mesh:
        if sh["kind"] == "train":
            # opt state follows param specs, upgraded with dp (ZeRO-1)
            opt_pspecs = adamw.OptState(master=pspecs, m=pspecs, v=pspecs,
                                        count=shd.P())
            if zero1:
                ospecs = shd.zero1_specs(ins["opt"], opt_pspecs, shard)
            else:
                ospecs = opt_pspecs
            oshard = jax.tree_util.tree_map(shard.named, ospecs)
            step = make_train_step(cfg, adamw.AdamWConfig(), shard)
            jf = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None))
            lowered = jf.lower(ins["params"], ins["opt"], ins["batch"])
        elif sh["kind"] == "prefill":
            def pf(params, batch):
                return prefill(params, cfg, batch, shard)
            jf = jax.jit(pf, in_shardings=(pshard, bshard))
            lowered = jf.lower(ins["params"], ins["batch"])
        else:                                   # decode
            cshard = jax.tree_util.tree_map(
                shard.named, shd.cache_specs(ins["caches"], shard))
            def dec(params, token, caches, pos):
                return decode_step(params, cfg, token, caches, pos, shard)
            jf = jax.jit(dec,
                         in_shardings=(pshard, bshard["tokens"], cshard,
                                       shard.named(shd.P())),
                         out_shardings=(None, cshard))
            lowered = jf.lower(ins["params"], ins["batch"]["tokens"],
                               ins["caches"], ins["pos"])
        compiled = lowered.compile()
    return lowered, compiled, shard


def _with_depth(cfg, n_periods: int):
    """Same-family config with `n_periods` repetitions of the layer pattern
    (plus any non-repeating prefix).  Used for depth extrapolation of HLO
    costs: XLA cost_analysis counts while-loop (scan) bodies once, so the
    full-depth scanned program under-reports FLOPs; costs are affine in
    depth, so two shallow compiles give the exact slope."""
    import dataclasses as dc
    from repro.models.lm import group_descs, layer_descs
    groups = group_descs(layer_descs(cfg))
    period = len(groups[-1][1])
    prefix = cfg.n_layers - groups[-1][0] * period
    kw = dict(n_layers=prefix + n_periods * period)
    if cfg.enc_dec:
        kw["n_enc_layers"] = n_periods
    return dc.replace(cfg, **kw), prefix, period


def depth_extrapolated_costs(arch: str, shape_name: str, multi_pod: bool,
                             seq_shard: bool, zero1: bool = True,
                             remat: str = "full", fsdp: bool = False
                             ) -> Dict[str, float]:
    """flops/bytes/collective-bytes per chip at full depth via slope."""
    cfg = get_config(arch)
    from repro import roofline as RL
    from repro.models import lm as lm_mod
    vals = []
    lm_mod.FORCE_UNROLL = True      # scan bodies are cost-counted once
    try:
        for k in (1, 2):
            cfg_k, prefix, period = _with_depth(cfg, k)
            _, compiled_k, _ = lower_cell_cfg(cfg_k, shape_name, multi_pod,
                                              seq_shard, zero1, remat, fsdp)
            vals.append(RL.analyze_compiled(compiled_k))
    finally:
        lm_mod.FORCE_UNROLL = False
    n_periods = (cfg.n_layers - prefix) // period
    out = {}
    for field in ("flops_per_chip", "bytes_per_chip", "coll_bytes_per_chip"):
        c1, c2 = getattr(vals[0], field), getattr(vals[1], field)
        out[field] = c1 + (c2 - c1) * (n_periods - 1)
    if cfg.enc_dec:  # encoder depth also scales (same slope trick)
        pass         # included: enc layers scale with k above
    out["coll_detail_slope"] = {
        k2: vals[0].coll_detail.get(k2, 0.0)
        + (vals[1].coll_detail.get(k2, 0.0)
           - vals[0].coll_detail.get(k2, 0.0)) * (n_periods - 1)
        for k2 in set(vals[0].coll_detail) | set(vals[1].coll_detail)}
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             seq_shard: bool = True, zero1: bool = True,
             remat: str = "full", fsdp: bool = False) -> Dict[str, Any]:
    t0 = time.time()
    ok, why = cell_is_runnable(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "SKIP", "reason": why}
    try:
        _, compiled, _ = lower_cell_cfg(get_config(arch), shape_name,
                                        multi_pod, seq_shard, zero1, remat,
                                        fsdp)
        full_compile_s = round(time.time() - t0, 1)
        roof = analyze_compiled(compiled)
        cfg = get_config(arch)
        n_dev = 512 if multi_pod else 256
        mf = model_flops(cfg, SHAPES[shape_name])
        t1 = time.time()
        extr = depth_extrapolated_costs(arch, shape_name, multi_pod,
                                        seq_shard, zero1, remat, fsdp)
        roof.flops_per_chip = max(extr["flops_per_chip"],
                                  roof.flops_per_chip)
        roof.bytes_per_chip = max(extr["bytes_per_chip"],
                                  roof.bytes_per_chip)
        roof.coll_bytes_per_chip = max(extr["coll_bytes_per_chip"],
                                       roof.coll_bytes_per_chip)
        roof.coll_detail = extr["coll_detail_slope"]
        rec = {
            "arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "status": "OK",
            "compile_s": full_compile_s,
            "extrap_compile_s": round(time.time() - t1, 1),
            "n_devices": n_dev,
            "model_flops_global": mf,
            "useful_flops_ratio": mf / max(roof.flops_per_chip * n_dev, 1),
            "roofline": roof.as_dict(),
        }
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k, 0)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")}
        return rec
    except Exception as e:  # noqa: BLE001 — failures are the signal here
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "FAIL", "compile_s": round(time.time() - t0, 1),
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_report.json")
    ap.add_argument("--seq-shard", type=int, default=1)
    ap.add_argument("--zero1", type=int, default=1)
    ap.add_argument("--remat", default="full", choices=["full", "dots"])
    ap.add_argument("--fsdp", type=int, default=0)
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    records = []
    if args.append and os.path.exists(args.out):
        records = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in records}
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                key = (arch, shape_name, "multi" if mp else "single")
                if key in done:
                    continue
                rec = run_cell(arch, shape_name, mp, bool(args.seq_shard),
                               bool(args.zero1), args.remat,
                               bool(args.fsdp))
                status = rec["status"]
                extra = ""
                if status == "OK":
                    r = rec["roofline"]
                    extra = (f"bottleneck={r['bottleneck']} "
                             f"tc={r['t_compute_s']:.4f}s "
                             f"tm={r['t_memory_s']:.4f}s "
                             f"tx={r['t_collective_s']:.4f}s "
                             f"compile={rec['compile_s']}s")
                elif status == "FAIL":
                    extra = rec["error"][:200]
                print(f"[{status}] {arch} × {shape_name} × {key[2]}  {extra}",
                      flush=True)
                records.append(rec)
                json.dump(records, open(args.out, "w"), indent=1)
    n_ok = sum(r["status"] == "OK" for r in records)
    n_skip = sum(r["status"] == "SKIP" for r in records)
    n_fail = sum(r["status"] == "FAIL" for r in records)
    print(f"dry-run complete: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL")


if __name__ == "__main__":
    main()
