"""Post-process dryrun_report.json: add analytic compute terms.

    PYTHONPATH=src python -m repro.launch.enrich dryrun_report.json
"""
from __future__ import annotations

import json
import sys

from repro.configs.base import get_config
from repro.flopcount import cell_flops
from repro.roofline import PEAK_FLOPS


def enrich(records):
    for r in records:
        if r["status"] != "OK":
            continue
        cfg = get_config(r["arch"])
        n_dev = r["n_devices"]
        fl = cell_flops(cfg, r["shape"])
        r["analytic_flops_global"] = fl
        r["roofline"]["t_compute_analytic_s"] = fl / n_dev / PEAK_FLOPS
        r["useful_flops_ratio_analytic"] = r["model_flops_global"] / fl
        # bottleneck using the analytic compute term
        f = r["roofline"]
        terms = {"compute": f["t_compute_analytic_s"],
                 "memory": f["t_memory_s"],
                 "collective": f["t_collective_s"]}
        f["bottleneck_analytic"] = max(terms, key=terms.get)
        f["roofline_fraction"] = (f["t_compute_analytic_s"]
                                  / max(sum(terms.values()), 1e-12))
    return records


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_report.json"
    records = json.load(open(path))
    json.dump(enrich(records), open(path, "w"), indent=1)
    print(f"enriched {sum(r['status'] == 'OK' for r in records)} OK records")


if __name__ == "__main__":
    main()
