"""Deterministic test-graph generators, analogs of the paper's Table 1 suite.

The UF collection is not available offline, so we generate graphs from the
same application families:

* ``grid2d`` / ``grid3d``  — FE-mesh analogs (paper: altr4, audikw1, bmw32,
  conesphere1m, coupole8000 are 2D/3D meshes).  3D grids have the
  O(n^{2/3}) separators the band-refinement argument relies on.
* ``rgg2d``                — random geometric graph (unstructured mesh analog).
* ``circuit``              — low average degree, long chains + random fanout
  (paper: qimonda07, avg degree 6.8 circuit graph).
* ``knn3d``                — high, regular degree (paper: thread, deg 149).
* ``cage_like``            — expander-ish DNA-electrophoresis analog (cage15).
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph


def grid2d(nx: int, ny: int) -> Graph:
    """5-point stencil nx×ny grid."""
    idx = np.arange(nx * ny).reshape(nx, ny)
    e = []
    e.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1))
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1))
    return Graph.from_edges(nx * ny, np.concatenate(e))


def grid3d(nx: int, ny: int, nz: int, stencil: int = 7) -> Graph:
    """7-point (or 27-point) stencil 3D grid — FE mesh analog."""
    idx = np.arange(nx * ny * nz).reshape(nx, ny, nz)
    e = []
    e.append(np.stack([idx[:-1].ravel(), idx[1:].ravel()], 1))
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1))
    e.append(np.stack([idx[:, :, :-1].ravel(), idx[:, :, 1:].ravel()], 1))
    if stencil == 27:
        for dx in (-1, 0, 1):
            for dy in (-1, 0, 1):
                for dz in (-1, 0, 1):
                    if (dx, dy, dz) <= (0, 0, 0):
                        continue
                    sa = idx[max(0, -dx):nx - max(0, dx),
                             max(0, -dy):ny - max(0, dy),
                             max(0, -dz):nz - max(0, dz)]
                    sb = idx[max(0, dx):nx - max(0, -dx),
                             max(0, dy):ny - max(0, -dy),
                             max(0, dz):nz - max(0, -dz)]
                    e.append(np.stack([sa.ravel(), sb.ravel()], 1))
    return Graph.from_edges(nx * ny * nz, np.concatenate(e))


def rgg2d(n: int, seed: int = 0, deg_target: float = 8.0) -> Graph:
    """Random geometric graph on the unit square via cell binning."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    r = np.sqrt(deg_target / (np.pi * n))
    nc = max(1, int(1.0 / r))
    cell = (np.minimum((pts / (1.0 / nc)).astype(np.int64), nc - 1))
    cid = cell[:, 0] * nc + cell[:, 1]
    order = np.argsort(cid, kind="stable")
    starts = np.searchsorted(cid[order], np.arange(nc * nc))
    ends = np.searchsorted(cid[order], np.arange(nc * nc), side="right")
    edges = []
    for cx in range(nc):
        for cy in range(nc):
            mine = order[starts[cx * nc + cy]:ends[cx * nc + cy]]
            if not len(mine):
                continue
            cand = [mine]
            for dx, dy in ((0, 1), (1, -1), (1, 0), (1, 1)):
                ox, oy = cx + dx, cy + dy
                if 0 <= ox < nc and 0 <= oy < nc:
                    cand.append(order[starts[ox * nc + oy]:ends[ox * nc + oy]])
            others = np.concatenate(cand)
            d2 = ((pts[mine, None, :] - pts[None, others, :]) ** 2).sum(-1)
            ii, jj = np.nonzero(d2 <= r * r)
            a, b = mine[ii], others[jj]
            keep = a < b
            if keep.any():
                edges.append(np.stack([a[keep], b[keep]], 1))
    if not edges:
        edges = [np.zeros((0, 2), dtype=np.int64)]
    g = Graph.from_edges(n, np.concatenate(edges))
    return _connect(g, pts_order=np.argsort(pts[:, 0], kind="stable"))


def circuit(n: int, seed: int = 0, fanout: float = 2.4) -> Graph:
    """Circuit-simulation analog: chain + random low-degree fanout."""
    rng = np.random.default_rng(seed)
    chain = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    k = int(n * fanout)
    src = rng.integers(0, n, k)
    # mostly-local wiring with a few long nets
    span = np.where(rng.random(k) < 0.9,
                    rng.integers(1, 50, k), rng.integers(1, n, k))
    dst = (src + span) % n
    return Graph.from_edges(n, np.concatenate([chain, np.stack([src, dst], 1)]))


def knn3d(n: int, k: int = 24, seed: int = 0) -> Graph:
    """k-nearest-neighbor graph in 3D — high-degree 'thread' analog."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 3))
    # brute-force in blocks (n expected ≤ ~20k)
    edges = []
    B = 512
    for s in range(0, n, B):
        blk = pts[s:s + B]
        d2 = ((blk[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
        nn = np.argpartition(d2, k + 1, axis=1)[:, :k + 1]
        src = np.repeat(np.arange(s, s + len(blk)), k + 1)
        edges.append(np.stack([src, nn.ravel()], 1))
    return Graph.from_edges(n, np.concatenate(edges))


def cage_like(n: int, seed: int = 0, deg: int = 8) -> Graph:
    """Expander-ish analog of cage15 (DNA electrophoresis): local 3D grid
    plus random matchings (long-range)."""
    side = max(2, round(n ** (1 / 3)))
    g = grid3d(side, side, side)
    nn = g.n
    rng = np.random.default_rng(seed)
    extra = []
    for _ in range(deg // 4):
        perm = rng.permutation(nn)
        extra.append(perm[:(nn // 2) * 2].reshape(-1, 2))
    edges = np.concatenate(extra)
    both = np.concatenate([np.stack([np.repeat(np.arange(nn), np.diff(g.xadj)),
                                     g.adjncy], 1), edges])
    return Graph.from_edges(nn, both)


def _connect(g: Graph, pts_order: np.ndarray) -> Graph:
    """Stitch components with a spatial chain so generators return one CC."""
    comp = g.components()
    if comp.max() == 0:
        return g
    seen = {}
    extra = []
    prev = None
    for v in pts_order:
        c = comp[v]
        if c not in seen:
            seen[c] = v
            if prev is not None:
                extra.append((prev, v))
            prev = v
    src = np.repeat(np.arange(g.n), g.degrees())
    all_edges = np.concatenate(
        [np.stack([src, g.adjncy], 1), np.array(extra, dtype=np.int64)])
    return Graph.from_edges(g.n, all_edges)


#: paper-analog suite used by the benchmarks (name -> constructor)
SUITE = {
    "altr4-like":    lambda: grid3d(30, 30, 30),              # 27k, 3D mesh
    "bmw32-like":    lambda: grid3d(61, 61, 61, stencil=7),   # 227k, 3D mesh
    "audikw1-like":  lambda: grid3d(21, 21, 21, stencil=27),  # 9.2k, deg~26
    "conesphere-like": lambda: rgg2d(100_000, seed=3),
    "qimonda-like":  lambda: circuit(120_000, seed=7),
    "thread-like":   lambda: knn3d(8_000, k=48, seed=1),
    "cage-like":     lambda: cage_like(40_000, seed=5),
}
