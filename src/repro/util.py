"""Shared utilities: persistent compile cache, pow2 bucketing, timers."""
from __future__ import annotations

import os
import time

_CACHE_ON = False


def enable_compile_cache() -> None:
    """Persistent XLA compilation cache (huge win for the host-recursion
    control plane, which reuses a small family of jitted kernels)."""
    global _CACHE_ON
    if _CACHE_ON or os.environ.get("REPRO_NO_CACHE"):
        return
    import jax
    cache_dir = os.environ.get("REPRO_CACHE_DIR",
                               os.path.expanduser("~/.cache/repro_jax"))
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.1)
    _CACHE_ON = True


def pow2(x: int, lo: int = 64) -> int:
    v = lo
    while v < x:
        v *= 2
    return v


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
