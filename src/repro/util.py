"""Shared utilities: persistent compile cache, pow2 bucketing, timers."""
from __future__ import annotations

import os
import time

_CACHE_ON = False


def enable_compile_cache() -> None:
    """Persistent XLA compilation cache (huge win for the host-recursion
    control plane, which reuses a small family of jitted kernels)."""
    global _CACHE_ON
    if _CACHE_ON or os.environ.get("REPRO_NO_CACHE"):
        return
    import jax
    cache_dir = os.environ.get("REPRO_CACHE_DIR",
                               os.path.expanduser("~/.cache/repro_jax"))
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.1)
    _CACHE_ON = True


_MASK64 = (1 << 64) - 1


def mix_seeds(*vals: int) -> int:
    """Splitmix64-style hash of a seed path → 31-bit PRNG seed.

    Per-node seeds in the ND tree are derived by chaining this over
    (seed, node path, level).  Affine formulas like ``seed * 31`` or
    ``seed * 101 + lvl`` collapse at ``seed=0`` (every node at a level
    reuses the identical noise stream); a full-avalanche mix does not.
    """
    h = 0
    for v in vals:
        h = (h + int(v) + 0x9E3779B97F4A7C15) & _MASK64
        h ^= h >> 30
        h = (h * 0xBF58476D1CE4E5B9) & _MASK64
        h ^= h >> 27
        h = (h * 0x94D049BB133111EB) & _MASK64
        h ^= h >> 31
    return h & 0x7FFFFFFF


def pow2(x: int, lo: int = 64) -> int:
    v = lo
    while v < x:
        v *= 2
    return v


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
