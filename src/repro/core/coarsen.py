"""Multilevel coarsening with fold-dup (paper §3.2).

The matching data-plane runs in JAX (``matching.py``); the coarse-graph
build is a host-side reshuffle (sort + segment-accumulate) — the control
plane / data plane split discussed in DESIGN.md §2.

Fold-dup: "coarsened graphs are folded and duplicated ... every subgroup of
processes that hold a working copy of the graph being able to perform an
almost-complete independent multi-level computation".  Quality-wise the
mechanism is: once the average number of vertices per process drops below
``fold_threshold`` (paper default 100), the process group splits into two
halves, each holding a *duplicate*, so from that point on independent
multilevel instances run and the best projected separator wins.  We model
the instance tree faithfully: ``n_instances`` doubles at every fold level
until each (simulated) process holds one copy.

Like BFS and FM, the matching stage is *work-yielding*:
``coarsen_multilevel_task`` yields one ``MatchWork`` per level and the
driver sends back the matching.  The sequential wrapper
(``coarsen_multilevel``) executes each work immediately; the ordering
service batches the matching works of every subproblem at a depth into
one ``kernels.ops.match_batch`` dispatch per ELL bucket (DESIGN.md §3),
so the deferred-subtree endgame no longer pays one device dispatch per
subproblem per level.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Generator, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.graph import Graph
from repro.core.matching import heavy_edge_matching
from repro.util import pow2


def match_graph(g: Graph, seed: int, rounds: int = 8) -> np.ndarray:
    """Heavy-edge matching of g via the JAX kernel (padded ELL)."""
    dmax = int(g.degrees().max()) if g.n else 1
    nbr, wgt = g.to_ell(dmax)
    n_pad = pow2(g.n)
    d_pad = pow2(dmax, 8)
    nbr_p = -np.ones((n_pad, d_pad), dtype=np.int32)
    wgt_p = np.zeros((n_pad, d_pad), dtype=np.int32)
    nbr_p[:g.n, :dmax] = nbr
    wgt_p[:g.n, :dmax] = wgt
    m = heavy_edge_matching(jax.numpy.asarray(nbr_p), jax.numpy.asarray(wgt_p),
                            jax.random.PRNGKey(seed), rounds=rounds)
    m = np.asarray(m)[:g.n]
    # Mask out-of-range ids (padded lanes) back to self-match: clamping to
    # n-1 would silently merge the vertex onto real vertex n-1.
    bad = (m < 0) | (m >= g.n)
    return np.where(bad, np.arange(g.n, dtype=m.dtype), m)


@dataclasses.dataclass
class MatchWork:
    """One heavy-edge-matching request (unpadded host ELL arrays).

    Yielded by ``coarsen_multilevel_task``; ``execute_match_works`` pads
    each work to its power-of-two ELL bucket and runs every work sharing a
    bucket as ONE ``kernels.ops.match_batch`` dispatch (one lane per
    graph).  Per-lane results are independent of batch composition.
    """
    nbr: np.ndarray                     # (n, d) int32 ELL ids, -1 pad
    wgt: np.ndarray                     # (n, d) int32 edge weights, 0 pad
    seed: int
    rounds: int = 8

    def bucket_key(self) -> Tuple[int, int, int]:
        n, d = self.nbr.shape
        return (pow2(n), pow2(max(d, 1), 8), self.rounds)


def match_work_for(g: Graph, seed: int, rounds: int = 8) -> MatchWork:
    """Build the MatchWork for one graph (same ELL form as match_graph)."""
    dmax = int(g.degrees().max()) if g.n else 1
    nbr, wgt = g.to_ell(dmax)
    return MatchWork(nbr=nbr, wgt=wgt, seed=seed, rounds=rounds)


def execute_match_works(works: Sequence[MatchWork]) -> List[np.ndarray]:
    """Run matching works, one batched dispatch per (n_pad, d_pad, rounds).

    Returns, per work in input order, the flat (n,) matching with
    match[v] = v for singletons (out-of-range ids from padded lanes are
    masked back to self, as in ``match_graph``).
    """
    from repro.kernels.ops import match_batch
    results: List[Optional[np.ndarray]] = [None] * len(works)
    groups = defaultdict(list)
    for i, w in enumerate(works):
        groups[w.bucket_key()].append(i)
    for (n_pad, d_pad, rounds), idxs in groups.items():
        L = len(idxs)
        nbr_b = -np.ones((L, n_pad, d_pad), np.int32)
        wgt_b = np.zeros((L, n_pad, d_pad), np.int32)
        keys = np.stack([np.asarray(jax.random.PRNGKey(works[i].seed))
                         for i in idxs])
        for j, i in enumerate(idxs):
            n, d = works[i].nbr.shape
            nbr_b[j, :n, :d] = works[i].nbr
            wgt_b[j, :n, :d] = works[i].wgt
        from repro import obs
        from repro.core.dgraph import _note_launch
        m = obs.timed_dispatch(
            "match", "match", ("match", n_pad, d_pad, rounds, L),
            lambda: np.asarray(match_batch(nbr_b, wgt_b, keys,
                                           rounds=rounds)),
            lanes=L, lanes_pad=L, bucket=(n_pad, d_pad), rounds=rounds)
        _note_launch("match", 0, L, L, (n_pad, d_pad), rounds, 0)
        for j, i in enumerate(idxs):
            n = works[i].nbr.shape[0]
            mi = m[j, :n].astype(np.int64)
            bad = (mi < 0) | (mi >= n)
            results[i] = np.where(bad, np.arange(n, dtype=np.int64), mi)
    return results                                           # type: ignore


def coarsen_once(g: Graph, match: np.ndarray):
    """Build the coarse graph from a matching.

    Returns (coarse_graph, cmap) with cmap[v_fine] = v_coarse.
    """
    rep = np.minimum(np.arange(g.n), match)
    reps = np.unique(rep)
    cmap_tbl = -np.ones(g.n, dtype=np.int64)
    cmap_tbl[reps] = np.arange(len(reps))
    cmap = cmap_tbl[rep]
    nc = len(reps)
    cvwgt = np.zeros(nc, dtype=np.int64)
    np.add.at(cvwgt, cmap, g.vwgt)
    src = np.repeat(np.arange(g.n), g.degrees())
    cs, cd = cmap[src], cmap[g.adjncy]
    keep = cs < cd                      # half-edges, drop collapsed
    cg = Graph.from_edges(nc, np.stack([cs[keep], cd[keep]], 1),
                          vwgt=cvwgt, ewgt=g.adjwgt[keep])
    return cg, cmap


def coarse_vtxdist(fine_vtxdist: np.ndarray, match: np.ndarray) -> np.ndarray:
    """Coarse ownership ranges for a shard-distributed coarsening step.

    Each coarse vertex lives on the owner of its representative (the min
    endpoint of its matched pair, as in ``coarsen_once``).  Unique reps in
    ascending order are already grouped by owner — vtxdist ranges are sorted
    — so the ``coarsen_once`` numbering keeps coarse ids shard-contiguous
    and the coarse vtxdist is a rank query of the fine boundaries.
    """
    rep = np.minimum(np.arange(len(match)), match)
    reps = np.unique(rep)
    return np.searchsorted(reps, np.asarray(fine_vtxdist)).astype(np.int64)


@dataclasses.dataclass
class Level:
    graph: Graph
    cmap: Optional[np.ndarray]          # fine -> coarse map (None at top)
    n_instances: int                    # independent fold-dup copies alive


@dataclasses.dataclass
class MultilevelState:
    levels: List[Level]                 # levels[0] = finest

    @property
    def coarsest(self) -> Graph:
        return self.levels[-1].graph


def coarsen_multilevel_task(g: Graph, seed: int, nproc: int = 1,
                            coarse_target: int = 120,
                            fold_threshold: int = 100,
                            max_instances: int = 16,
                            min_reduction: float = 0.97
                            ) -> Generator[MatchWork, np.ndarray,
                                           MultilevelState]:
    """Coarsen until ``coarse_target`` vertices, tracking fold-dup instances.

    Work-yielding form: yields one ``MatchWork`` per level, receives the
    flat matching back, and returns the ``MultilevelState``.  ``nproc`` is
    the simulated process count p of the paper; folding starts when
    n / p_cur < fold_threshold, and every fold doubles the number of
    independent instances (capped at ``max_instances`` for memory, the
    paper's own trade-off: "resort to folding only when the number of
    vertices ... reaches some minimum threshold").
    """
    levels = [Level(g, None, 1)]
    p_cur = max(1, nproc)
    n_inst = 1
    lvl_seed = seed
    while levels[-1].graph.n > coarse_target:
        cur = levels[-1].graph
        if p_cur > 1 and cur.n / p_cur < fold_threshold:
            p_cur = (p_cur + 1) // 2                       # fold ...
            n_inst = min(n_inst * 2, max_instances)        # ... with dup
        m = yield match_work_for(cur, lvl_seed)
        lvl_seed += 1
        cg, cmap = coarsen_once(cur, m)
        if cg.n > cur.n * min_reduction:                   # stalled
            break
        levels.append(Level(cg, cmap, n_inst))
    return MultilevelState(levels)


def coarsen_multilevel(g: Graph, seed: int, nproc: int = 1,
                       coarse_target: int = 120, fold_threshold: int = 100,
                       max_instances: int = 16,
                       min_reduction: float = 0.97) -> MultilevelState:
    """Synchronous driver of ``coarsen_multilevel_task`` (one dispatch per
    level; the ordering service drives the generator batched instead)."""
    gen = coarsen_multilevel_task(g, seed, nproc, coarse_target,
                                  fold_threshold, max_instances,
                                  min_reduction)
    try:
        work = next(gen)
        while True:
            work = gen.send(execute_match_works([work])[0])
    except StopIteration as stop:
        return stop.value
