"""Multilevel coarsening with fold-dup (paper §3.2).

The matching data-plane runs in JAX (``matching.py``); the coarse-graph
build is a host-side reshuffle (sort + segment-accumulate) — the control
plane / data plane split discussed in DESIGN.md §2.

Fold-dup: "coarsened graphs are folded and duplicated ... every subgroup of
processes that hold a working copy of the graph being able to perform an
almost-complete independent multi-level computation".  Quality-wise the
mechanism is: once the average number of vertices per process drops below
``fold_threshold`` (paper default 100), the process group splits into two
halves, each holding a *duplicate*, so from that point on independent
multilevel instances run and the best projected separator wins.  We model
the instance tree faithfully: ``n_instances`` doubles at every fold level
until each (simulated) process holds one copy.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import numpy as np

from repro.core.graph import Graph
from repro.core.matching import heavy_edge_matching
from repro.util import pow2


def match_graph(g: Graph, seed: int, rounds: int = 8) -> np.ndarray:
    """Heavy-edge matching of g via the JAX kernel (padded ELL)."""
    dmax = int(g.degrees().max()) if g.n else 1
    nbr, wgt = g.to_ell(dmax)
    n_pad = pow2(g.n)
    d_pad = pow2(dmax, 8)
    nbr_p = -np.ones((n_pad, d_pad), dtype=np.int32)
    wgt_p = np.zeros((n_pad, d_pad), dtype=np.int32)
    nbr_p[:g.n, :dmax] = nbr
    wgt_p[:g.n, :dmax] = wgt
    m = heavy_edge_matching(jax.numpy.asarray(nbr_p), jax.numpy.asarray(wgt_p),
                            jax.random.PRNGKey(seed), rounds=rounds)
    m = np.asarray(m)[:g.n]
    # Mask out-of-range ids (padded lanes) back to self-match: clamping to
    # n-1 would silently merge the vertex onto real vertex n-1.
    bad = (m < 0) | (m >= g.n)
    return np.where(bad, np.arange(g.n, dtype=m.dtype), m)


def coarsen_once(g: Graph, match: np.ndarray):
    """Build the coarse graph from a matching.

    Returns (coarse_graph, cmap) with cmap[v_fine] = v_coarse.
    """
    rep = np.minimum(np.arange(g.n), match)
    reps = np.unique(rep)
    cmap_tbl = -np.ones(g.n, dtype=np.int64)
    cmap_tbl[reps] = np.arange(len(reps))
    cmap = cmap_tbl[rep]
    nc = len(reps)
    cvwgt = np.zeros(nc, dtype=np.int64)
    np.add.at(cvwgt, cmap, g.vwgt)
    src = np.repeat(np.arange(g.n), g.degrees())
    cs, cd = cmap[src], cmap[g.adjncy]
    keep = cs < cd                      # half-edges, drop collapsed
    cg = Graph.from_edges(nc, np.stack([cs[keep], cd[keep]], 1),
                          vwgt=cvwgt, ewgt=g.adjwgt[keep])
    return cg, cmap


def coarse_vtxdist(fine_vtxdist: np.ndarray, match: np.ndarray) -> np.ndarray:
    """Coarse ownership ranges for a shard-distributed coarsening step.

    Each coarse vertex lives on the owner of its representative (the min
    endpoint of its matched pair, as in ``coarsen_once``).  Unique reps in
    ascending order are already grouped by owner — vtxdist ranges are sorted
    — so the ``coarsen_once`` numbering keeps coarse ids shard-contiguous
    and the coarse vtxdist is a rank query of the fine boundaries.
    """
    rep = np.minimum(np.arange(len(match)), match)
    reps = np.unique(rep)
    return np.searchsorted(reps, np.asarray(fine_vtxdist)).astype(np.int64)


@dataclasses.dataclass
class Level:
    graph: Graph
    cmap: Optional[np.ndarray]          # fine -> coarse map (None at top)
    n_instances: int                    # independent fold-dup copies alive


@dataclasses.dataclass
class MultilevelState:
    levels: List[Level]                 # levels[0] = finest

    @property
    def coarsest(self) -> Graph:
        return self.levels[-1].graph


def coarsen_multilevel(g: Graph, seed: int, nproc: int = 1,
                       coarse_target: int = 120, fold_threshold: int = 100,
                       max_instances: int = 16,
                       min_reduction: float = 0.97) -> MultilevelState:
    """Coarsen until ``coarse_target`` vertices, tracking fold-dup instances.

    ``nproc`` is the simulated process count p of the paper; folding starts
    when n / p_cur < fold_threshold, and every fold doubles the number of
    independent instances (capped at ``max_instances`` for memory, the
    paper's own trade-off: "resort to folding only when the number of
    vertices ... reaches some minimum threshold").
    """
    levels = [Level(g, None, 1)]
    p_cur = max(1, nproc)
    n_inst = 1
    lvl_seed = seed
    while levels[-1].graph.n > coarse_target:
        cur = levels[-1].graph
        if p_cur > 1 and cur.n / p_cur < fold_threshold:
            p_cur = (p_cur + 1) // 2                       # fold ...
            n_inst = min(n_inst * 2, max_instances)        # ... with dup
        m = match_graph(cur, lvl_seed)
        lvl_seed += 1
        cg, cmap = coarsen_once(cur, m)
        if cg.n > cur.n * min_reduction:                   # stalled
            break
        levels.append(Level(cg, cmap, n_inst))
    return MultilevelState(levels)
