"""Distributed nested dissection (paper §3) on the sharded DGraph layer.

End-to-end pipeline for ordering a *distributed* graph: the top levels of
the ND tree run directly on the sharded representation —

  * **distributed multilevel coarsening** — heavy-edge matching over the
    parts mesh (``dgraph.distributed_matching``: propose/grant rounds with
    halo exchange of the unmatched mask), coarse-graph build on the host
    control plane with coarse vertices kept on their representative's owner
    (``coarsen.coarse_vtxdist``), so successive levels stay shard-aligned;
  * **fold-dup** (§3.2) — once the average vertex count per process drops
    below ``fold_threshold``, the process group *actually splits*: each
    half receives a duplicate of the current coarse graph redistributed
    over its own parts, and the halves run fully independent multilevel
    instances; the best projected separator wins when the groups rejoin;
  * **multi-sequential band refinement** (§3.3) — the separator projected
    onto each fine level is band-extracted with a *distributed* BFS (one
    halo exchange per width step), the small band graph is centralized, and
    ``k`` FM lanes (``fm_refine_multi``) refine perturbed copies, the best
    one being projected back;
  * **centralize threshold** (§3.1) — subtrees whose subgraphs fall below
    ``centralize_threshold`` are gathered and handed, all together, to the
    ordering service's breadth-first scheduler (``service.scheduler``),
    which executes their BFS/FM work as bucketed batches across every
    deferred subtree at once.

The host recursion / device data-plane split follows DESIGN.md §2; §4
documents this pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.band import extract_band, project_band
from repro.core.coarsen import coarse_vtxdist, coarsen_once
from repro.core.dgraph import (DGraph, distribute, distributed_bfs,
                               distributed_matching, shard_vector, to_host,
                               unshard_vector)
from repro.core.fm import refine_parts, separator_is_valid
from repro.core.graph import Graph
from repro.core.initsep import initial_parts
from repro.core.nd import (NDConfig, child_nprocs, child_seeds,
                           component_seed, compute_separator,
                           resolve_separator, separator_perm,
                           split_by_separator)
from repro.core.ordering import Ordering
from repro.util import mix_seeds


@dataclasses.dataclass
class DNDConfig(NDConfig):
    """NDConfig + the distributed-pipeline knobs."""
    centralize_threshold: int = 256     # below: gather + defer to scheduler
    match_rounds: int = 8               # distributed matching rounds
    min_reduction: float = 0.97         # coarsening stall bound


@dataclasses.dataclass
class _Deferred:
    """One centralized subtree, ordered later by the batched scheduler."""
    g: Graph
    gids: np.ndarray
    seed: int
    nproc: int
    node: object
    start: int


# ------------------------------------------------------------------ #
# separator quality (best-projected-separator-wins selection)
# ------------------------------------------------------------------ #
def _eval_part(g: Graph, part: np.ndarray, eps_frac: float
               ) -> Tuple[float, float, float]:
    """(score, sep_w, imb): min separator weight among balance-feasible."""
    w0 = float(g.vwgt[part == 0].sum())
    w1 = float(g.vwgt[part == 1].sum())
    ws = float(g.vwgt[part == 2].sum())
    imb = abs(w0 - w1)
    total = w0 + w1 + ws
    score = ws if imb <= eps_frac * total else ws + total
    return score, ws, imb


# ------------------------------------------------------------------ #
# distributed multilevel separator
# ------------------------------------------------------------------ #
def _band_refine_level(g: Graph, dg: DGraph, part: np.ndarray, seed: int,
                       p_cur: int, cfg: DNDConfig) -> np.ndarray:
    """§3.3 at one distributed level: sharded BFS + multi-sequential FM.

    The distance sweep runs on the sharded structure (one halo exchange
    per width step); the band graph it selects is small (O(n^{2/3}) for
    meshes), so it is centralized and refined by k perturbed FM lanes —
    the best lane's separator is projected back.
    """
    # lane count mirrors nd.separator_task's non-strict path: one FM lane
    # per process of the group under fold-dup (p_cur >= 2 here — folded
    # instances go through compute_separator), else the host floor of 2
    k_fm = int(np.clip(p_cur, 2, cfg.k_fm_cap)) if cfg.fold_dup else 2
    if not cfg.use_band:
        nbr_f, _ = g.to_ell()
        part2, _, _ = refine_parts(
            nbr_f, g.vwgt, part, np.zeros(g.n, bool), mix_seeds(seed, 7),
            k_inst=k_fm, eps_frac=cfg.eps_frac, passes=cfg.fm_passes,
            n_pert=8)
        assert separator_is_valid(nbr_f, part2)
        return part2
    dist_sh = distributed_bfs(dg, shard_vector(dg, part == 2),
                              cfg.band_width)
    dist = unshard_vector(dg, dist_sh)
    band, bpart, locked, old_ids = extract_band(
        g, part, width=cfg.band_width, dist=dist)
    nbr_b, _ = band.to_ell()
    bpart, _, _ = refine_parts(
        nbr_b, band.vwgt, bpart, locked, mix_seeds(seed, 7), k_inst=k_fm,
        eps_frac=cfg.eps_frac, passes=cfg.fm_passes, n_pert=8)
    assert separator_is_valid(nbr_b, bpart)
    return project_band(part, bpart, old_ids)


def _coarsest_separator(g: Graph, seed: int, cfg: DNDConfig
                        ) -> Optional[np.ndarray]:
    """Initial separator on a (centralized) coarsest graph."""
    if g.n < 4:
        return None
    parts0 = initial_parts(g, seed, k_tries=min(cfg.k_init, 32))
    nbr, _ = g.to_ell()
    part, _, _ = refine_parts(
        nbr, g.vwgt, parts0[0], np.zeros(g.n, bool), mix_seeds(seed, 0),
        k_inst=len(parts0), eps_frac=cfg.eps_frac, passes=3, n_pert=4,
        parts_init=parts0)
    assert separator_is_valid(nbr, part)
    return part


def _dsep(g: Graph, dg: Optional[DGraph], p_cur: int, seed: int,
          cfg: DNDConfig, inst_budget: int) -> Optional[np.ndarray]:
    """Multilevel separator of g, distributed over p_cur parts.

    Returns the refined part vector of g (0/1/2) or None when degenerate.
    ``inst_budget`` caps the fold-dup instance tree (paper: "resort to
    folding only when ... reaches some minimum threshold" — here also a
    memory cap, mirroring ``coarsen_multilevel``'s ``max_instances``).
    """
    if p_cur <= 1:
        # a fully-folded instance: one process, the sequential pipeline
        return compute_separator(g, seed, 1, cfg)
    if g.n <= cfg.coarse_target:
        return _coarsest_separator(g, seed, cfg)

    if cfg.fold_dup and g.n / p_cur < cfg.fold_threshold and inst_budget >= 2:
        # fold-dup: the group splits; each half holds a duplicate of g
        # redistributed over its own parts and runs an independent
        # multilevel instance.  Best projected separator wins (§3.2).
        pa, pb = child_nprocs(p_cur)
        sa, sb = mix_seeds(seed, 11), mix_seeds(seed, 12)
        cand: List[np.ndarray] = []
        for p_half, s_half in ((pa, sa), (pb, sb)):
            dg_half = distribute(g, p_half) if p_half > 1 else None
            part = _dsep(g, dg_half, p_half, s_half, cfg, inst_budget // 2)
            if part is not None:
                cand.append(part)
        if not cand:
            return None
        best = min(cand, key=lambda p: _eval_part(g, p, cfg.eps_frac)[0])
        # the rejoined group refines the winning duplicate's separator at
        # the fold level with its full complement of FM lanes (§3.3)
        if dg is None:
            dg = distribute(g, p_cur)
        return _band_refine_level(g, dg, best, mix_seeds(seed, 13), p_cur,
                                  cfg)

    if dg is None:
        dg = distribute(g, p_cur)
    match = distributed_matching(dg, mix_seeds(seed, 5), cfg.match_rounds)
    cg, cmap = coarsen_once(g, match)
    if cg.n > g.n * cfg.min_reduction:          # stalled coarsening
        return _coarsest_separator(g, seed, cfg)
    # coarse vertices stay on their representative's owner: the coarse
    # level is shard-aligned without moving any vertex between shards
    cvtx = coarse_vtxdist(dg.vtxdist, match)
    cdg = distribute(cg, p_cur, vtxdist=cvtx)
    part_c = _dsep(cg, cdg, p_cur, mix_seeds(seed, 101), cfg, inst_budget)
    if part_c is None:
        return None
    part = part_c[cmap].astype(np.int8)
    return _band_refine_level(g, dg, part, seed, p_cur, cfg)


def distributed_separator(g: Graph, dg: DGraph, seed: int, nproc: int,
                          cfg: DNDConfig) -> Optional[np.ndarray]:
    """Top-level entry: separator of a distributed graph."""
    if g.n < 4:
        return None
    return _dsep(g, dg, nproc, seed, cfg, max(cfg.k_fm_cap, 1))


# ------------------------------------------------------------------ #
# distributed ND driver
# ------------------------------------------------------------------ #
def distributed_nested_dissection(dg: DGraph, seed: int = 0,
                                  cfg: Optional[DNDConfig] = None
                                  ) -> np.ndarray:
    """Full ordering of a distributed graph.  Returns perm.

    The top levels dissect on the sharded representation; subtrees below
    ``cfg.centralize_threshold`` are gathered and ordered *together* by the
    service scheduler's bucketed breadth-first executor, so the sequential
    endgame of every branch shares its kernel dispatches.
    """
    from repro.service.scheduler import order_batch
    from repro.util import enable_compile_cache
    enable_compile_cache()
    cfg = cfg or DNDConfig()
    g = to_host(dg)
    ordering = Ordering(g.n)
    deferred: List[_Deferred] = []
    _dnd_rec(g, dg, np.arange(g.n, dtype=np.int64), seed, dg.nparts, cfg,
             ordering, ordering.root, 0, deferred)
    if deferred:
        perms = order_batch([d.g for d in deferred],
                            [d.seed for d in deferred],
                            [d.nproc for d in deferred],
                            [cfg] * len(deferred))
        for d, perm in zip(deferred, perms):
            ordering.add_leaf(d.node, d.start, d.gids[perm])
    perm = ordering.assemble()
    assert np.array_equal(np.sort(perm), np.arange(g.n)), "not a permutation"
    return perm


def _dnd_rec(g: Graph, dg: Optional[DGraph], gids: np.ndarray, seed: int,
             nparts: int, cfg: DNDConfig, ordering: Ordering, node,
             start: int, deferred: List[_Deferred]) -> None:
    n = g.n
    if nparts <= 1 or n <= max(cfg.centralize_threshold, cfg.leaf_size):
        # §3.1 centralization: the subtree is sequential from here; defer
        # it so all deferred subtrees batch through the scheduler at once
        deferred.append(_Deferred(g, gids, seed, nparts, node, start))
        return
    comp = g.components()
    ncomp = int(comp.max()) + 1
    if ncomp > 1:                       # independent parts: no separator
        off = start
        for c in range(ncomp):
            sub, old = g.induced_subgraph(comp == c)
            child = ordering.add_internal(node, off, sub.n)
            _dnd_rec(sub, None, gids[old], component_seed(seed, c), nparts,
                     cfg, ordering, child, off, deferred)
            off += sub.n
        return
    if dg is None:
        dg = distribute(g, nparts)
    part = distributed_separator(g, dg, seed, nparts, cfg)
    part = resolve_separator(g, seed, part, cfg)
    if part is None:
        deferred.append(_Deferred(g, gids, seed, 1, node, start))
        return
    (g0, old0), (g1, old1), (gs, olds) = split_by_separator(g, part)
    p0, p1 = child_nprocs(nparts)
    s0, s1 = child_seeds(seed)
    c0 = ordering.add_internal(node, start, g0.n)
    _dnd_rec(g0, None, gids[old0], s0, p0, cfg, ordering, c0, start,
             deferred)
    c1 = ordering.add_internal(node, start + g0.n, g1.n)
    _dnd_rec(g1, None, gids[old1], s1, p1, cfg, ordering, c1,
             start + g0.n, deferred)
    sperm = separator_perm(gs, seed)
    ordering.add_leaf(node, start + g0.n + g1.n, gids[olds[sperm]], "sep")
