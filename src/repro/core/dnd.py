"""Gather-free distributed nested dissection (paper §2.2 + §3),
frontier-batched.

End-to-end *sharded* ordering pipeline: above the centralization
thresholds, every structure the recursion touches stays distributed —

  * **distributed dissection** — separators are computed on the sharded
    ``DGraph`` (multilevel: ``dgraph.distributed_matching`` +
    ``dgraph.dgraph_coarsen`` keep coarse vertices on their
    representative's owner), and the two separated parts are extracted
    with the *distributed induced subgraph* routine
    (``dgraph.dgraph_induced``), each redistributed onto its child
    process group (⌈p/2⌉ / ⌊p/2⌋, paper §3.1) — never through a
    centralized CSR graph;
  * **fold-dup** (§3.2) — once vertices per process drop below
    ``fold_threshold`` the group folds (``dgraph.dgraph_fold``) and two
    duplicate multilevel instances run with independent seeds; the best
    projected separator wins at rejoin and is re-refined by the full
    group;
  * **sharded band refinement** (§3.3) — the band is extracted *in
    place* on each shard from the distributed BFS distances
    (``ell_relax_step`` sweeps, one halo exchange per width step).  Small
    bands (≤ ``band_central_threshold``) are centralized and refined by
    k multi-sequential FM lanes exactly as before; large bands stay
    sharded, refined in alternating-color phases (gid-hash two-coloring,
    at most one movable endpoint per cross-shard edge per phase, ghost
    pulls pushed to owners — conflict-free by construction, asserted);
  * **distributed ordering tree** (§2.2) — ``DistOrdering`` records, per
    ND node, its column-block range in the inverse permutation and, per
    shard, the locally-held ordering fragments, so the inverse
    permutation can be *assembled sharded* (``assemble_sharded``);
  * **centralize threshold** (§3.1) — subtrees below
    ``centralize_threshold`` are gathered and handed, all together, to
    the ordering service's breadth-first scheduler.

**Frontier-batched execution** (DESIGN.md §4).  Every stage above is
written as a *work-yielding generator* (mirroring ``nd.separator_task``):
instead of dispatching collectives, tasks yield typed descriptors —
``DMatchWork`` (one distributed-matching request), ``DBFSWork`` (one
band-distance sweep), ``DHaloWork`` (one host-level halo exchange), plain
``FMWork`` / ``BFSWork`` / ``MatchWork`` for centralized subproblems, and
lists of ``FMWork`` for the per-phase fragment batches of the sharded
band — and receive the results.  Two drivers execute the same generators:

  * the **depth-first driver** (``DNDConfig.frontier=False``) runs each
    work the moment it is yielded and spawned subtasks to completion in
    order — the PR 2–4 recursion's execution order, kept as the
    bit-parity oracle;
  * the **frontier driver** (default) walks the whole task tree in
    readiness *waves*: all live tasks advance until blocked on device
    work, then the wave's outstanding works execute bucketed — every
    same-bucket ``DGraph`` stacks along a lane axis into ONE
    ``shard_map`` launch (``dgraph.*_stacked``), and centralized works
    run through the service's bucketed executors.  Sibling subgraphs,
    fold-dup duplicates and deferred endgames all join the same
    frontier, so per-wave launch count is O(shape buckets), not
    O(live subproblems).

Lane-stacked collectives are bit-identical to singleton execution
(within-lane reductions — same argument as ``execute_fm_works``), so the
two drivers produce **bit-identical orderings**; the frontier tests
assert this plus the per-wave launch budget (launches == buckets) via
``dgraph.instrument()``.

Per-host memory is O(n/p + thresholds): the gather-free tests run the
driver under ``dgraph.track_gathers()`` and assert no centralizing
gather ever exceeds the configured thresholds.  DESIGN.md §4 documents
the pipeline; §4.1 maps the paper's ordering-tree concepts onto
``DistOrdering``.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core import dgraph as _dg
from repro.core.band import BFSWork, band_graph_with_anchors, \
    execute_bfs_works
from repro.core.coarsen import MatchWork, execute_match_works
from repro.core.dgraph import (DGraph, boundary_mask, color_by_gid,
                               dgraph_coarsen, dgraph_fold,
                               dgraph_induced, distributed_bfs_stacked,
                               distributed_matching_stacked,
                               halo_exchange_stacked, np_hash_mix,
                               pull_by_gid, reshard_vector, scatter_by_gid,
                               shard_gids, shard_vector, to_host,
                               unshard_vector, valid_mask)
from repro.core.fm import (FMWork, execute_fm_works, fm_lane_count,
                           separator_is_valid)
from repro.core.graph import Graph
from repro.core.initsep import initial_parts
from repro.core.nd import (NDConfig, child_nprocs, child_seeds,
                           separator_perm, separator_task)
from repro.util import mix_seeds


@dataclasses.dataclass
class DNDConfig(NDConfig):
    """NDConfig + the distributed-pipeline knobs.

    ``centralize_threshold``: subtrees below this size are gathered and
    deferred to the batched sequential endgame (§3.1).
    ``band_central_threshold``: bands at most this size are centralized
    for multi-sequential FM; larger bands are refined sharded.
    ``band_sync_rounds`` / ``band_shard_lanes``: synchronous halo-sync
    rounds and FM lanes per shard of the sharded band refinement.
    ``band_alt_colors``: schedule sharded-band boundary moves by an
    alternating gid-hash two-coloring — each sync round becomes two
    color phases in which every cross-shard edge has at most one movable
    endpoint, so boundary vertices refine without conflicts (the
    lock-all-boundary legacy schedule is the False setting).
    ``band_check_conflicts``: assert the alternating schedule really
    produced zero cross-shard 0–1 conflicts (the repair rule stays as a
    guarded fallback either way).
    ``frontier``: drive the recursion breadth-first with lane-stacked
    wave execution (the default); False replays the depth-first
    one-launch-per-step driver (the bit-parity oracle).
    """
    centralize_threshold: int = 256     # below: gather + defer to scheduler
    match_rounds: int = 8               # distributed matching rounds
    min_reduction: float = 0.97         # coarsening stall bound
    band_central_threshold: int = 2048  # bands ≤ this centralize (§3.3)
    band_sync_rounds: int = 2           # sharded-band halo-sync rounds
    band_shard_lanes: int = 4           # FM lanes per shard (sharded band)
    band_alt_colors: bool = True        # alternating-color boundary moves
    band_check_conflicts: bool = True   # assert zero conflicts under alt
    frontier: bool = True               # wave-batched lane-stacked driver


# ------------------------------------------------------------------ #
# distributed ordering tree (paper §2.2)
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class DistNode:
    """One ND node: a column-block range of the inverse permutation.

    ``start`` / ``size`` delimit the global index range this node's
    subtree orders — fixed at dissection time from the separated part
    sizes, so no later exchange is needed to place fragments.
    """
    parent: int
    start: int
    size: int
    kind: str = "nd"                # "nd" | "sep"


@dataclasses.dataclass
class DistFragment:
    """One shard-held piece of the inverse permutation.

    ``gids`` are original global vertex ids in elimination order;
    ``start`` is the fragment's absolute position (node column-block
    start + the prefix-sum offset of the preceding shards' pieces);
    ``shard`` records which process holds the piece.
    """
    node: int
    start: int
    shard: int
    gids: np.ndarray


class DistOrdering:
    """Distributed ordering tree: fragments + column-block ranges (§2.2).

    Mirrors the paper's structure: "a distributed tree ... every process
    holds the fragments of the inverse permutation computed by the
    subtrees it took part in".  Each ND node carries its column-block
    range; leaves carry per-shard fragments whose absolute offsets are
    prefix sums of fragment sizes — so the inverse permutation exists as
    shard-local slices (``assemble_sharded``) and is only concatenated
    on one host when the caller explicitly asks (``assemble``).
    """

    root = 0

    def __init__(self, n: int, nparts: int):
        self.n = int(n)
        self.nparts = max(int(nparts), 1)
        self.nodes: List[DistNode] = [DistNode(-1, 0, self.n)]
        self.frags: List[DistFragment] = []

    # -------------------------------------------------------------- #
    def add_node(self, parent: int, start: int, size: int,
                 kind: str = "nd") -> int:
        """Create a child node covering [start, start+size); returns id."""
        pn = self.nodes[parent]
        assert pn.start <= start and start + size <= pn.start + pn.size, \
            "child column block escapes parent range"
        self.nodes.append(DistNode(parent, int(start), int(size), kind))
        return len(self.nodes) - 1

    def column_block(self, node_id: int) -> Tuple[int, int]:
        """The node's [start, end) range in the inverse permutation."""
        nd = self.nodes[node_id]
        return nd.start, nd.start + nd.size

    def add_fragment(self, node_id: int, gids: np.ndarray,
                     shard: int) -> None:
        """Attach one whole-node fragment held by ``shard``."""
        nd = self.nodes[node_id]
        assert len(gids) == nd.size, "fragment does not cover its node"
        self.frags.append(DistFragment(node_id, nd.start, int(shard),
                                       np.asarray(gids, np.int64)))

    def add_sharded_fragments(self, node_id: int,
                              pieces: Sequence[np.ndarray]) -> None:
        """Attach one fragment per shard; offsets by prefix-sum exchange.

        ``pieces[q]`` is shard q's locally-held, locally-ordered slice of
        the node's sub-ordering.  Absolute starts are the exclusive
        prefix sum of piece sizes over shard rank — the offset exchange
        the paper performs to glue ordering-tree fragments.
        """
        nd = self.nodes[node_id]
        sizes = [len(p) for p in pieces]
        assert sum(sizes) == nd.size, "shard pieces do not cover the node"
        offs = np.concatenate([[0], np.cumsum(sizes)])
        for q, piece in enumerate(pieces):
            if len(piece):
                self.frags.append(DistFragment(
                    node_id, nd.start + int(offs[q]), q,
                    np.asarray(piece, np.int64)))

    # -------------------------------------------------------------- #
    def assemble(self) -> np.ndarray:
        """Concatenate all fragments into the flat inverse permutation.

        perm[k] = original vertex eliminated k-th.  This is the explicit
        centralization step (for benchmarks / host consumers); the
        pipeline itself never calls it — use ``assemble_sharded`` to keep
        the result distributed.
        """
        perm = np.empty(self.n, dtype=np.int64)
        seen = 0
        for f in sorted(self.frags, key=lambda f: f.start):
            assert f.start == seen, (
                f"fragment at {f.start} overlaps/gaps previous end {seen}")
            perm[f.start:f.start + len(f.gids)] = f.gids
            seen += len(f.gids)
        assert seen == self.n, f"fragments cover {seen} of {self.n}"
        return perm

    def assemble_sharded(self, vtxdist: Optional[np.ndarray] = None
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-shard slices of the inverse permutation (no concatenation).

        Shard q receives global positions [vtxdist[q], vtxdist[q+1]) of
        the inverse permutation (balanced blocks by default).  Every
        fragment knows its absolute start, so routing is a pure local
        write per (fragment, overlapping shard) pair — the paper's
        offset-exchange assembly.  Returns ``(slices, vtxdist)`` where
        ``slices`` is (P, max_slice) with -1 padding.
        """
        if vtxdist is None:
            vtxdist = np.linspace(0, self.n, self.nparts + 1
                                  ).astype(np.int64)
        vtxdist = np.asarray(vtxdist, np.int64)
        P = len(vtxdist) - 1
        width = int(np.diff(vtxdist).max()) if P else 0
        out = -np.ones((P, max(width, 1)), dtype=np.int64)
        for f in self.frags:
            lo, hi = f.start, f.start + len(f.gids)
            q = int(np.searchsorted(vtxdist, lo, side="right") - 1)
            q = max(q, 0)
            while q < P and vtxdist[q] < hi:
                a, b = max(lo, int(vtxdist[q])), min(hi, int(vtxdist[q + 1]))
                if a < b:
                    out[q, a - vtxdist[q]:b - vtxdist[q]] = \
                        f.gids[a - lo:b - lo]
                q += 1
        return out, vtxdist

    def fragment_shards(self) -> np.ndarray:
        """Number of fragments held per shard (bookkeeping / tests)."""
        counts = np.zeros(self.nparts, dtype=np.int64)
        for f in self.frags:
            counts[f.shard % self.nparts] += 1
        return counts


# ------------------------------------------------------------------ #
# separator quality (best-projected-separator-wins, sharded)
# ------------------------------------------------------------------ #
def _eval_part_sh(dg: DGraph, part_sh: np.ndarray, eps_frac: float
                  ) -> Tuple[float, float, float]:
    """(score, sep_w, imb): min separator weight among balance-feasible."""
    v = valid_mask(dg)
    vw = dg.vwgt
    w0 = float(vw[v & (part_sh == 0)].sum())
    w1 = float(vw[v & (part_sh == 1)].sum())
    ws = float(vw[v & (part_sh == 2)].sum())
    imb = abs(w0 - w1)
    total = w0 + w1 + ws
    score = ws if imb <= eps_frac * total else ws + total
    return score, ws, imb


def conflict_loser(vg: np.ndarray, ug: np.ndarray, rnd: int,
                   seed: int) -> np.ndarray:
    """Symmetric loser rule for a conflicted cross-shard 0–1 edge.

    ``True`` where the first endpoint (``vg``) loses and returns to the
    separator.  Both endpoints' owners evaluate the same rule from the
    two global ids alone — no extra messages, like the matching
    protocol's coins — and the rule is *antisymmetric* for distinct
    gids (swapping the arguments flips the result, gid tiebreak on hash
    collisions), so the two shard perspectives always agree on the one
    loser.  Under the alternating-color schedule this is only a guarded
    fallback: the schedule itself admits no conflicts.
    """
    hv = np_hash_mix(vg, rnd, seed & 0x7FFFFFFF)
    hu = np_hash_mix(ug, rnd, seed & 0x7FFFFFFF)
    return (hv < hu) | ((hv == hu) & (vg < ug))


# ------------------------------------------------------------------ #
# band-refinement instrumentation (bench + schedule-invariant tests)
# ------------------------------------------------------------------ #
@contextlib.contextmanager
def track_band_stats():
    """Record one stats dict per sharded-band refinement in the block.

    Compat view over ``dgraph.instrument()`` (its ``band_stats``
    channel).  Each sharded-band task appends ``{"schedule", "n",
    "nparts", "phases", "conflicts" (directed conflict-arc count per
    phase), "repairs" (vertices kicked back to the separator per phase),
    "pulls" (ghost pulls pushed to owners per phase), "anchor_min"
    (smallest rest-of-graph anchor weight seen), "halos" (host-level
    halo exchanges executed)}``.  The bench reports these; the
    gather-free tests assert zero conflicts under the alternating
    schedule and that the per-round halo budget does not grow versus the
    locked-ghost baseline.
    """
    with _dg.instrument() as ins:
        yield ins.band_stats


def _cross_conflicts(bpart: np.ndarray, part_ext: np.ndarray,
                     pb: np.ndarray, lib: np.ndarray, cb: np.ndarray
                     ) -> np.ndarray:
    """Mask of conflicted cross-shard arcs under the exchanged view.

    ``(pb, lib, cb)`` is the refinement's cached cross-shard arc index
    (local endpoint, ghost compact index ≥ n_loc_max); the mask marks
    arcs whose ghost neighbor sits on the opposite 0/1 side.  Every
    conflicted edge shows up once per incident shard, so both owners
    see it and the antisymmetric loser rule picks the same vertex from
    either perspective.
    """
    lp = bpart[pb, lib].astype(np.int32)
    gp_ = part_ext[pb, cb]
    return ((lp == 0) & (gp_ == 1)) | ((lp == 1) & (gp_ == 0))


# ------------------------------------------------------------------ #
# typed device-work descriptors of the distributed data plane
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class DMatchWork:
    """One distributed-matching request; result: (P, n_loc_max) mates."""
    dg: DGraph
    seed: int
    rounds: int = 8


@dataclasses.dataclass
class DBFSWork:
    """One distributed band-BFS request; result: (P, n_loc_max) dists."""
    dg: DGraph
    src: np.ndarray                     # (P, n_loc_max) int32 source mask
    width: int


@dataclasses.dataclass
class DHaloWork:
    """One host-level halo exchange; result: (P, n_loc_max + G) ext."""
    dg: DGraph
    x: np.ndarray                       # (P, n_loc_max)


@dataclasses.dataclass
class _Spawn:
    """Yielded by a task to run subtasks; resumed with their results.

    The depth-first driver runs them to completion in order; the
    frontier driver advances them concurrently — this is how fold-dup
    duplicate instances and the two dissection children of every node
    join the same wave frontier.
    """
    tasks: List


# ------------------------------------------------------------------ #
# band refinement (§3.3): centralized below threshold, sharded above
# ------------------------------------------------------------------ #
def _centralize_band_task(dg: DGraph, part_sh: np.ndarray,
                          dist_sh: np.ndarray, seed: int, k_fm: int,
                          cfg: DNDConfig):
    """Multi-sequential FM on the centralized band (small bands).

    The band subgraph is extracted in place (``dgraph_induced`` with
    ownership preserved), gathered — the band is O(n^{2/3}) on meshes,
    far below ``band_central_threshold`` — and refined by ``k_fm``
    perturbed FM lanes (ONE yielded ``FMWork``); the winning separator
    is scattered back to the owners.  Constructs the exact FM problem
    ``band.extract_band`` would (shared ``band_graph_with_anchors``), so
    this path is bit-identical to the centralized pipeline.
    """
    width = cfg.band_width
    v = valid_mask(dg)
    keep = v & (dist_sh <= width)
    band_dg, (bpart_sh, bdist_sh, bgid_sh) = dgraph_induced(
        dg, keep, payloads=(part_sh, dist_sh, shard_gids(dg)),
        fills=(3, 0, -1))
    g_band = to_host(band_dg)
    bpart = unshard_vector(band_dg, bpart_sh).astype(np.int8)
    bdist = unshard_vector(band_dg, bdist_sh)
    bgid = unshard_vector(band_dg, bgid_sh)

    out = v & ~keep
    w_out0 = int(dg.vwgt[out & (part_sh == 0)].sum())
    w_out1 = int(dg.vwgt[out & (part_sh == 1)].sum())
    band, bpart_full, locked = band_graph_with_anchors(
        g_band, bpart, bdist, width, w_out0, w_out1)
    nbr_b, _ = band.to_ell()
    bref, _, _ = yield FMWork(
        nbr=nbr_b, vwgt=band.vwgt, part=bpart_full, locked=locked,
        seed=mix_seeds(seed, 7), k_inst=k_fm, eps_frac=cfg.eps_frac,
        passes=cfg.fm_passes, n_pert=8)
    assert separator_is_valid(nbr_b, bref)

    return scatter_by_gid(dg, part_sh, bgid, bref[:g_band.n])


def _sharded_band_task(dg: DGraph, part_sh: np.ndarray, keep_sh: np.ndarray,
                       dist_sh: np.ndarray, seed: int, cfg: DNDConfig):
    """Shard-local band FM with alternating-color boundary moves (§3.3).

    The band stays sharded: each shard refines the fragment it owns,
    with its ghost ring present but *locked* (remote-owned vertices
    cannot be moved locally) and per-side anchors carrying the rest of
    the graph's weight, so boundary gains and global balance are exact.

    **Schedule** (``band_alt_colors``, default): boundary vertices are
    two-colored by a gid hash and each sync round runs as two *color
    phases* — phase ``ph`` unlocks local boundary vertices of color
    ``ph % 2`` while the opposite color (and, as always, every ghost
    copy) stays locked; of a *monochromatic* cross-shard pair only the
    (hash, gid)-larger endpoint is ever unlocked.  Every cross-shard
    edge therefore has at most one movable endpoint per phase.  When a
    movable vertex drags a locked ghost into the separator, the pull is
    *pushed to the owner* (an owner-routed O(pulled) message — pushes
    only ever move vertices to the separator, so concurrent pushes
    cannot disagree), which makes the fragment-local FM accounting
    globally exact and leaves the phase with **zero** cross-shard 0–1
    conflicts — checked as an invariant each phase.  All shard
    fragments of a phase are yielded as ONE ``FMWork`` list (bucketed
    into one fused-FM kernel dispatch — ``kernels.fm_fused``, mode
    switch ``REPRO_FM_MODE``; under the frontier driver the list batches
    with every other live band refinement of the wave, regardless of the
    fragments' per-lane move budgets since ``max_moves`` left the bucket
    key), and
    one halo exchange per phase both verifies the invariant and feeds
    the next phase — the same per-round exchange budget as the legacy
    schedule.

    The legacy schedule (``band_alt_colors=False``) keeps every local
    vertex movable every round and repairs concurrent-move conflicts
    after the fact with the symmetric hash rule (``conflict_loser``,
    the losing endpoint returns to the separator); under the
    alternating schedule that repair survives only as a guarded
    fallback behind the zero-conflict assertion.
    """
    width = cfg.band_width
    band_dg, (bpart_sh, bdist_sh, bgid_sh) = dgraph_induced(
        dg, keep_sh, payloads=(part_sh, dist_sh, shard_gids(dg)),
        fills=(3, 0, -1))
    P = band_dg.nparts
    nlm = band_dg.n_loc_max
    vwgt_ext = np.asarray((yield DHaloWork(band_dg,
                                           band_dg.vwgt.astype(np.int32))))
    band_gid = shard_gids(band_dg)      # band-graph ids (colors, repair)
    vb = valid_mask(band_dg)

    # out-of-band side weights never change during band refinement; the
    # in-band side weights do, so global totals recompute every phase
    v_full = valid_mask(dg)
    out_full = v_full & ~np.asarray(keep_sh, bool)
    w_out = [int(dg.vwgt[out_full & (part_sh == s)].sum()) for s in (0, 1)]
    bpart = np.asarray(bpart_sh, np.int8).copy()
    bdist = np.asarray(bdist_sh)

    # cross-shard arc index (fixed for the whole refinement): shared by
    # the per-round yield rule, the conflict check and the repair rule
    pb, lib, slb = np.nonzero(band_dg.nbr_gst >= nlm)
    cb = band_dg.nbr_gst[pb, lib, slb].astype(np.int64)
    vg_b = band_gid[pb, lib]
    ug_b = band_dg.ghost_gid[pb, cb - nlm]

    alt = cfg.band_alt_colors and P > 1
    if alt:
        bmask = boundary_mask(band_dg)

    n_phases = (2 if alt else 1) * cfg.band_sync_rounds

    stats = {"schedule": "alt" if alt else "locked", "n": band_dg.n_global,
             "nparts": P, "phases": n_phases, "conflicts": [],
             "repairs": [], "pulls": [], "anchor_min": None,
             "halos": 2 + (1 if alt else 0)}    # vwgt + initial + colors

    # phase-invariant fragment structure, built once per shard: only the
    # anchor edges and the part/weight views change between phases
    frag_base: List[Optional[Tuple]] = []
    for p in range(P):
        n_p = int(band_dg.n_loc[p])
        if n_p == 0:
            frag_base.append(None)
            continue
        G_p = int(band_dg.n_ghost[p])
        rows = band_dg.nbr_gst[p, :n_p]
        li, sl = np.nonzero(rows >= 0)
        c = rows[li, sl].astype(np.int64)
        tgt = np.where(c < nlm, c, n_p + (c - nlm))
        frag_base.append((n_p, G_p, np.stack([li, tgt], 1),
                          bdist[p, :n_p], band_dg.vwgt[p, :n_p],
                          vwgt_ext[p, nlm:nlm + G_p]))

    part_ext = np.asarray((yield DHaloWork(band_dg,
                                           bpart.astype(np.int32))))
    color = yield_to_nbr = None
    for ph in range(n_phases):
        if alt and ph % 2 == 0:
            # round r's coloring + yield set (salt rotates per round): a
            # fixed coloring would freeze the same tiebreak losers for
            # the whole refinement (dense boundaries starve); rotating
            # the hash salt per sync round unlocks a different subset
            # each round while the per-phase at-most-one-movable-endpoint
            # invariant still holds (the coloring is constant within a
            # round).  Only round 0's ghost colors are halo-validated —
            # later colorings are the same pure gid hash, recomputable
            # locally.
            r = ph // 2
            hash_ext, color_ext = color_by_gid(
                band_dg, mix_seeds(seed, 29, r), exchange=False)
            if r == 0:
                col_ext = np.asarray((yield DHaloWork(
                    band_dg, color_ext[:, :nlm].astype(np.int32))))
                gok = band_dg.ghost_gid >= 0
                assert np.array_equal(
                    np.where(gok, col_ext[:, nlm:], 0),
                    np.where(gok, color_ext[:, nlm:].astype(np.int32), 0)
                ), "halo-exchanged ghost colors disagree with the gid hash"
            # monochromatic cross-shard pairs: the (hash, gid)-smaller
            # endpoint yields to its neighbor this round, so those edges
            # too have at most one movable endpoint in their color's phase
            hv_b, hu_b = hash_ext[pb, lib], hash_ext[pb, cb]
            mono = color_ext[pb, lib] == color_ext[pb, cb]
            u_wins = mono & ((hu_b > hv_b)
                             | ((hu_b == hv_b) & (ug_b > vg_b)))
            yield_to_nbr = np.zeros((P, nlm), bool)
            yield_to_nbr[pb[u_wins], lib[u_wins]] = True
            color = color_ext[:, :nlm]
        w_glob = [w_out[s] + int(band_dg.vwgt[vb & (bpart == s)].sum())
                  for s in (0, 1)]
        works: List[FMWork] = []
        shards: List[Tuple[int, np.ndarray]] = []
        for p in range(P):
            if frag_base[p] is None:
                continue
            n_p, G_p, edges0, ldist, lw, gw = frag_base[p]
            edges = edges0
            lpart = bpart[p, :n_p]
            gpart = part_ext[p, nlm:nlm + G_p]
            a0, a1 = n_p + G_p, n_p + G_p + 1
            for s, a in ((0, a0), (1, a1)):
                ll = np.nonzero((ldist == width) & (lpart == s))[0]
                if len(ll):
                    edges = np.concatenate(
                        [edges, np.stack([np.full(len(ll), a), ll], 1)])
            frag_w = [int(lw[lpart == s].sum()) + int(gw[gpart == s].sum())
                      for s in (0, 1)]
            # rest-of-graph anchors: the residual of the freshly
            # recomputed global side totals over the fragment's share.
            # The totals are recomputed from the live part vector every
            # phase (repair kicks and ghost-pull pushes included), so a
            # negative residual can only mean broken round-weight
            # accounting — assert instead of clamping the drift away.
            anchor_w = [w_glob[s] - frag_w[s] for s in (0, 1)]
            assert min(anchor_w) >= 0, (
                f"band round-weight drift: shard {p} phase {ph} holds "
                f"side weights {frag_w} exceeding globals {w_glob}")
            stats["anchor_min"] = (min(anchor_w)
                                   if stats["anchor_min"] is None
                                   else min(stats["anchor_min"],
                                            *anchor_w))
            locked = np.zeros(n_p + G_p + 2, bool)
            locked[n_p:] = True                 # ghosts + anchors
            if alt:
                locked[:n_p] = bmask[p, :n_p] & (
                    (color[p, :n_p] != ph % 2) | yield_to_nbr[p, :n_p])
            if not np.any((lpart == 2) & ~locked[:n_p]):
                continue        # no movable separator vertex: FM no-ops
            frag = Graph.from_edges(n_p + G_p + 2, edges)
            vwgt_f = np.concatenate([lw, gw, anchor_w])
            part_f = np.concatenate([lpart, gpart, [0, 1]]).astype(np.int8)
            nbr_f, _ = frag.to_ell()
            works.append(FMWork(
                nbr=nbr_f, vwgt=vwgt_f, part=part_f, locked=locked,
                seed=mix_seeds(seed, 41, ph, p),
                k_inst=cfg.band_shard_lanes, eps_frac=cfg.eps_frac,
                passes=cfg.fm_passes, n_pert=8))
            shards.append((p, gpart))
        if not works:
            if not alt:
                break           # nothing can ever move again
            stats["conflicts"].append(0)
            stats["repairs"].append(0)
            stats["pulls"].append(0)
            continue            # the other color phase may still refine
        fm_out = yield works    # ONE bucketed dispatch (wave-batched)
        pull_gids: List[np.ndarray] = []
        for (p, gpart_in), (pf, _, _) in zip(shards, fm_out):
            n_p = int(band_dg.n_loc[p])
            G_p = int(band_dg.n_ghost[p])
            bpart[p, :n_p] = pf[:n_p]
            if alt:
                # ghost pulls: local moves dragged these locked remote
                # vertices into the separator; push the pulls to the
                # owners so the fragment accounting is globally real
                pulled = (pf[n_p:n_p + G_p] == 2) & (gpart_in <= 1)
                if pulled.any():
                    pull_gids.append(band_dg.ghost_gid[p, :G_p][pulled])
        n_pulls = 0
        if pull_gids:
            pg_all = np.concatenate(pull_gids)
            n_pulls = len(pg_all)
            bpart = scatter_by_gid(band_dg, bpart, pg_all,
                                   np.full(n_pulls, 2, np.int8))
        stats["pulls"].append(n_pulls)

        # one halo exchange per phase: provides this phase's cross-shard
        # view for the conflict check AND the ghost parts of the next
        # phase — the per-round exchange budget of the legacy schedule
        part_ext = np.asarray((yield DHaloWork(band_dg,
                                               bpart.astype(np.int32))))
        stats["halos"] += 1
        cmask = _cross_conflicts(bpart, part_ext, pb, lib, cb)
        n_conf = int(cmask.sum())
        stats["conflicts"].append(n_conf)
        n_rep = 0
        if n_conf:
            assert not (alt and cfg.band_check_conflicts), (
                f"alternating-color schedule produced {n_conf} "
                f"cross-shard 0-1 conflict arcs in phase {ph}: the "
                "at-most-one-movable-endpoint invariant is broken")
            # guarded fallback (the legacy schedule's repair): the
            # endpoint losing the symmetric hash rule returns to the
            # separator — both owners compute the same loser from the
            # two gids alone, so validity is restored without messages
            lose_local = conflict_loser(vg_b[cmask], ug_b[cmask], ph, seed)
            pk, lk = pb[cmask][lose_local], lib[cmask][lose_local]
            # a vertex losing on several arcs is kicked once
            n_rep = len(np.unique(pk.astype(np.int64) * nlm + lk))
            bpart[pk, lk] = 2
            part_ext = np.asarray((yield DHaloWork(
                band_dg, bpart.astype(np.int32))))
            stats["halos"] += 1
        stats["repairs"].append(n_rep)
    _dg._note_band_stats(stats)

    # project back: each shard writes its refined local band parts to the
    # owners of the original vertices (carried in the bgid payload)
    return scatter_by_gid(dg, part_sh, np.asarray(bgid_sh)[vb], bpart[vb])


def _band_refine_task(dg: DGraph, part_sh: np.ndarray, seed: int,
                      p_cur: int, cfg: DNDConfig):
    """§3.3 at one distributed level: sharded BFS + FM refinement.

    The distance sweep always runs on the sharded structure (one halo
    exchange per width step, reusing ``ell_relax_step``); the refinement
    path depends on the band size: centralized multi-sequential lanes
    below ``band_central_threshold``, shard-local FM above.
    """
    k_fm = fm_lane_count(p_cur, cfg.k_fm_cap, cfg.fold_dup)
    v = valid_mask(dg)
    if cfg.use_band:
        dist_sh = np.asarray((yield DBFSWork(
            dg, (part_sh == 2).astype(np.int32), cfg.band_width)))
        dist_sh = np.where(v, dist_sh, np.int32(2 ** 30))
        keep = v & (dist_sh <= cfg.band_width)
    else:                               # ablation: refine the whole level
        dist_sh = np.zeros_like(part_sh, dtype=np.int32)
        keep = v
    band_n = int(keep.sum())
    if band_n + 2 <= cfg.band_central_threshold or dg.nparts == 1:
        if cfg.use_band:
            return (yield from _centralize_band_task(dg, part_sh, dist_sh,
                                                     seed, k_fm, cfg))
        g = to_host(dg)
        part = unshard_vector(dg, part_sh).astype(np.int8)
        nbr_f, _ = g.to_ell()
        part, _, _ = yield FMWork(
            nbr=nbr_f, vwgt=g.vwgt, part=part,
            locked=np.zeros(g.n, bool), seed=mix_seeds(seed, 7),
            k_inst=k_fm, eps_frac=cfg.eps_frac, passes=cfg.fm_passes,
            n_pert=8)
        assert separator_is_valid(nbr_f, part)
        return shard_vector(dg, part, fill=3)
    return (yield from _sharded_band_task(dg, part_sh, keep, dist_sh, seed,
                                          cfg))


def _band_refine_level_sh(dg: DGraph, part_sh: np.ndarray, seed: int,
                          p_cur: int, cfg: DNDConfig) -> np.ndarray:
    """Synchronous wrapper over ``_band_refine_task`` (tests, ablation)."""
    return _drive_depth_first(_band_refine_task(dg, part_sh, seed, p_cur,
                                                cfg))


# ------------------------------------------------------------------ #
# distributed multilevel separator
# ------------------------------------------------------------------ #
def _coarsest_task(g: Graph, seed: int, cfg: DNDConfig):
    """Initial separator on a (centralized) coarsest graph.

    The one FM refinement is yielded, so coarsest separators of every
    live branch share a bucketed dispatch under the frontier driver.
    """
    if g.n < 4:
        return None
    parts0 = initial_parts(g, seed, k_tries=min(cfg.k_init, 32))
    nbr, _ = g.to_ell()
    part, _, _ = yield FMWork(
        nbr=nbr, vwgt=g.vwgt, part=parts0[0], locked=np.zeros(g.n, bool),
        seed=mix_seeds(seed, 0), k_inst=len(parts0), eps_frac=cfg.eps_frac,
        passes=3, n_pert=4, parts_init=parts0)
    assert separator_is_valid(nbr, part)
    return part


def _centralized_part(dg: DGraph, part: Optional[np.ndarray]
                      ) -> Optional[np.ndarray]:
    """Shard a host-computed part vector back onto dg's layout."""
    if part is None:
        return None
    return shard_vector(dg, part.astype(np.int8), fill=3)


def _dsep_task(dg: DGraph, seed: int, cfg: DNDConfig, inst_budget: int):
    """Multilevel separator of a sharded graph, as a work-yielding task.

    Returns a (P, n_loc_max) int8 part vector (0/1/2, 3 on padding) or
    None when degenerate.  ``inst_budget`` caps the fold-dup instance
    tree (paper: "resort to folding only when ... reaches some minimum
    threshold" — here also a memory cap, mirroring
    ``coarsen_multilevel``'s ``max_instances``).  Centralization only
    happens at bounded sizes: fully-folded instances (n < 2·fold
    threshold) and coarsest graphs (n ≤ coarse_target).  Fully-folded
    single-process instances run ``nd.separator_task`` *inline* (via
    ``yield from``), so their matching / BFS / FM works batch with the
    rest of the frontier.
    """
    p, n = dg.nparts, dg.n_global
    if n < 4:
        return None
    if p <= 1:
        # a fully-folded instance: one process, the sequential pipeline
        part = yield from separator_task(to_host(dg), seed, 1, cfg)
        return _centralized_part(dg, part)
    if n <= cfg.coarse_target:
        part = yield from _coarsest_task(to_host(dg), seed, cfg)
        return _centralized_part(dg, part)

    if cfg.fold_dup and n / p < cfg.fold_threshold and inst_budget >= 2:
        # fold-dup: the group splits; each half holds a duplicate of the
        # folded structure and runs an independent multilevel instance.
        # Best projected separator wins at rejoin (§3.2).  The two
        # halves are spawned as sibling tasks, so under the frontier
        # driver their device waves lane-stack with each other (and with
        # every other live instance of the tree).
        dgf = dgraph_fold(dg)
        halves = yield _Spawn([
            _dsep_task(dgf, s_half, cfg, inst_budget // 2)
            for s_half in (mix_seeds(seed, 11), mix_seeds(seed, 12))])
        cand = [ph for ph in halves if ph is not None]
        if not cand:
            return None
        best = min(cand,
                   key=lambda q: _eval_part_sh(dgf, q, cfg.eps_frac)[0])
        # the rejoined group refines the winning duplicate's separator at
        # the fold level with its full complement of FM lanes (§3.3)
        part_sh = reshard_vector(dgf, dg, best, fill=3)
        return (yield from _band_refine_task(dg, part_sh,
                                             mix_seeds(seed, 13), p, cfg))

    match_sh = yield DMatchWork(dg, mix_seeds(seed, 5), cfg.match_rounds)
    cdg, cmap_sh = dgraph_coarsen(dg, match_sh)
    if cdg.n_global > n * cfg.min_reduction:    # stalled coarsening
        if n <= max(cfg.centralize_threshold, cfg.coarse_target):
            part = yield from _coarsest_task(to_host(dg), seed, cfg)
            return _centralized_part(dg, part)
        if cdg.n_global >= n:
            return None
        # slow but nonzero progress on a big graph: keep going sharded
    part_c = yield from _dsep_task(cdg, mix_seeds(seed, 101), cfg,
                                   inst_budget)
    if part_c is None:
        return None
    # separator projection: fine vertex reads its coarse vertex's part
    # from the coarse owner (coarse vertices stayed on their
    # representative's owner, so most reads are shard-local)
    part_sh = pull_by_gid(cdg, part_c, cmap_sh, fill=3).astype(np.int8)
    return (yield from _band_refine_task(dg, part_sh, seed, p, cfg))


def distributed_separator(dg: DGraph, seed: int,
                          cfg: Optional[DNDConfig] = None
                          ) -> Optional[np.ndarray]:
    """Top-level entry: sharded separator of a distributed graph.

    Returns the (P, n_loc_max) int8 part vector (0/1/2, padding 3) or
    None when the graph is degenerate.  Drives ``_dsep_task`` depth-first
    (the frontier batching lives in ``distributed_nested_dissection``'s
    driver, which owns a whole task tree).
    """
    cfg = cfg or DNDConfig()
    return _drive_depth_first(_dsep_task(dg, seed, cfg,
                                         max(cfg.k_fm_cap, 1)))


def _fallback_task(dg: DGraph):
    """Validity-first fallback: gid bisection, boundary into separator.

    Mirrors ``nd._fallback_separator``'s role when the multilevel
    heuristic degenerates on a big subgraph, without centralizing: side
    by global-id rank, then every side-1 vertex adjacent to side 0 (ghost
    parts via one halo exchange) moves into the separator — no 0–1 edge
    survives, on any shard.
    """
    gid = shard_gids(dg)
    valid = gid >= 0
    part = np.where(gid < dg.n_global // 2, 0, 1).astype(np.int8)
    part[~valid] = 3
    ext = np.asarray((yield DHaloWork(dg, part.astype(np.int32))))
    p, li, sl = np.nonzero(dg.nbr_gst >= 0)
    c = dg.nbr_gst[p, li, sl].astype(np.int64)
    nbr_part = ext[p, c]
    mine = part[p, li]
    to_sep = (mine == 1) & (nbr_part == 0)
    part[p[to_sep], li[to_sep]] = 2
    return part


def _resolve_task(dg: DGraph, part_sh: Optional[np.ndarray],
                  cfg: DNDConfig):
    """Degenerate-separator policy of the sharded recursion."""
    v = valid_mask(dg)

    def degenerate(ps):
        return ps is None or min(int(((ps == 0) & v).sum()),
                                 int(((ps == 1) & v).sum())) == 0

    if degenerate(part_sh):
        if dg.n_global > 4 * cfg.leaf_size:
            part_sh = yield from _fallback_task(dg)
        if degenerate(part_sh):
            return None
    return part_sh


# ------------------------------------------------------------------ #
# distributed ND task tree
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class _Deferred:
    """One centralized subtree, ordered later by the batched scheduler."""
    g: Graph
    gids: np.ndarray
    seed: int
    nproc: int
    node: int
    shard: int


def _defer(dg: DGraph, gids_sh: np.ndarray, seed: int, nproc: int,
           node_id: int, dord: DistOrdering,
           deferred: List[_Deferred]) -> None:
    """§3.1 centralization: gather a sub-threshold subtree for the batch.

    The subtree is assigned (round-robin by node id) to the shard that
    will hold its ordering fragment in the distributed tree.
    """
    g = to_host(dg)
    gids = unshard_vector(dg, gids_sh)
    deferred.append(_Deferred(g, gids, seed, nproc, node_id,
                              node_id % dord.nparts))


def _dnd_task(dg: DGraph, gids_sh: np.ndarray, seed: int, cfg: DNDConfig,
              dord: DistOrdering, node_id: int,
              deferred: List[_Deferred]):
    """One ND tree node as a task: separator, split, spawn the children."""
    p, n = dg.nparts, dg.n_global
    start = dord.nodes[node_id].start
    if p <= 1 or n <= max(cfg.centralize_threshold, cfg.leaf_size):
        # the subtree is sequential from here; defer it so all deferred
        # subtrees batch through the scheduler at once
        _defer(dg, gids_sh, seed, p, node_id, dord, deferred)
        return
    part_sh = yield from _dsep_task(dg, seed, cfg, max(cfg.k_fm_cap, 1))
    part_sh = yield from _resolve_task(dg, part_sh, cfg)
    if part_sh is None:
        _defer(dg, gids_sh, seed, 1, node_id, dord, deferred)
        return
    v = valid_mask(dg)
    n0 = int(((part_sh == 0) & v).sum())
    n1 = int(((part_sh == 1) & v).sum())
    ns = n - n0 - n1
    p0, p1 = child_nprocs(p)
    s0, s1 = child_seeds(seed)
    # distributed induced subgraphs, each redistributed onto its child
    # process group (§3.1: part 0 onto ⌈p/2⌉ processes, part 1 onto ⌊p/2⌋)
    dg0, (g0ids,) = dgraph_induced(dg, (part_sh == 0) & v, nparts=p0,
                                   payloads=(gids_sh,), fills=(-1,))
    dg1, (g1ids,) = dgraph_induced(dg, (part_sh == 1) & v, nparts=p1,
                                   payloads=(gids_sh,), fills=(-1,))
    c0 = dord.add_node(node_id, start, n0)
    c1 = dord.add_node(node_id, start + n0, n1)

    # separator ordered last (highest indices of the column block)
    if ns:
        snode = dord.add_node(node_id, start + n0 + n1, ns, "sep")
        if ns <= max(cfg.centralize_threshold, cfg.leaf_size):
            dgs, (sgids_sh,) = dgraph_induced(dg, (part_sh == 2) & v,
                                              nparts=1,
                                              payloads=(gids_sh,),
                                              fills=(-1,))
            gs = to_host(dgs)
            sgids = unshard_vector(dgs, sgids_sh)
            dord.add_fragment(snode, sgids[separator_perm(gs, seed)],
                              node_id % dord.nparts)
        else:
            # huge separator: each shard keeps its local fragment,
            # ordered by local id; offsets by the §2.2 prefix-sum exchange
            pieces = [gids_sh[q][v[q] & (part_sh[q] == 2)]
                      for q in range(p)]
            dord.add_sharded_fragments(snode, pieces)

    # the two sides are independent subtrees (paper §3.1): spawned as
    # sibling tasks so the frontier driver advances them concurrently
    yield _Spawn([_dnd_task(dg0, g0ids, s0, cfg, dord, c0, deferred),
                  _dnd_task(dg1, g1ids, s1, cfg, dord, c1, deferred)])


# ------------------------------------------------------------------ #
# drivers: depth-first (oracle) and frontier (wave-batched)
# ------------------------------------------------------------------ #
def _execute_one(work):
    """Singleton execution of one yielded work (the depth-first driver).

    Runs exactly the program the frontier driver would run for a
    one-lane bucket, so the two drivers stay bit-identical.
    """
    if isinstance(work, list):          # per-phase band fragment batch
        return execute_fm_works(work)
    if isinstance(work, FMWork):
        return execute_fm_works([work])[0]
    if isinstance(work, BFSWork):
        return execute_bfs_works([work])[0]
    if isinstance(work, MatchWork):
        return execute_match_works([work])[0]
    if isinstance(work, DMatchWork):
        return distributed_matching_stacked([work.dg], [work.seed],
                                            work.rounds)[0]
    if isinstance(work, DBFSWork):
        return distributed_bfs_stacked([work.dg], [work.src],
                                       work.width)[0]
    if isinstance(work, DHaloWork):
        return halo_exchange_stacked([work.dg], [work.x])[0]
    raise TypeError(f"unknown work kind: {type(work).__name__}")


def _drive_depth_first(gen):
    """Depth-first driver: the PR 2–4 recursion's execution order.

    Every yielded work executes immediately as a singleton; spawned
    subtasks run to completion in order.  One launch per device step —
    the oracle the frontier driver is asserted bit-identical against.
    """
    try:
        item = next(gen)
        while True:
            if isinstance(item, _Spawn):
                res = [_drive_depth_first(sub) for sub in item.tasks]
            else:
                res = _execute_one(item)
            item = gen.send(res)
    except StopIteration as stop:
        return stop.value


def _execute_wave(works: List, level: Optional[int] = None
                  ) -> Tuple[List, dict]:
    """Compat adapter: one wave through the service wave router.

    The wave executor moved to ``repro.service.router.execute_wave``
    (the unified-router refactor); this thin forwarder keeps the old
    ``core.dnd`` entry point alive for existing callers and tests.
    Imported lazily — ``core`` never imports ``service`` at module
    scope.
    """
    from repro.service.router import execute_wave
    return execute_wave(works, level=level)


def _drive_frontier(root_gen):
    """Compat adapter: drive ONE task tree through a private router.

    The frontier driver moved to ``repro.service.router.WaveRouter``,
    which owns the shared lane stacks of *all* concurrently-submitted
    orderings; a single-tree drive is now the one-request special case.
    """
    from repro.service.router import drive_frontier
    return drive_frontier(root_gen)


# ------------------------------------------------------------------ #
# distributed ND entry points
# ------------------------------------------------------------------ #
def distributed_order_batch(dgs: List[DGraph], seeds=0, cfgs=None,
                            return_trees: bool = False):
    """Order N distributed graphs concurrently through ONE wave router.

    Every request's task tree is submitted to a shared
    ``repro.service.router.WaveRouter``, so each wave gathers the
    outstanding device works of ALL requests and dispatches each shape
    bucket once — lanes from different requests stack into the same
    ``shard_map`` launch.  Per-lane results are pure functions of the
    lane's inputs, so each ordering is bit-identical to draining it
    alone (asserted in ``tests/test_router.py``).  The centralized
    endgames of all requests merge into a single ``order_batch`` call,
    sharing their matching / BFS / FM dispatches across requests too.

    Args:
      dgs: sharded input graphs; requests may differ in size and seed.
      seeds: one int for all, or one per request.
      cfgs: one ``DNDConfig`` per request (None → defaults).  All
        requests must use the frontier driver (``cfg.frontier=True``);
        the DFS oracle is inherently one-at-a-time.
      return_trees: return ``DistOrdering`` trees instead of perms.

    Returns a list of permutations (or trees), one per request.
    """
    from repro.service.router import WaveRouter
    from repro.service.scheduler import order_batch
    from repro.util import enable_compile_cache
    enable_compile_cache()
    n = len(dgs)
    if isinstance(seeds, int):
        seeds = [seeds] * n
    if cfgs is None:
        cfgs = [DNDConfig() for _ in range(n)]
    assert len(seeds) == n and len(cfgs) == n
    assert all(c.frontier for c in cfgs), \
        "distributed_order_batch requires the frontier driver"
    dords = [DistOrdering(dg.n_global, dg.nparts) for dg in dgs]
    deferreds: List[List[_Deferred]] = [[] for _ in range(n)]
    router = WaveRouter()
    with obs.span("dnd", requests=n,
                  n=int(sum(dg.n_global for dg in dgs)),
                  driver="frontier"):
        for i, (dg, seed, cfg) in enumerate(zip(dgs, seeds, cfgs)):
            root = _dnd_task(dg, shard_gids(dg), seed, cfg, dords[i],
                             DistOrdering.root, deferreds[i])
            router.submit(root, tag=i)
        router.run()
        # ONE merged endgame: the gathered subtrees of every request
        # drain through the scheduler's bucketed executor together
        flat = [(i, d) for i, ds in enumerate(deferreds) for d in ds]
        if flat:
            with _dg.stage("endgame"):
                perms = order_batch([d.g for _, d in flat],
                                    [d.seed for _, d in flat],
                                    [d.nproc for _, d in flat],
                                    [cfgs[i] for i, _ in flat],
                                    tags=[i for i, _ in flat])
            for (i, d), perm in zip(flat, perms):
                dords[i].add_fragment(d.node, d.gids[perm], d.shard)
    if return_trees:
        return dords
    out = []
    for dg, dord in zip(dgs, dords):
        perm = dord.assemble()
        assert np.array_equal(np.sort(perm), np.arange(dg.n_global)), \
            "not a permutation"
        out.append(perm)
    return out


def distributed_order_task(dg: DGraph, seed: int, cfg: DNDConfig,
                           hints=None, rec=None):
    """One distributed request as a single suspendable task tree.

    The incremental (pump-driven) counterpart of
    ``distributed_order_batch``: the whole request — top sharded
    dissection AND its centralized endgame — is one composite generator
    a service ``WaveRouter`` can park and resume at any wave boundary.
    The endgame subtrees spawn as ``scheduler._nd_node_task`` siblings
    the moment this request's top tree finishes, so they share waves
    with whatever else is live on the router (the cross-request endgame
    merge happens per-wave rather than in one deferred batch — same
    per-lane computations, bit-identical orderings).

    ``hints`` / ``rec`` carry the warm-start surface into the endgame:
    each deferred subtree's splits are recorded under (and replayed
    from) paths prefixed ``n<node-id>``, which are stable across
    structurally identical runs because the deferred node ids are
    determined by the recursion shape — and the recursion shape is
    replayed from the same splits.  The sharded top-level separators
    are not warm-started (their part vectors live sharded; see
    DESIGN.md §7 invariants).

    Returns the completed ``DistOrdering`` (assembly is the caller's —
    the service assembles outside the router so parked requests never
    block it).
    """
    from repro.service.scheduler import _nd_node_task
    from repro.core.ordering import Ordering
    dord = DistOrdering(dg.n_global, dg.nparts)
    deferred: List[_Deferred] = []
    yield _Spawn([_dnd_task(dg, shard_gids(dg), seed, cfg, dord,
                            DistOrdering.root, deferred)])
    if deferred:
        orderings = [Ordering(d.g.n) for d in deferred]
        yield _Spawn([
            _nd_node_task(d.g, np.arange(d.g.n, dtype=np.int64), d.seed,
                          d.nproc, cfg, o, o.root, 0, hints=hints,
                          rec=rec, path=f"n{d.node}")
            for d, o in zip(deferred, orderings)])
        for d, o in zip(deferred, orderings):
            perm = o.assemble()
            dord.add_fragment(d.node, d.gids[perm], d.shard)
    return dord


def distributed_nested_dissection(dg: DGraph, seed: int = 0,
                                  cfg: Optional[DNDConfig] = None,
                                  return_tree: bool = False):
    """Full gather-free ordering of a distributed graph.

    Args:
      dg: the sharded input graph (P shards).
      seed: deterministic seed; the whole pipeline (matching coins, FM
        perturbations, tiebreaks) derives from it, so equal (dg, seed,
        cfg) give identical orderings.
      cfg: DNDConfig; None uses defaults.  ``cfg.frontier`` picks the
        driver; both drivers return bit-identical orderings (asserted in
        the frontier tests), the frontier one in O(buckets) launches per
        wave instead of O(live subproblems).
      return_tree: return the ``DistOrdering`` (fragments stay sharded)
        instead of the flat permutation.

    The top levels dissect on the sharded representation — no
    ``to_host`` / ``unshard_vector`` above the configured thresholds, as
    asserted by the gather-free tests under ``dgraph.track_gathers()``.
    The frontier path is the one-request special case of
    ``distributed_order_batch``; the DFS path (``cfg.frontier=False``)
    keeps its own depth-first oracle drive.  Subtrees below
    ``cfg.centralize_threshold`` are gathered and ordered *together* by
    the service scheduler's bucketed breadth-first executor.  Returns
    perm (perm[k] = vertex eliminated k-th) unless ``return_tree``.
    """
    cfg = cfg or DNDConfig()
    if cfg.frontier:
        return distributed_order_batch([dg], [seed], [cfg],
                                       return_trees=return_tree)[0]
    from repro.service.scheduler import order_batch
    from repro.util import enable_compile_cache
    enable_compile_cache()
    dord = DistOrdering(dg.n_global, dg.nparts)
    deferred: List[_Deferred] = []
    root = _dnd_task(dg, shard_gids(dg), seed, cfg, dord,
                     DistOrdering.root, deferred)
    with obs.span("dnd", n=dg.n_global, nparts=dg.nparts, seed=seed,
                  driver="dfs"):
        _drive_depth_first(root)
        if deferred:
            with _dg.stage("endgame"):
                perms = order_batch([d.g for d in deferred],
                                    [d.seed for d in deferred],
                                    [d.nproc for d in deferred],
                                    [cfg] * len(deferred))
            for d, perm in zip(deferred, perms):
                dord.add_fragment(d.node, d.gids[perm], d.shard)
    if return_tree:
        return dord
    perm = dord.assemble()
    assert np.array_equal(np.sort(perm), np.arange(dg.n_global)), \
        "not a permutation"
    return perm
