"""Synchronous probabilistic heavy-edge matching (paper §3.2), in JAX.

The paper's request/grant protocol maps one-to-one onto data-parallel rounds:

  * every unmatched vertex picks a mating candidate among its unmatched
    neighbors, "randomly chosen among vertices linked by edges of heaviest
    weight" — here a masked argmax over the ELL row with a random tiebreak;
  * query buffers are exchanged and feasible matings granted — here a
    coin flip splits vertices into proposers/acceptors (so grant chains
    cannot form), and grants are resolved with segment-max reductions;
  * unsatisfied requests are notified and vertices re-enqueued — here simply
    the next round's unmatched mask.

"This whole process is repeated until the list is almost empty ... It
usually converges in 5 iterations" — we run a fixed number of rounds
(default 8) and leave stragglers unmatched (singletons), exactly the
paper's almost-empty stopping rule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

INT_MAX = jnp.iinfo(jnp.int32).max


# --------------------------------------------------------------------- #
# deterministic protocol hashes (shared with the distributed matcher)
# --------------------------------------------------------------------- #
def hash_u32(x: jax.Array) -> jax.Array:
    """Avalanche hash (lowbias32) on uint32 arrays.

    The distributed request/grant protocol (``dgraph.distributed_matching``)
    derives coin flips and tiebreaks from ``(gid, round, seed)`` hashes so
    any shard can evaluate any vertex's state without communication.
    """
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def hash_mix(*xs) -> jax.Array:
    """Chain ``hash_u32`` over several (broadcastable) integer arrays."""
    h = jnp.uint32(0x9E3779B9)
    for x in xs:
        h = hash_u32(h ^ (jnp.asarray(x).astype(jnp.uint32)
                          * jnp.uint32(0x85EBCA6B) + jnp.uint32(1)))
    return h


def hash_unit(*xs) -> jax.Array:
    """Deterministic uniform tiebreak in [0, 1)."""
    return hash_mix(*xs).astype(jnp.float32) * jnp.float32(2.0 ** -32)


@functools.partial(jax.jit, static_argnames=("rounds",))
def heavy_edge_matching(nbr: jax.Array, wgt: jax.Array, key: jax.Array,
                        rounds: int = 8) -> jax.Array:
    """Compute a matching on an ELL graph.

    Args:
      nbr:  (n, dmax) int32 neighbor ids, -1 padding.
      wgt:  (n, dmax) int32 edge weights (0 on padding).
      key:  PRNG key.
      rounds: number of synchronous propose/grant rounds.

    Returns:
      match: (n,) int32 with match[v] = mate of v (== v for singletons).
    """
    n, dmax = nbr.shape
    valid = nbr >= 0
    nbr_safe = jnp.where(valid, nbr, 0)
    vid = jnp.arange(n, dtype=jnp.int32)

    def round_fn(carry, rkey):
        match = carry
        unmatched = match < 0
        k_coin, k_tie, k_grant = jax.random.split(rkey, 3)
        # coin flip: proposers vs acceptors (breaks grant chains)
        is_prop = jax.random.bernoulli(k_coin, 0.5, (n,)) & unmatched
        is_acc = (~is_prop) & unmatched

        # --- propose: heaviest unmatched acceptor neighbor, random tiebreak
        nbr_ok = valid & is_acc[nbr_safe]
        tie = jax.random.uniform(k_tie, (n, dmax))
        score = jnp.where(nbr_ok, wgt.astype(jnp.float32) + tie, -jnp.inf)
        best_slot = jnp.argmax(score, axis=1)
        has_cand = jnp.any(nbr_ok, axis=1)
        prop = jnp.where(is_prop & has_cand,
                         nbr_safe[vid, best_slot], -1)          # (n,)
        prop_w = jnp.where(prop >= 0, wgt[vid, best_slot], 0)

        # --- grant: acceptor takes heaviest proposal (random tiebreak)
        gtie = jax.random.uniform(k_grant, (n,))
        gkey = jnp.where(prop >= 0, prop_w.astype(jnp.float32) + gtie, -jnp.inf)
        seg = jnp.where(prop >= 0, prop, n)                     # dump row
        best = jax.ops.segment_max(gkey, seg, num_segments=n + 1)[:n]
        is_best = (prop >= 0) & (gkey >= best[jnp.where(prop >= 0, prop, 0)])
        # min proposer id among best-key holders (deterministic final tie)
        winner = jax.ops.segment_min(jnp.where(is_best, vid, INT_MAX),
                                     seg, num_segments=n + 1)[:n]
        granted = is_best & (winner[jnp.where(prop >= 0, prop, 0)] == vid)

        # --- commit both directions
        match = jnp.where(granted, prop, match)
        tgt = jnp.where(granted, prop, n)
        match = match.at[tgt].set(jnp.where(granted, vid, -1).astype(match.dtype),
                                  mode="drop")
        return match, None

    match0 = jnp.full((n,), -1, dtype=jnp.int32)
    match, _ = jax.lax.scan(round_fn, match0, jax.random.split(key, rounds))
    return jnp.where(match < 0, vid, match)                     # singletons


@functools.partial(jax.jit, static_argnames=("rounds",))
def heavy_edge_matching_multi(nbr: jax.Array, wgt: jax.Array,
                              keys: jax.Array, rounds: int = 8) -> jax.Array:
    """Lane-batched ``heavy_edge_matching``: (L, n, d) ELL bucket → (L, n).

    A ``vmap`` over independent lanes; per-lane results are identical to
    the single-graph kernel with the same key, so the service's bucketed
    matching waves are result-compatible with per-subproblem dispatch.
    """
    return jax.vmap(lambda nb, wg, k: heavy_edge_matching(
        nb, wg, k, rounds=rounds))(nbr, wgt, keys)


def validate_matching(match: np.ndarray) -> bool:
    """match is an involution: match[match[v]] == v."""
    match = np.asarray(match)
    return bool(np.all(match[match] == np.arange(len(match))))
