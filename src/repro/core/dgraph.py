"""Distributed graph structure + halo exchange (paper §2.1), shard_map form.

The paper's structure maps onto JAX as stacked per-shard arrays with a
``parts`` mesh axis:

  * ``vtxdist``      — the paper's ``procvrttab``: global vertex ranges per
    shard (duplicated everywhere, owner lookup by range search);
  * ``nbr_gst``      — the paper's ``edgegsttab``: ELL adjacency in *compact
    local indexing* where indices < n_loc are local and indices ≥ n_loc
    address the ghost slots, numbered by (owner, global id) — the
    cache-friendly agglomeration order of §2.1;
  * ``ghost_gid``    — global ids of ghost slots per shard (the receive
    manifest of the halo exchange).

``halo_exchange`` diffuses local vertex values to the ghost copies on
neighboring shards: the reference implementation is an ``all_gather`` over
the parts axis + gather (dense collective — the TPU-idiomatic replacement
for MPI point-to-point; DESIGN.md §2 discusses the trade).

Scalability note (matching the paper): no shard stores ghost *adjacency* —
only ghost values — so per-shard memory is O(local arcs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.graph import Graph


@dataclasses.dataclass
class DGraph:
    """Host-resident description of a P-way distributed graph."""
    vtxdist: np.ndarray        # (P+1,) global ranges
    nbr_gst: np.ndarray        # (P, n_loc_max, dmax) compact local/ghost ids
    ghost_gid: np.ndarray      # (P, n_ghost_max) global ids of ghosts (-1 pad)
    n_loc: np.ndarray          # (P,) real local counts
    n_ghost: np.ndarray        # (P,) real ghost counts
    vwgt: np.ndarray           # (P, n_loc_max)

    @property
    def nparts(self) -> int:
        return len(self.vtxdist) - 1

    @property
    def n_loc_max(self) -> int:
        return self.nbr_gst.shape[1]


def distribute(g: Graph, nparts: int) -> DGraph:
    """Block-distribute a host graph (the paper's user-defined ranges)."""
    n = g.n
    vtxdist = np.linspace(0, n, nparts + 1).astype(np.int64)
    n_loc = np.diff(vtxdist)
    n_loc_max = int(n_loc.max())
    deg = g.degrees()
    dmax = int(deg.max()) if n else 1
    owner = np.searchsorted(vtxdist, np.arange(n), side="right") - 1

    nbr_gst = -np.ones((nparts, n_loc_max, dmax), dtype=np.int32)
    ghost_lists = []
    for p in range(nparts):
        lo, hi = vtxdist[p], vtxdist[p + 1]
        ghosts = {}
        for li, v in enumerate(range(lo, hi)):
            nbrs = g.neighbors(v)
            for j, u in enumerate(nbrs):
                if lo <= u < hi:
                    nbr_gst[p, li, j] = u - lo
                else:
                    ghosts.setdefault(int(u), None)
        # ghost numbering: ascending (owner process, global id) — §2.1
        glist = sorted(ghosts, key=lambda u: (owner[u], u))
        gidx = {u: n_loc_max + k for k, u in enumerate(glist)}
        for li, v in enumerate(range(lo, hi)):
            for j, u in enumerate(g.neighbors(v)):
                if not (lo <= u < hi):
                    nbr_gst[p, li, j] = gidx[int(u)]
        ghost_lists.append(np.array(glist, dtype=np.int64))
    n_ghost = np.array([len(x) for x in ghost_lists])
    n_ghost_max = max(int(n_ghost.max()), 1)
    ghost_gid = -np.ones((nparts, n_ghost_max), dtype=np.int64)
    for p, gl in enumerate(ghost_lists):
        ghost_gid[p, :len(gl)] = gl
    vwgt = np.zeros((nparts, n_loc_max), dtype=np.int64)
    for p in range(nparts):
        lo, hi = vtxdist[p], vtxdist[p + 1]
        vwgt[p, :hi - lo] = g.vwgt[lo:hi]
    return DGraph(vtxdist, nbr_gst, ghost_gid, n_loc, n_ghost, vwgt)


def make_parts_mesh(nparts: int) -> Mesh:
    devs = jax.devices()[:nparts]
    return Mesh(np.array(devs), ("parts",))


def halo_exchange_fn(dg: DGraph, mesh: Mesh):
    """Returns jitted halo(x (P, n_loc_max)) -> (P, n_loc_max + n_ghost_max).

    Reference path: all_gather of owned slabs + gather by global id.
    """
    vtxdist = jnp.asarray(dg.vtxdist)
    ghost_gid = jnp.asarray(dg.ghost_gid)          # (P, G)
    n_loc_max = dg.n_loc_max

    def body(x, gids):
        # x: (1, n_loc_max) this shard's values; gids: (1, G)
        allx = jax.lax.all_gather(x[0], "parts")    # (P, n_loc_max)
        owner = jnp.searchsorted(vtxdist, gids[0], side="right") - 1
        local = gids[0] - vtxdist[owner]
        vals = allx[owner, local]
        vals = jnp.where(gids[0] >= 0, vals, 0)
        return jnp.concatenate([x[0], vals])[None]

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("parts", None), P("parts", None)),
                   out_specs=P("parts", None))
    gids = jnp.asarray(ghost_gid)
    return jax.jit(lambda x: fn(x, gids))


def halo_reference(dg: DGraph, x: np.ndarray) -> np.ndarray:
    """Host oracle for tests."""
    Pn, G = dg.ghost_gid.shape
    out = np.zeros((Pn, dg.n_loc_max + G), dtype=x.dtype)
    flat = np.zeros(dg.vtxdist[-1], dtype=x.dtype)
    for p in range(Pn):
        lo, hi = dg.vtxdist[p], dg.vtxdist[p + 1]
        flat[lo:hi] = x[p, :hi - lo]
    for p in range(Pn):
        out[p, :dg.n_loc_max] = x[p]
        for k, gid in enumerate(dg.ghost_gid[p]):
            if gid >= 0:
                out[p, dg.n_loc_max + k] = flat[gid]
    return out


def distributed_bfs(dg: DGraph, mesh: Mesh, src_mask: np.ndarray,
                    width: int) -> np.ndarray:
    """Band-graph distance sweep (§3.3) on the distributed structure: one
    halo exchange per relaxation — the paper's 'spreading distance
    information from all of the separator vertices, using our halo exchange
    routine'."""
    halo = halo_exchange_fn(dg, mesh)
    nbr = jnp.asarray(np.where(dg.nbr_gst >= 0, dg.nbr_gst, 0))
    valid = jnp.asarray(dg.nbr_gst >= 0)
    BIG = jnp.int32(2 ** 30)
    dist = jnp.where(jnp.asarray(src_mask), 0, BIG).astype(jnp.int32)

    @jax.jit
    def relax(dist):
        ext = halo(dist)                            # (P, n_loc+G)
        pidx = jnp.arange(ext.shape[0])[:, None, None]
        dn = jnp.where(valid, ext[pidx, nbr], BIG)
        return jnp.minimum(dist, dn.min(axis=-1) + 1)

    for _ in range(width):
        dist = relax(dist)
    return np.asarray(dist)
