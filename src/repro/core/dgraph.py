"""Distributed graph structure + halo exchange (paper §2.1), shard_map form.

The paper's structure maps onto JAX as stacked per-shard arrays with a
``parts`` mesh axis:

  * ``vtxdist``      — the paper's ``procvrttab``: global vertex ranges per
    shard (duplicated everywhere, owner lookup by range search);
  * ``nbr_gst``      — the paper's ``edgegsttab``: ELL adjacency in *compact
    local indexing* where indices < n_loc_max are local and indices ≥
    n_loc_max address the ghost slots, numbered by (owner, global id) — the
    cache-friendly agglomeration order of §2.1;
  * ``ewgt_gst``     — matching ELL edge weights (heavy-edge matching on
    coarse levels needs them);
  * ``ghost_gid``    — global ids of ghost slots per shard (the receive
    manifest of the halo exchange).

``halo_exchange`` diffuses local vertex values to the ghost copies on
neighboring shards: the reference implementation is an ``all_gather`` over
the parts axis + gather (dense collective — the TPU-idiomatic replacement
for MPI point-to-point; DESIGN.md §2 discusses the trade).

All device functions take the per-graph arrays (``vtxdist``, ``ghost_gid``,
…) as *traced arguments* and are cached per padded shape, so the jit cache
is shared across every subgraph of a nested-dissection recursion that lands
in the same power-of-two bucket (same bucketing the centralized data plane
uses, ``repro.util.pow2``).

Scalability note (matching the paper): no shard stores ghost *adjacency* —
only ghost values — so per-shard memory is O(local arcs).

Two kinds of routines live here (DESIGN.md §4):

  * **device collectives** (``halo_exchange_fn``, ``distributed_bfs``,
    ``distributed_matching``) — ``shard_map`` programs over the parts axis;
  * **structure rebuilds** (``distribute``, ``dgraph_induced``,
    ``dgraph_fold``, ``dgraph_coarsen``) — host-side reshuffles of the
    stacked arrays that model the owner-routed ``MPI_Alltoallv`` of the
    paper's redistribution steps.  They stage the routed arcs in flat
    arrays (the analog of the exchange's send/receive buffers, O(arcs)
    words), never a centralized CSR graph.

The *gather* API — ``to_host`` and ``unshard_vector``, the only two
routines that intentionally materialize one centralized object from a
distributed one — is instrumented: inside a ``track_gathers()`` block every
call records its element count, which is how the gather-free tests assert
that ``distributed_nested_dissection`` never centralizes more than its
configured thresholds (ISSUE: no O(n) per-host cliff).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.graph import Graph
from repro.core.matching import hash_mix, hash_unit
from repro.util import pow2


@dataclasses.dataclass
class DGraph:
    """Host-resident description of a P-way distributed graph."""
    vtxdist: np.ndarray        # (P+1,) global ranges
    nbr_gst: np.ndarray        # (P, n_loc_max, dmax) compact local/ghost ids
    ewgt_gst: np.ndarray       # (P, n_loc_max, dmax) edge weights (0 pad)
    ghost_gid: np.ndarray      # (P, n_ghost_max) global ids of ghosts (-1 pad)
    n_loc: np.ndarray          # (P,) real local counts
    n_ghost: np.ndarray        # (P,) real ghost counts
    vwgt: np.ndarray           # (P, n_loc_max)

    @property
    def nparts(self) -> int:
        return len(self.vtxdist) - 1

    @property
    def n_loc_max(self) -> int:
        return self.nbr_gst.shape[1]

    @property
    def n_global(self) -> int:
        return int(self.vtxdist[-1])


def _build_dgraph(vtxdist: np.ndarray, src: np.ndarray, dst: np.ndarray,
                  w: np.ndarray, vwgt: np.ndarray,
                  bucket: bool = True) -> DGraph:
    """Assemble the stacked shard arrays from an owner-routed arc list.

    The shared back end of every structure rebuild (``distribute``,
    ``dgraph_induced``, ``dgraph_fold``, ``dgraph_coarsen``).  ``src`` /
    ``dst`` / ``w`` are flat *directed* arc arrays in global ids (each
    undirected edge appears in both directions) — the staging buffers of
    the owner-routed Alltoallv that the paper's redistribution performs;
    ``vwgt`` is the flat (n,) vertex-weight vector in global-id order.
    Parallel arcs are deduplicated with accumulated weights (exactly
    ``Graph.from_edges``'s canonicalization), so rebuilding through here
    matches the centralized builders arc-for-arc.
    """
    vtxdist = np.asarray(vtxdist, dtype=np.int64)
    nparts = len(vtxdist) - 1
    n = int(vtxdist[-1])
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    w = np.asarray(w, dtype=np.int64)
    if len(src):
        order = np.lexsort((dst, src))
        src, dst, w = src[order], dst[order], w[order]
        uniq = np.concatenate(
            [[True], (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])])
        seg = np.cumsum(uniq) - 1
        wacc = np.zeros(seg[-1] + 1, dtype=np.int64)
        np.add.at(wacc, seg, w)
        src, dst, w = src[uniq], dst[uniq], wacc

    n_loc = np.diff(vtxdist)
    n_loc_max = int(n_loc.max()) if nparts else 1
    deg = np.bincount(src, minlength=max(n, 1))[:max(n, 1)]
    dmax = int(deg.max()) if len(src) else 1
    if bucket:
        n_loc_max = pow2(max(n_loc_max, 1), 8)
        dmax = pow2(max(dmax, 1), 4)
    n_loc_max = max(n_loc_max, 1)
    dmax = max(dmax, 1)

    owner = np.searchsorted(vtxdist, np.arange(n), side="right") - 1
    p_src = owner[src]
    xadj = np.concatenate([[0], np.cumsum(deg)])
    col = np.arange(len(dst)) - xadj[src]
    li_src = src - vtxdist[p_src]
    remote = p_src != owner[dst]

    # ghost manifests: unique (shard, gid) pairs among remote arc heads.
    # Ascending gid is ascending (owner, gid) because vtxdist is sorted —
    # the §2.1 cache-friendly agglomeration order.
    keys = p_src[remote] * np.int64(max(n, 1)) + dst[remote]
    uk = np.unique(keys)
    gp = uk // max(n, 1)
    ggid = uk % max(n, 1)
    counts = np.bincount(gp, minlength=nparts)
    offs = np.concatenate([[0], np.cumsum(counts)])
    gslot = np.arange(len(uk)) - offs[gp]
    n_ghost = counts.astype(np.int64)
    n_ghost_max = max(int(n_ghost.max()) if nparts else 0, 1)
    if bucket:
        n_ghost_max = pow2(n_ghost_max, 4)
    ghost_gid = -np.ones((nparts, n_ghost_max), dtype=np.int64)
    ghost_gid[gp, gslot] = ggid

    nbr_gst = -np.ones((nparts, n_loc_max, dmax), dtype=np.int32)
    ewgt_gst = np.zeros((nparts, n_loc_max, dmax), dtype=np.int32)
    cidx = dst - vtxdist[owner[dst]] if len(dst) else dst
    if len(uk):
        cidx[remote] = n_loc_max + gslot[np.searchsorted(uk, keys)]
    nbr_gst[p_src, li_src, col] = cidx
    ewgt_gst[p_src, li_src, col] = w

    vwgt_sh = np.zeros((nparts, n_loc_max), dtype=np.int64)
    vwgt_sh[owner, np.arange(n) - vtxdist[owner]] = np.asarray(vwgt, np.int64)
    return DGraph(vtxdist, nbr_gst, ewgt_gst, ghost_gid, n_loc, n_ghost,
                  vwgt_sh)


def distribute(g: Graph, nparts: int,
               vtxdist: Optional[np.ndarray] = None,
               bucket: bool = True) -> DGraph:
    """Distribute a host graph (the paper's user-defined ranges).

    Args:
      g: centralized host graph (symmetric CSR).
      nparts: number of shards P.
      vtxdist: optional (P+1,) custom ownership ranges (the coarse graphs
        of distributed coarsening keep coarse vertices on the owner of
        their representative); the default is a balanced block
        distribution.
      bucket: round padded shard shapes up to powers of two so jitted
        collectives are reused across same-bucket subgraphs.

    Returns a ``DGraph`` whose stacked arrays hold g partitioned by
    ``vtxdist`` ranges.
    """
    n = g.n
    if vtxdist is None:
        vtxdist = np.linspace(0, n, nparts + 1).astype(np.int64)
    else:
        vtxdist = np.asarray(vtxdist, dtype=np.int64)
        assert len(vtxdist) == nparts + 1 and vtxdist[-1] == n
    src = np.repeat(np.arange(n, dtype=np.int64), g.degrees())
    return _build_dgraph(vtxdist, src, g.adjncy, g.adjwgt, g.vwgt,
                         bucket=bucket)


@functools.lru_cache(maxsize=None)
def make_parts_mesh(nparts: int) -> Mesh:
    devs = jax.devices()[:nparts]
    assert len(devs) == nparts, (
        f"need {nparts} devices, have {len(jax.devices())} "
        "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return Mesh(np.array(devs), ("parts",))


# ------------------------------------------------------------------ #
# gather instrumentation (the gather-free tests hang off this)
# ------------------------------------------------------------------ #
_GATHER_LOG: Optional[List[Tuple[str, int]]] = None
_HALO_LOG: Optional[List[int]] = None


@contextlib.contextmanager
def track_gathers():
    """Record every centralizing gather executed inside the block.

    Yields a list that receives one ``(kind, n_elements)`` tuple per
    ``to_host`` / ``unshard_vector`` call.  The gather-free ND tests run
    ``distributed_nested_dissection`` under this and assert that no
    recorded gather exceeds the configured centralization thresholds —
    i.e. that no full-graph adjacency or full permutation is ever
    materialized on a single host above those thresholds.
    """
    global _GATHER_LOG
    prev, _GATHER_LOG = _GATHER_LOG, []
    try:
        yield _GATHER_LOG
    finally:
        _GATHER_LOG = prev


def _note_gather(kind: str, size: int) -> None:
    if _GATHER_LOG is not None:
        _GATHER_LOG.append((kind, int(size)))


@contextlib.contextmanager
def track_halos():
    """Record every host-level halo exchange executed inside the block.

    Yields a list that receives the exchanged element count (P · n_loc_max
    words pushed through the collective) per call to a
    ``halo_exchange_fn`` closure.  Exchanges fused *inside* jitted sweeps
    (the per-step relaxations of ``distributed_bfs``, the matching
    rounds) are not counted — this tracks the per-round synchronization
    budget of host-driven loops, which is what the sharded-band
    refinement tests bound.
    """
    global _HALO_LOG
    prev, _HALO_LOG = _HALO_LOG, []
    try:
        yield _HALO_LOG
    finally:
        _HALO_LOG = prev


def _note_halo(size: int) -> None:
    if _HALO_LOG is not None:
        _HALO_LOG.append(int(size))


# ------------------------------------------------------------------ #
# sharded <-> flat host vectors
# ------------------------------------------------------------------ #
def shard_vector(dg: DGraph, x: np.ndarray, fill=0) -> np.ndarray:
    """Flat global (n,) -> sharded (P, n_loc_max) (padding = fill).

    A scatter (host value distributed *out* to shards), so it is not part
    of the instrumented gather API.
    """
    out = np.full((dg.nparts, dg.n_loc_max), fill, dtype=np.asarray(x).dtype)
    for p in range(dg.nparts):
        lo, hi = dg.vtxdist[p], dg.vtxdist[p + 1]
        out[p, :hi - lo] = x[lo:hi]
    return out


def _raster_flat(dg: DGraph, xs: np.ndarray) -> np.ndarray:
    """Sharded (P, n_loc_max) -> flat (n,) without touching the gather log.

    Internal staging primitive for the structure rebuilds; user-facing
    centralization must go through ``unshard_vector`` so it is counted.
    """
    xs = np.asarray(xs)
    li = np.arange(dg.n_loc_max)
    keep = (li[None, :] < dg.n_loc[:, None]).reshape(-1)
    return xs.reshape(dg.nparts * dg.n_loc_max, *xs.shape[2:])[keep]


def unshard_vector(dg: DGraph, xs: np.ndarray) -> np.ndarray:
    """Gather a sharded (P, n_loc_max) vector into a flat global (n,).

    One of the two instrumented centralizing gathers (with ``to_host``);
    the gather-free pipeline only applies it to sub-threshold objects.
    """
    _note_gather("unshard_vector", dg.n_global)
    return _raster_flat(dg, xs)


def shard_gids(dg: DGraph) -> np.ndarray:
    """(P, n_loc_max) global vertex id per local slot (-1 on padding)."""
    li = np.arange(dg.n_loc_max, dtype=np.int64)
    gid = dg.vtxdist[:-1, None] + li[None, :]
    return np.where(li[None, :] < dg.n_loc[:, None], gid, -1)


def valid_mask(dg: DGraph) -> np.ndarray:
    """(P, n_loc_max) bool: True on real local slots, False on padding."""
    li = np.arange(dg.n_loc_max)
    return li[None, :] < dg.n_loc[:, None]


def pull_by_gid(dg: DGraph, values_sh: np.ndarray, gid: np.ndarray,
                fill=0) -> np.ndarray:
    """Owner-routed value pull: out[...] = values of vertices ``gid``.

    ``values_sh`` is a (P, n_loc_max) sharded vector on ``dg``'s layout;
    ``gid`` is any-shape global ids (< 0 yields ``fill``).  This is the
    host-side model of the paper's point-to-point value fetch (the same
    owner lookup the halo exchange performs on device); data volume is
    O(len(gid)) words, independent of graph size.
    """
    gid = np.asarray(gid, dtype=np.int64)
    ok = (gid >= 0) & (gid < dg.n_global)
    gsafe = np.clip(gid, 0, max(dg.n_global - 1, 0))
    owner = np.searchsorted(dg.vtxdist, gsafe, side="right") - 1
    owner = np.clip(owner, 0, dg.nparts - 1)
    li = np.clip(gsafe - dg.vtxdist[owner], 0, dg.n_loc_max - 1)
    out = np.asarray(values_sh)[owner, li]
    return np.where(ok, out, fill)


def scatter_by_gid(dg: DGraph, target_sh: np.ndarray, gid: np.ndarray,
                   vals: np.ndarray) -> np.ndarray:
    """Owner-routed value push: write ``vals`` at vertices ``gid``.

    The inverse of ``pull_by_gid``: returns a copy of ``target_sh``
    (a (P, n_loc_max) sharded vector on ``dg``'s layout) with
    ``vals[k]`` written to the owner slot of ``gid[k]`` (negative ids
    skipped).  Models the project-back message of band refinement; data
    volume is O(len(gid)) words.
    """
    gid = np.asarray(gid, dtype=np.int64).reshape(-1)
    vals = np.asarray(vals).reshape(-1)
    ok = (gid >= 0) & (gid < dg.n_global)
    gid, vals = gid[ok], vals[ok]
    owner = np.searchsorted(dg.vtxdist, gid, side="right") - 1
    out = np.asarray(target_sh).copy()
    out[owner, gid - dg.vtxdist[owner]] = vals
    return out


def reshard_vector(src_dg: DGraph, dst_dg: DGraph, xs: np.ndarray,
                   fill=0) -> np.ndarray:
    """Move a sharded vector between two layouts of the *same* vertex set.

    Used when fold-dup rejoins: the winning duplicate's part vector lives
    on the folded layout and is pulled back onto the full group's layout.
    """
    assert src_dg.n_global == dst_dg.n_global
    return pull_by_gid(src_dg, xs, shard_gids(dst_dg), fill=fill)


# ------------------------------------------------------------------ #
# boundary masks + deterministic coloring (alternating-color schedule)
# ------------------------------------------------------------------ #
def np_hash_mix(x: np.ndarray, *salts: int) -> np.ndarray:
    """lowbias32 chain on int arrays (numpy mirror of matching.hash_mix).

    Every shard evaluates the same pure function of global ids alone, so
    symmetric rules (conflict-repair losers, boundary colors) need no
    extra messages — the same argument as the matching protocol's coins.
    """
    def lb(v):
        v = v ^ (v >> np.uint32(16))
        v = v * np.uint32(0x7FEB352D)
        v = v ^ (v >> np.uint32(15))
        v = v * np.uint32(0x846CA68B)
        return v ^ (v >> np.uint32(16))

    h = np.full(np.shape(x), 0x9E3779B9, dtype=np.uint32)
    for v in (x,) + salts:
        v = np.asarray(v).astype(np.uint32)
        h = lb(h ^ (v * np.uint32(0x85EBCA6B) + np.uint32(1)))
    return h


def boundary_mask(dg: DGraph) -> np.ndarray:
    """(P, n_loc_max) bool: local vertices with ≥ 1 cross-shard edge.

    A vertex is *boundary* when any ELL slot addresses the ghost ring
    (compact index ≥ n_loc_max).  Interior vertices can never create a
    cross-shard 0–1 edge, so refinement schedules only need to gate the
    boundary set.
    """
    return (dg.nbr_gst >= dg.n_loc_max).any(axis=2) & valid_mask(dg)


def color_by_gid(dg: DGraph, salt: int = 0, exchange: bool = True
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic two-coloring of vertices by gid hash (§3.3 schedule).

    Returns ``(hash_ext, color_ext)``, both (P, n_loc_max + n_ghost_max):
    the full uint32 hash (for tiebreaks on monochromatic edges) and the
    color (hash & 1, int8; -1 on padding) for every local slot *and* its
    ghost ring.  Local colors are computed from ``shard_gids``; ghost
    colors are the same pure hash of ``ghost_gid``, so owner and
    neighbor always agree with no messages.  With ``exchange`` the ghost
    colors are additionally halo-exchanged from the owners and
    cross-checked against the local recomputation — callers that
    re-color every round (the alternating-color band schedule rotates
    the salt to avoid starving tiebreak losers) validate the first
    coloring this way and skip the exchange for the rest, keeping the
    per-round exchange budget flat.
    """
    gid = shard_gids(dg)
    h_loc = np_hash_mix(np.maximum(gid, 0), salt & 0x7FFFFFFF)
    h_gst = np_hash_mix(np.maximum(dg.ghost_gid, 0), salt & 0x7FFFFFFF)
    hash_ext = np.concatenate([h_loc, h_gst], axis=1)
    col_loc = np.where(gid >= 0, (h_loc & 1).astype(np.int32), -1)
    gok = dg.ghost_gid >= 0
    if exchange:
        col_ext = np.asarray(halo_exchange_fn(dg)(col_loc))
        assert np.array_equal(np.where(gok, col_ext[:, dg.n_loc_max:], 0),
                              np.where(gok, h_gst & 1, 0)), \
            "halo-exchanged ghost colors disagree with the gid hash"
    color_ext = np.concatenate(
        [col_loc, np.where(gok, (h_gst & 1).astype(np.int32), -1)],
        axis=1).astype(np.int8)
    return hash_ext, color_ext


# ------------------------------------------------------------------ #
# structure rebuilds (host-modelled Alltoallv; DESIGN.md §4)
# ------------------------------------------------------------------ #
def dgraph_arcs(dg: DGraph) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Flat directed arc triples (src_gid, dst_gid, w) of the structure.

    The staging form every rebuild routes through; both directions of
    each undirected edge are present (ELL rows are symmetric).
    """
    nlm = dg.n_loc_max
    p, li, slot = np.nonzero(dg.nbr_gst >= 0)
    c = dg.nbr_gst[p, li, slot].astype(np.int64)
    src = dg.vtxdist[p] + li
    loc = c < nlm
    dst = np.where(loc, dg.vtxdist[p] + c,
                   dg.ghost_gid[p, np.maximum(c - nlm, 0)])
    w = dg.ewgt_gst[p, li, slot].astype(np.int64)
    return src, dst, w


def to_host(dg: DGraph) -> Graph:
    """Gather the distributed structure back into one centralized Graph.

    The §3.1 centralization step: below the sequential threshold the
    subgraph is gathered onto one process and ordered there.  Instrumented
    (see ``track_gathers``): the gather-free pipeline only calls this on
    sub-threshold subgraphs, coarsest graphs, and band graphs.
    """
    _note_gather("to_host", dg.n_global)
    src, dst, w = dgraph_arcs(dg)
    keep = src < dst                      # one direction; from_edges mirrors
    vwgt = _raster_flat(dg, dg.vwgt)
    return Graph.from_edges(dg.n_global,
                            np.stack([src[keep], dst[keep]], 1),
                            vwgt=vwgt, ewgt=w[keep])


def dgraph_induced(dg: DGraph, keep_sh: np.ndarray,
                   nparts: Optional[int] = None,
                   payloads: Sequence[np.ndarray] = (),
                   fills: Sequence = (),
                   bucket: bool = True
                   ) -> Tuple[DGraph, List[np.ndarray]]:
    """Distributed induced subgraph (paper §3.1, gather-free form).

    Args:
      keep_sh: (P, n_loc_max) bool mask of kept vertices (padding slots
        ignored).
      nparts: target shard count.  ``None`` keeps every kept vertex on its
        current owner (in-place extraction — the band path); an integer
        redistributes onto balanced blocks over that many shards (the
        paper folds each separated part onto its child process group).
      payloads: per-vertex (P, n_loc_max) arrays (e.g. original-id
        vectors) to carry onto the new layout.
      fills: padding fill value per payload (default 0).

    Kept vertices are renumbered by ascending global id, so the induced
    numbering is independent of the shard layout; new ownership ranges
    come from a prefix sum over per-shard keep counts (the offset
    exchange of the paper's redistribution).  Returns the sub-DGraph and
    the payloads mapped onto its layout.
    """
    keep = np.asarray(keep_sh, dtype=bool) & valid_mask(dg)
    counts = keep.sum(axis=1).astype(np.int64)
    n_new = int(counts.sum())
    if nparts is None:
        new_vtxdist = np.concatenate([[0], np.cumsum(counts)])
    else:
        new_vtxdist = np.linspace(0, n_new, nparts + 1).astype(np.int64)

    # rank kept vertices in shard-major raster order == ascending gid
    flatk = keep.reshape(-1)
    newid_flat = -np.ones(dg.n_global, dtype=np.int64)
    old_gid = shard_gids(dg).reshape(-1)[flatk]          # ascending
    newid_flat[old_gid] = np.arange(n_new)

    src, dst, w = dgraph_arcs(dg)
    ns, nd = newid_flat[src], newid_flat[dst]
    ka = (ns >= 0) & (nd >= 0)
    vwgt_new = dg.vwgt.reshape(-1)[flatk]
    sub = _build_dgraph(new_vtxdist, ns[ka], nd[ka], w[ka], vwgt_new,
                        bucket=bucket)
    mapped = []
    for i, pay in enumerate(payloads):
        fill = fills[i] if i < len(fills) else 0
        flat = np.asarray(pay).reshape(-1)[flatk]        # by new gid
        mapped.append(shard_vector(sub, flat, fill=fill))
    return sub, mapped


def dgraph_fold(dg: DGraph, bucket: bool = True) -> DGraph:
    """Fold the structure onto ⌈P/2⌉ shards (paper §3.2).

    Adjacent shard pairs merge (ownership ranges stay contiguous); global
    vertex ids are unchanged, so sharded vectors move between the two
    layouts with ``reshard_vector``.  Each fold-dup half runs an
    independent multilevel instance on (a duplicate of) the folded
    structure.
    """
    new_vtxdist = np.concatenate([dg.vtxdist[:-1:2], dg.vtxdist[-1:]])
    src, dst, w = dgraph_arcs(dg)
    vwgt = _raster_flat(dg, dg.vwgt)
    return _build_dgraph(new_vtxdist, src, dst, w, vwgt, bucket=bucket)


def dgraph_coarsen(dg: DGraph, match_sh: np.ndarray,
                   bucket: bool = True) -> Tuple[DGraph, np.ndarray]:
    """Distributed coarse-graph build from a sharded matching (§3.2).

    ``match_sh`` is (P, n_loc_max) mate global ids (self for singletons,
    as ``distributed_matching(..., flat=False)`` returns).  Each coarse
    vertex lives on the owner of its *representative* (min endpoint of
    the matched pair), so no vertex migrates at a coarsening step; coarse
    ownership ranges are the prefix sum of per-shard representative
    counts (identical to ``coarsen.coarse_vtxdist``), and the coarse
    numbering matches the centralized ``coarsen_once`` bit-for-bit.

    Returns ``(coarse_dg, cmap_sh)`` with cmap_sh[p, i] = coarse global
    id of fine local vertex i on shard p (-1 on padding).
    """
    gid = shard_gids(dg)
    valid = gid >= 0
    match = np.where(valid, np.asarray(match_sh, dtype=np.int64), -1)
    match = np.where(valid & (match >= 0) & (match < dg.n_global),
                     match, gid)
    rep = np.minimum(gid, match)
    is_rep = valid & (rep == gid)
    counts = is_rep.sum(axis=1).astype(np.int64)
    cvtxdist = np.concatenate([[0], np.cumsum(counts)])

    crank = (np.cumsum(is_rep.reshape(-1)) - 1).reshape(is_rep.shape)
    cmap_rep = np.where(is_rep, crank, np.int64(-1))
    # non-representatives read their mate's coarse id from its owner (the
    # mate is always the representative: rep = min of the pair)
    cmap_mate = pull_by_gid(dg, cmap_rep, match, fill=-1)
    cmap_sh = np.where(is_rep, cmap_rep, cmap_mate)
    assert int((cmap_sh[valid] < 0).sum()) == 0, \
        "match_sh is not an involution (mate's mate differs); pass a " \
        "matching from distributed_matching or repair symmetry first"
    cmap_sh = np.where(valid, cmap_sh, -1)

    cmap_flat = cmap_sh.reshape(-1)[valid.reshape(-1)]   # by fine gid
    nc = int(cvtxdist[-1])
    cvwgt = np.zeros(nc, dtype=np.int64)
    np.add.at(cvwgt, cmap_flat, _raster_flat(dg, dg.vwgt))
    src, dst, w = dgraph_arcs(dg)
    cs, cd = cmap_flat[src], cmap_flat[dst]
    ka = cs != cd                        # drop collapsed pairs
    cdg = _build_dgraph(cvtxdist, cs[ka], cd[ka], w[ka], cvwgt,
                        bucket=bucket)
    return cdg, cmap_sh


# ------------------------------------------------------------------ #
# halo exchange
# ------------------------------------------------------------------ #
def _halo_local(x, gids, vtxdist):
    """Per-shard halo body: all_gather owned slabs + gather by global id.

    ``x`` (n_loc_max,) this shard's values; returns (n_loc_max + G,).
    Shared by the standalone halo fn, the BFS sweep and the matching
    protocol (all run inside ``shard_map`` over the parts axis).
    """
    allx = jax.lax.all_gather(x, "parts")               # (P, n_loc_max)
    owner = jnp.clip(jnp.searchsorted(vtxdist, gids, side="right") - 1,
                     0, allx.shape[0] - 1)
    local = jnp.clip(gids - vtxdist[owner], 0, allx.shape[1] - 1)
    vals = jnp.where(gids >= 0, allx[owner, local], 0)
    return jnp.concatenate([x, vals])


@functools.lru_cache(maxsize=None)
def _halo_jit(nparts: int, n_loc_max: int, n_ghost_max: int, dtype: str):
    mesh = make_parts_mesh(nparts)

    def body(x, gids, vtxdist):
        return _halo_local(x[0], gids[0], vtxdist)[None]

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("parts", None), P("parts", None), P(None)),
                   out_specs=P("parts", None))
    return jax.jit(fn)


def halo_exchange_fn(dg: DGraph):
    """Returns halo(x (P, n_loc_max)) -> (P, n_loc_max + n_ghost_max).

    The underlying jitted collective is cached per (nparts, padded shapes,
    dtype) and takes the ghost manifest / ranges as traced arguments, so it
    is reused by every same-bucket graph.
    """
    gids = jnp.asarray(dg.ghost_gid, jnp.int32)
    vtxdist = jnp.asarray(dg.vtxdist, jnp.int32)

    def halo(x):
        x = jnp.asarray(x)
        _note_halo(dg.nparts * dg.n_loc_max)
        fn = _halo_jit(dg.nparts, dg.n_loc_max, dg.ghost_gid.shape[1],
                       str(x.dtype))
        return fn(x, gids, vtxdist)
    return halo


def halo_reference(dg: DGraph, x: np.ndarray) -> np.ndarray:
    """Host oracle for tests."""
    Pn, G = dg.ghost_gid.shape
    out = np.zeros((Pn, dg.n_loc_max + G), dtype=x.dtype)
    flat = np.zeros(dg.vtxdist[-1], dtype=x.dtype)
    for p in range(Pn):
        lo, hi = dg.vtxdist[p], dg.vtxdist[p + 1]
        flat[lo:hi] = x[p, :hi - lo]
    for p in range(Pn):
        out[p, :dg.n_loc_max] = x[p]
        for k, gid in enumerate(dg.ghost_gid[p]):
            if gid >= 0:
                out[p, dg.n_loc_max + k] = flat[gid]
    return out


# ------------------------------------------------------------------ #
# distributed band-BFS
# ------------------------------------------------------------------ #
@functools.lru_cache(maxsize=None)
def _bfs_jit(nparts: int, n_loc_max: int, dmax: int, n_ghost_max: int,
             width: int):
    from repro.kernels.ops import ell_relax_step
    mesh = make_parts_mesh(nparts)

    def body(nbr, src, gids, vtxdist):
        nbr, src, gids = nbr[0], src[0], gids[0]
        BIG = jnp.int32(2 ** 30)
        dist = jnp.where(src != 0, 0, BIG).astype(jnp.int32)

        def step(dist, _):
            ext = _halo_local(dist, gids, vtxdist)
            return jnp.minimum(dist, ell_relax_step(nbr, ext, BIG)), None

        dist, _ = jax.lax.scan(step, dist, None, length=width)
        return dist[None]

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("parts", None, None), P("parts", None),
                             P("parts", None), P(None)),
                   out_specs=P("parts", None))
    return jax.jit(fn)


def distributed_bfs(dg: DGraph, src_mask: np.ndarray,
                    width: int) -> np.ndarray:
    """Band-graph distance sweep (§3.3) on the distributed structure: one
    halo exchange per relaxation — the paper's 'spreading distance
    information from all of the separator vertices, using our halo exchange
    routine'."""
    fn = _bfs_jit(dg.nparts, dg.n_loc_max, dg.nbr_gst.shape[2],
                  dg.ghost_gid.shape[1], width)
    dist = fn(jnp.asarray(dg.nbr_gst), jnp.asarray(src_mask, jnp.int32),
              jnp.asarray(dg.ghost_gid, jnp.int32),
              jnp.asarray(dg.vtxdist, jnp.int32))
    return np.asarray(dist)


# ------------------------------------------------------------------ #
# distributed heavy-edge matching (paper §3.2)
# ------------------------------------------------------------------ #
@functools.lru_cache(maxsize=None)
def _matching_jit(nparts: int, n_loc_max: int, dmax: int, n_ghost_max: int,
                  rounds: int):
    mesh = make_parts_mesh(nparts)
    INT_MAX = jnp.iinfo(jnp.int32).max

    def body(nbr, ew, gids, vtxdist, nloc, seed):
        nbr, ew, gids, nloc = nbr[0], ew[0], gids[0], nloc[0]
        pidx = jax.lax.axis_index("parts")
        lo = vtxdist[pidx]
        li = jnp.arange(n_loc_max, dtype=jnp.int32)
        valid_loc = li < nloc
        my_gid = jnp.where(valid_loc, lo + li, -1)
        ext_gid = jnp.concatenate([my_gid, gids])       # (n_loc_max + G,)
        valid_e = nbr >= 0
        nb = jnp.where(valid_e, nbr, 0)
        ewf = ew.astype(jnp.float32)
        # proposer gid of every (shard, row) of the gathered proposal
        # buffers; every shard can compute it from vtxdist alone
        prop_gid_flat = (vtxdist[:nparts, None]
                         + jnp.arange(n_loc_max, dtype=jnp.int32)[None, :]
                         ).reshape(-1)

        def round_fn(match, r):
            unmatched = (match < 0) & valid_loc
            ext_unm = _halo_local(unmatched.astype(jnp.int32), gids,
                                  vtxdist) != 0
            # hash coin: any shard can evaluate any vertex's side locally
            is_prop_ext = (hash_mix(ext_gid, r, seed) & 1) == 1
            # --- propose: heaviest unmatched acceptor neighbor
            tgt_slots = ext_gid[nb]                     # (n_loc_max, d)
            cand = (valid_e & ext_unm[nb] & ~is_prop_ext[nb]
                    & (tgt_slots >= 0))
            tie = hash_unit(my_gid[:, None], tgt_slots, r + 17)
            score = jnp.where(cand, ewf + tie, -jnp.inf)
            slot = jnp.argmax(score, axis=1)
            has = jnp.any(cand, axis=1) & unmatched & is_prop_ext[:n_loc_max]
            prop_tgt = jnp.where(has, tgt_slots[li, slot], -1)
            prop_w = jnp.where(has, ewf[li, slot], 0.0)

            # --- grant: every shard grants for its own local acceptors
            allt = jax.lax.all_gather(prop_tgt, "parts").reshape(-1)
            allw = jax.lax.all_gather(prop_w, "parts").reshape(-1)
            mine = (allt >= lo) & (allt < lo + nloc)
            seg = jnp.where(mine, allt - lo, n_loc_max)
            gsc = allw + hash_unit(prop_gid_flat, allt, r + 31)
            gsc = jnp.where(mine, gsc, -jnp.inf)
            best = jax.ops.segment_max(gsc, seg,
                                       num_segments=n_loc_max + 1)
            is_best = mine & (gsc >= best[seg])
            winner = jax.ops.segment_min(
                jnp.where(is_best, prop_gid_flat, INT_MAX), seg,
                num_segments=n_loc_max + 1)[:n_loc_max]
            can_accept = unmatched & ~is_prop_ext[:n_loc_max]
            grant = jnp.where(can_accept & (winner < INT_MAX), winner, -1)

            # --- notify: proposers read their target's grant
            allg = jax.lax.all_gather(grant, "parts")   # (P, n_loc_max)
            tsafe = jnp.maximum(prop_tgt, 0)
            owner_t = jnp.clip(
                jnp.searchsorted(vtxdist, tsafe, side="right") - 1,
                0, nparts - 1)
            loc_t = jnp.clip(tsafe - vtxdist[owner_t], 0, n_loc_max - 1)
            got = (prop_tgt >= 0) & (allg[owner_t, loc_t] == my_gid)
            match = jnp.where(got, prop_tgt, match)
            match = jnp.where(grant >= 0, grant, match)
            return match, None

        match0 = jnp.full((n_loc_max,), -1, dtype=jnp.int32)
        match, _ = jax.lax.scan(round_fn, match0,
                                jnp.arange(rounds, dtype=jnp.int32))
        return match[None]

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("parts", None, None), P("parts", None, None),
                             P("parts", None), P(None), P("parts"), P(None)),
                   out_specs=P("parts", None))
    return jax.jit(fn)


def distributed_matching(dg: DGraph, seed: int, rounds: int = 8,
                         flat: bool = True) -> np.ndarray:
    """Synchronous probabilistic heavy-edge matching across shards.

    The paper's request/grant protocol (§3.2) with the collectives of this
    file: each round, unmatched proposers pick their heaviest unmatched
    acceptor neighbor (ghosts included, via halo exchange of the unmatched
    mask); proposals are gathered; every shard grants the best proposal for
    each of its local acceptors; grants are gathered back and both ends
    commit.  Coin flips and tiebreaks are hashes of (gid, round, seed), so
    every shard evaluates any vertex's state without extra messages — and
    the result is independent of the shard layout.

    With ``flat`` (legacy contract) the matching is gathered into a flat
    global (n,) array with match[v] = v for singletons — same contract as
    ``matching.heavy_edge_matching``.  With ``flat=False`` it stays
    sharded: (P, n_loc_max) mate global ids (-1 on padding), the form
    ``dgraph_coarsen`` consumes — no centralization at any size.
    """
    fn = _matching_jit(dg.nparts, dg.n_loc_max, dg.nbr_gst.shape[2],
                       dg.ghost_gid.shape[1], rounds)
    m = fn(jnp.asarray(dg.nbr_gst), jnp.asarray(dg.ewgt_gst, jnp.int32),
           jnp.asarray(dg.ghost_gid, jnp.int32),
           jnp.asarray(dg.vtxdist, jnp.int32),
           jnp.asarray(dg.n_loc, jnp.int32),
           jnp.asarray([seed & 0x7FFFFFFF], jnp.int32))
    gid = shard_gids(dg)
    valid = gid >= 0
    m_sh = np.asarray(m).astype(np.int64)
    m_sh = np.where(valid & (m_sh >= 0) & (m_sh < dg.n_global), m_sh, gid)
    # defensive symmetry repair (protocol is symmetric by construction):
    # each vertex checks its mate's mate via an owner-routed pull
    mate_of_mate = pull_by_gid(dg, m_sh, m_sh, fill=-1)
    m_sh = np.where(valid & (mate_of_mate == gid), m_sh, gid)
    if flat:
        return unshard_vector(dg, m_sh)
    return m_sh
