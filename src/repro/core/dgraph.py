"""Distributed graph structure + halo exchange (paper §2.1), shard_map form.

The paper's structure maps onto JAX as stacked per-shard arrays with a
``parts`` mesh axis:

  * ``vtxdist``      — the paper's ``procvrttab``: global vertex ranges per
    shard (duplicated everywhere, owner lookup by range search);
  * ``nbr_gst``      — the paper's ``edgegsttab``: ELL adjacency in *compact
    local indexing* where indices < n_loc_max are local and indices ≥
    n_loc_max address the ghost slots, numbered by (owner, global id) — the
    cache-friendly agglomeration order of §2.1;
  * ``ewgt_gst``     — matching ELL edge weights (heavy-edge matching on
    coarse levels needs them);
  * ``ghost_gid``    — global ids of ghost slots per shard (the receive
    manifest of the halo exchange).

``halo_exchange`` diffuses local vertex values to the ghost copies on
neighboring shards: the reference implementation is an ``all_gather`` over
the parts axis + gather (dense collective — the TPU-idiomatic replacement
for MPI point-to-point; DESIGN.md §2 discusses the trade).

All device functions take the per-graph arrays (``vtxdist``, ``ghost_gid``,
…) as *traced arguments* and are cached per padded shape, so the jit cache
is shared across every subgraph of a nested-dissection recursion that lands
in the same power-of-two bucket (same bucketing the centralized data plane
uses, ``repro.util.pow2``).

Scalability note (matching the paper): no shard stores ghost *adjacency* —
only ghost values — so per-shard memory is O(local arcs).

Two kinds of routines live here (DESIGN.md §4):

  * **device collectives** (``halo_exchange_fn``, ``distributed_bfs``,
    ``distributed_matching``) — ``shard_map`` programs over the parts axis.
    Each is the one-lane special case of its **lane-stacked** form
    (``halo_exchange_stacked``, ``distributed_bfs_stacked``,
    ``distributed_matching_stacked``): same-bucket graphs stack along a
    leading lane axis and ONE launch — with one fused ``all_gather`` per
    internal round for the whole stack — serves all of them.  Per-lane
    reductions are within-lane, so lane-stacked results are bit-identical
    to singleton execution (the frontier driver of ``core.dnd`` relies on
    this, exactly as ``fm.execute_fm_works`` does for FM lanes).
  * **structure rebuilds** (``distribute``, ``dgraph_induced``,
    ``dgraph_fold``, ``dgraph_coarsen``) — host-side reshuffles of the
    stacked arrays that model the owner-routed ``MPI_Alltoallv`` of the
    paper's redistribution steps.  They stage the routed arcs in flat
    arrays (the analog of the exchange's send/receive buffers, O(arcs)
    words), never a centralized CSR graph.

All instrumentation hangs off ONE entry point, ``instrument()``: the
centralizing gathers (``to_host`` / ``unshard_vector`` element counts, the
gather-free guarantee), host-level halo exchanges (the per-round band sync
budget), per-launch collective counters (kind, lanes, all_gather words —
how the frontier driver's launch budget is asserted), sharded-band
refinement stats, per-stage wall-clock, and frontier wave summaries.
``track_gathers`` / ``track_halos`` (and ``dnd.track_band_stats``) are
thin compatibility views over the same block.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import obs
from repro.core.graph import Graph
from repro.core.matching import hash_mix, hash_unit
from repro.util import pow2


@dataclasses.dataclass
class DGraph:
    """Host-resident description of a P-way distributed graph."""
    vtxdist: np.ndarray        # (P+1,) global ranges
    nbr_gst: np.ndarray        # (P, n_loc_max, dmax) compact local/ghost ids
    ewgt_gst: np.ndarray       # (P, n_loc_max, dmax) edge weights (0 pad)
    ghost_gid: np.ndarray      # (P, n_ghost_max) global ids of ghosts (-1 pad)
    n_loc: np.ndarray          # (P,) real local counts
    n_ghost: np.ndarray        # (P,) real ghost counts
    vwgt: np.ndarray           # (P, n_loc_max)

    @property
    def nparts(self) -> int:
        return len(self.vtxdist) - 1

    @property
    def n_loc_max(self) -> int:
        return self.nbr_gst.shape[1]

    @property
    def n_global(self) -> int:
        return int(self.vtxdist[-1])


def _build_dgraph(vtxdist: np.ndarray, src: np.ndarray, dst: np.ndarray,
                  w: np.ndarray, vwgt: np.ndarray,
                  bucket: bool = True) -> DGraph:
    """Assemble the stacked shard arrays from an owner-routed arc list.

    The shared back end of every structure rebuild (``distribute``,
    ``dgraph_induced``, ``dgraph_fold``, ``dgraph_coarsen``).  ``src`` /
    ``dst`` / ``w`` are flat *directed* arc arrays in global ids (each
    undirected edge appears in both directions) — the staging buffers of
    the owner-routed Alltoallv that the paper's redistribution performs;
    ``vwgt`` is the flat (n,) vertex-weight vector in global-id order.
    Parallel arcs are deduplicated with accumulated weights (exactly
    ``Graph.from_edges``'s canonicalization), so rebuilding through here
    matches the centralized builders arc-for-arc.

    Timed as the ``rebuild`` stage (every structure rebuild funnels
    through here), so the bench's per-stage wall-clock breakdown can
    separate host reshuffles from device collectives.
    """
    with stage("rebuild"):
        return _build_dgraph_impl(vtxdist, src, dst, w, vwgt, bucket=bucket)


def _build_dgraph_impl(vtxdist, src, dst, w, vwgt, bucket=True) -> DGraph:
    vtxdist = np.asarray(vtxdist, dtype=np.int64)
    nparts = len(vtxdist) - 1
    n = int(vtxdist[-1])
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    w = np.asarray(w, dtype=np.int64)
    if len(src):
        order = np.lexsort((dst, src))
        src, dst, w = src[order], dst[order], w[order]
        uniq = np.concatenate(
            [[True], (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])])
        seg = np.cumsum(uniq) - 1
        wacc = np.zeros(seg[-1] + 1, dtype=np.int64)
        np.add.at(wacc, seg, w)
        src, dst, w = src[uniq], dst[uniq], wacc

    n_loc = np.diff(vtxdist)
    n_loc_max = int(n_loc.max()) if nparts else 1
    deg = np.bincount(src, minlength=max(n, 1))[:max(n, 1)]
    dmax = int(deg.max()) if len(src) else 1
    if bucket:
        n_loc_max = pow2(max(n_loc_max, 1), 8)
        dmax = pow2(max(dmax, 1), 4)
    n_loc_max = max(n_loc_max, 1)
    dmax = max(dmax, 1)

    owner = np.searchsorted(vtxdist, np.arange(n), side="right") - 1
    p_src = owner[src]
    xadj = np.concatenate([[0], np.cumsum(deg)])
    col = np.arange(len(dst)) - xadj[src]
    li_src = src - vtxdist[p_src]
    remote = p_src != owner[dst]

    # ghost manifests: unique (shard, gid) pairs among remote arc heads.
    # Ascending gid is ascending (owner, gid) because vtxdist is sorted —
    # the §2.1 cache-friendly agglomeration order.
    keys = p_src[remote] * np.int64(max(n, 1)) + dst[remote]
    uk = np.unique(keys)
    gp = uk // max(n, 1)
    ggid = uk % max(n, 1)
    counts = np.bincount(gp, minlength=nparts)
    offs = np.concatenate([[0], np.cumsum(counts)])
    gslot = np.arange(len(uk)) - offs[gp]
    n_ghost = counts.astype(np.int64)
    n_ghost_max = max(int(n_ghost.max()) if nparts else 0, 1)
    if bucket:
        n_ghost_max = pow2(n_ghost_max, 4)
    ghost_gid = -np.ones((nparts, n_ghost_max), dtype=np.int64)
    ghost_gid[gp, gslot] = ggid

    nbr_gst = -np.ones((nparts, n_loc_max, dmax), dtype=np.int32)
    ewgt_gst = np.zeros((nparts, n_loc_max, dmax), dtype=np.int32)
    cidx = dst - vtxdist[owner[dst]] if len(dst) else dst
    if len(uk):
        cidx[remote] = n_loc_max + gslot[np.searchsorted(uk, keys)]
    nbr_gst[p_src, li_src, col] = cidx
    ewgt_gst[p_src, li_src, col] = w

    vwgt_sh = np.zeros((nparts, n_loc_max), dtype=np.int64)
    vwgt_sh[owner, np.arange(n) - vtxdist[owner]] = np.asarray(vwgt, np.int64)
    return DGraph(vtxdist, nbr_gst, ewgt_gst, ghost_gid, n_loc, n_ghost,
                  vwgt_sh)


def distribute(g: Graph, nparts: int,
               vtxdist: Optional[np.ndarray] = None,
               bucket: bool = True) -> DGraph:
    """Distribute a host graph (the paper's user-defined ranges).

    Args:
      g: centralized host graph (symmetric CSR).
      nparts: number of shards P.
      vtxdist: optional (P+1,) custom ownership ranges (the coarse graphs
        of distributed coarsening keep coarse vertices on the owner of
        their representative); the default is a balanced block
        distribution.
      bucket: round padded shard shapes up to powers of two so jitted
        collectives are reused across same-bucket subgraphs.

    Returns a ``DGraph`` whose stacked arrays hold g partitioned by
    ``vtxdist`` ranges.
    """
    n = g.n
    if vtxdist is None:
        vtxdist = np.linspace(0, n, nparts + 1).astype(np.int64)
    else:
        vtxdist = np.asarray(vtxdist, dtype=np.int64)
        assert len(vtxdist) == nparts + 1 and vtxdist[-1] == n
    src = np.repeat(np.arange(n, dtype=np.int64), g.degrees())
    return _build_dgraph(vtxdist, src, g.adjncy, g.adjwgt, g.vwgt,
                         bucket=bucket)


@functools.lru_cache(maxsize=None)
def make_parts_mesh(nparts: int) -> Mesh:
    devs = jax.devices()[:nparts]
    assert len(devs) == nparts, (
        f"need {nparts} devices, have {len(jax.devices())} "
        "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return Mesh(np.array(devs), ("parts",))


# ------------------------------------------------------------------ #
# bounded jit-builder cache (router-managed data-plane policy)
# ------------------------------------------------------------------ #
class _JitCache:
    """LRU over the stacked-collective jit executables.

    A long-lived service accumulates (bucket, lanes, …) shape keys
    without bound — every new pow2 bucket × lane count × rounds/width
    combination is a fresh executable.  This cache caps them: keys are
    *identical* to the ``obs.first_use`` dispatch keys, so an eviction
    calls ``obs.forget_use(key)`` and the re-build after re-insertion
    bills itself as a compile again (not a suspiciously slow dispatch).
    The live entry count is mirrored into the ``repro_jit_cache_size``
    metric (evictions counted by ``repro_jit_cache_evictions_total``).

    Capacity comes from ``RouterConfig.jit_cache_capacity`` via
    ``set_jit_cache_capacity`` (env default ``REPRO_JIT_CACHE_CAP``);
    ``repro.core`` never imports the service layer, so the setter is
    the interface.
    """

    def __init__(self, capacity: int):
        self._cap = max(int(capacity), 1)
        self._entries: "OrderedDict[Tuple, object]" = OrderedDict()
        self._lock = threading.Lock()

    def get(self, key: Tuple, builder):
        with self._lock:
            fn = self._entries.get(key)
            if fn is not None:
                self._entries.move_to_end(key)
                return fn
        fn = builder()                  # build outside the lock (slow)
        with self._lock:
            if key in self._entries:    # lost a build race: keep theirs
                fn = self._entries[key]
            else:
                self._entries[key] = fn
                obs.REGISTRY.inc("repro_jit_cache_size")
            self._entries.move_to_end(key)
            self._trim()
        return fn

    def _trim(self) -> None:            # caller holds the lock
        while len(self._entries) > self._cap:
            old_key, _ = self._entries.popitem(last=False)
            obs.forget_use(old_key)
            obs.REGISTRY.inc("repro_jit_cache_size", -1.0)
            obs.REGISTRY.inc("repro_jit_cache_evictions_total")

    def set_capacity(self, capacity: int) -> None:
        with self._lock:
            self._cap = max(int(capacity), 1)
            self._trim()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


_JIT_CACHE = _JitCache(int(os.environ.get("REPRO_JIT_CACHE_CAP", "64")))


def set_jit_cache_capacity(capacity: int) -> None:
    """Bound the stacked-collective jit cache (RouterConfig surface)."""
    _JIT_CACHE.set_capacity(capacity)


def jit_cache_size() -> int:
    """Live stacked-collective executables (tests / metrics cross-check)."""
    return len(_JIT_CACHE)


#: compact the matching proposal gather (RouterConfig surface; lossless,
#: see ``distributed_matching_stacked``)
_MATCH_COMPACT = os.environ.get("REPRO_MATCH_COMPACT", "1") != "0"


def set_match_compact(on: bool) -> None:
    global _MATCH_COMPACT
    _MATCH_COMPACT = bool(on)


# ------------------------------------------------------------------ #
# instrumentation: one entry point for every counter (DESIGN.md §4)
# ------------------------------------------------------------------ #
@dataclasses.dataclass(eq=False)      # identity semantics: nested blocks
class Instrumentation:                # with equal contents must not alias
    """Counters recorded by one ``instrument()`` block.

    ``gathers``   — one ``(kind, n_elements)`` per centralizing gather
      (``to_host`` / ``unshard_vector``); the gather-free tests bound it.
    ``halos``     — exchanged element count (P · n_loc_max words) per
      host-level halo exchange, one entry per *work*: a lane-stacked
      launch serving L works appends L entries, so this keeps measuring
      the per-task synchronization budget the band tests bound.
      Exchanges fused inside jitted sweeps (BFS relaxations, matching
      rounds) are not counted.
    ``launches``  — one dict per device launch:
      ``{"kind", "nparts", "lanes", "lanes_pad", "bucket", "rounds",
      "words"}``.  Distributed ``shard_map`` collectives record kinds
      ``dhalo`` / ``dbfs`` / ``dmatch`` with ``words`` = the launch's
      total ``all_gather`` traffic in elements summed over its internal
      rounds; the centralized bucketed executors record ``fm`` / ``bfs``
      / ``match`` (nparts 0, words 0) per dispatch.  This is the counter
      behind the frontier driver's launch-budget assertions (the wave
      summaries count *these records*, not their own bookkeeping) and
      the matching grant-compaction measurement.
    ``band_stats``— one dict per sharded-band refinement (appended by
      ``dnd``'s band task; see ``dnd.track_band_stats``).
    ``stage_s``   — accumulated wall-clock seconds per pipeline stage
      (``match`` / ``bfs`` / ``halo`` / ``fm`` / ``rebuild`` /
      ``endgame``).  Stages are attribution *categories*, not disjoint
      intervals: ``endgame`` times the whole deferred-subtree batch and
      therefore contains the ``fm`` / ``bfs`` / ``match`` shares its
      executors bill.
    ``stage_detail`` — per stage, the compile/dispatch split:
      ``{stage: {"compile_s", "dispatch_s"}}``.  A dispatch whose jit
      cache key (mirroring the builder's ``lru_cache`` key) is seen for
      the first time bills its whole wall to ``compile_s`` (trace +
      lower + XLA compile, or a persistent-cache load); steady-state
      repeats bill ``dispatch_s``.
    ``waves``     — one summary dict per frontier wave (appended by the
      frontier driver): outstanding works / shape buckets / launches /
      wall-clock (``t_s``) / per-stage seconds (``stage_s``) by kind.
    """
    gathers: List[Tuple[str, int]] = dataclasses.field(default_factory=list)
    halos: List[int] = dataclasses.field(default_factory=list)
    launches: List[dict] = dataclasses.field(default_factory=list)
    band_stats: List[dict] = dataclasses.field(default_factory=list)
    stage_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    waves: List[dict] = dataclasses.field(default_factory=list)
    stage_detail: Dict[str, Dict[str, float]] = \
        dataclasses.field(default_factory=dict)

    def on_event(self, kind: str, payload: dict) -> None:
        """Event-bus entry point (called with the bus lock held, so the
        read-modify-write ``stage_s`` accumulation is atomic under
        concurrent emitters)."""
        if kind == "gather":
            self.gathers.append((payload["kind"], payload["n"]))
        elif kind == "halo":
            self.halos.append(payload["n"])
        elif kind == "launch":
            self.launches.append(payload)      # the shared launch record
        elif kind == "band_stats":
            self.band_stats.append(payload)
        elif kind == "stage":
            name, sec = payload["name"], float(payload["seconds"])
            self.stage_s[name] = self.stage_s.get(name, 0.0) + sec
            d = self.stage_detail.setdefault(
                name, {"compile_s": 0.0, "dispatch_s": 0.0})
            d["compile_s" if payload.get("compile") else "dispatch_s"] += sec
        elif kind == "wave":
            self.waves.append(payload)         # the shared wave summary


@contextlib.contextmanager
def instrument():
    """Record all data-plane counters executed inside the block.

    Yields an ``Instrumentation``.  Blocks nest: every active block
    receives every event (so a ``track_halos()`` view inside a broader
    ``instrument()`` sees the same exchanges the outer block does).
    Registration lives on the ``repro.obs`` event bus, whose lock makes
    concurrent emitters (a service drain thread under a caller-thread
    reader) safe; removal is **by identity** so nested blocks with equal
    contents never evict each other.
    """
    ins = Instrumentation()
    obs.register_collector(ins)
    try:
        yield ins
    finally:
        obs.unregister_collector(ins)


@contextlib.contextmanager
def track_gathers():
    """Compat view over ``instrument()``: yields its ``gathers`` list."""
    with instrument() as ins:
        yield ins.gathers


@contextlib.contextmanager
def track_halos():
    """Compat view over ``instrument()``: yields its ``halos`` list."""
    with instrument() as ins:
        yield ins.halos


def _note_gather(kind: str, size: int) -> None:
    obs.emit("gather", {"kind": kind, "n": int(size)})


def _note_halo(size: int) -> None:
    obs.emit("halo", {"n": int(size)})


def _note_launch(kind: str, nparts: int, lanes: int, lanes_pad: int,
                 bucket: Tuple[int, ...], rounds: int, words: int,
                 **extra) -> None:
    """``extra`` carries launch-specific metadata: ``tags`` (per-lane
    request attribution from the wave router), ``cap`` / ``words_dense``
    (the matching proposal-gather compaction measurement)."""
    payload = {"kind": kind, "nparts": int(nparts),
               "lanes": int(lanes), "lanes_pad": int(lanes_pad),
               "bucket": tuple(bucket), "rounds": int(rounds),
               "words": int(words)}
    payload.update(extra)
    obs.emit("launch", payload)


def _note_band_stats(stats: dict) -> None:
    obs.emit("band_stats", stats)


def _note_stage(name: str, seconds: float, compile: bool = False) -> None:
    obs.emit("stage", {"name": name, "seconds": float(seconds),
                       "compile": compile})


def _note_wave(summary: dict) -> None:
    obs.emit("wave", summary)


@contextlib.contextmanager
def stage(name: str):
    """Time a pipeline stage into every active ``instrument()`` block,
    and open a ``stage:{name}`` span when tracing is enabled (host-side
    stages — ``rebuild``, ``endgame`` — get their trace attribution
    here; device dispatches use ``obs.timed_dispatch`` instead)."""
    t0 = time.perf_counter()
    with obs.span(f"stage:{name}"):
        try:
            yield
        finally:
            _note_stage(name, time.perf_counter() - t0)


# ------------------------------------------------------------------ #
# sharded <-> flat host vectors
# ------------------------------------------------------------------ #
def shard_vector(dg: DGraph, x: np.ndarray, fill=0) -> np.ndarray:
    """Flat global (n,) -> sharded (P, n_loc_max) (padding = fill).

    A scatter (host value distributed *out* to shards), so it is not part
    of the instrumented gather API.
    """
    out = np.full((dg.nparts, dg.n_loc_max), fill, dtype=np.asarray(x).dtype)
    for p in range(dg.nparts):
        lo, hi = dg.vtxdist[p], dg.vtxdist[p + 1]
        out[p, :hi - lo] = x[lo:hi]
    return out


def _raster_flat(dg: DGraph, xs: np.ndarray) -> np.ndarray:
    """Sharded (P, n_loc_max) -> flat (n,) without touching the gather log.

    Internal staging primitive for the structure rebuilds; user-facing
    centralization must go through ``unshard_vector`` so it is counted.
    """
    xs = np.asarray(xs)
    li = np.arange(dg.n_loc_max)
    keep = (li[None, :] < dg.n_loc[:, None]).reshape(-1)
    return xs.reshape(dg.nparts * dg.n_loc_max, *xs.shape[2:])[keep]


def unshard_vector(dg: DGraph, xs: np.ndarray) -> np.ndarray:
    """Gather a sharded (P, n_loc_max) vector into a flat global (n,).

    One of the two instrumented centralizing gathers (with ``to_host``);
    the gather-free pipeline only applies it to sub-threshold objects.
    """
    _note_gather("unshard_vector", dg.n_global)
    return _raster_flat(dg, xs)


def shard_gids(dg: DGraph) -> np.ndarray:
    """(P, n_loc_max) global vertex id per local slot (-1 on padding)."""
    li = np.arange(dg.n_loc_max, dtype=np.int64)
    gid = dg.vtxdist[:-1, None] + li[None, :]
    return np.where(li[None, :] < dg.n_loc[:, None], gid, -1)


def valid_mask(dg: DGraph) -> np.ndarray:
    """(P, n_loc_max) bool: True on real local slots, False on padding."""
    li = np.arange(dg.n_loc_max)
    return li[None, :] < dg.n_loc[:, None]


def pull_by_gid(dg: DGraph, values_sh: np.ndarray, gid: np.ndarray,
                fill=0) -> np.ndarray:
    """Owner-routed value pull: out[...] = values of vertices ``gid``.

    ``values_sh`` is a (P, n_loc_max) sharded vector on ``dg``'s layout;
    ``gid`` is any-shape global ids (< 0 yields ``fill``).  This is the
    host-side model of the paper's point-to-point value fetch (the same
    owner lookup the halo exchange performs on device); data volume is
    O(len(gid)) words, independent of graph size.
    """
    gid = np.asarray(gid, dtype=np.int64)
    ok = (gid >= 0) & (gid < dg.n_global)
    gsafe = np.clip(gid, 0, max(dg.n_global - 1, 0))
    owner = np.searchsorted(dg.vtxdist, gsafe, side="right") - 1
    owner = np.clip(owner, 0, dg.nparts - 1)
    li = np.clip(gsafe - dg.vtxdist[owner], 0, dg.n_loc_max - 1)
    out = np.asarray(values_sh)[owner, li]
    return np.where(ok, out, fill)


def scatter_by_gid(dg: DGraph, target_sh: np.ndarray, gid: np.ndarray,
                   vals: np.ndarray) -> np.ndarray:
    """Owner-routed value push: write ``vals`` at vertices ``gid``.

    The inverse of ``pull_by_gid``: returns a copy of ``target_sh``
    (a (P, n_loc_max) sharded vector on ``dg``'s layout) with
    ``vals[k]`` written to the owner slot of ``gid[k]`` (negative ids
    skipped).  Models the project-back message of band refinement; data
    volume is O(len(gid)) words.
    """
    gid = np.asarray(gid, dtype=np.int64).reshape(-1)
    vals = np.asarray(vals).reshape(-1)
    ok = (gid >= 0) & (gid < dg.n_global)
    gid, vals = gid[ok], vals[ok]
    owner = np.searchsorted(dg.vtxdist, gid, side="right") - 1
    out = np.asarray(target_sh).copy()
    out[owner, gid - dg.vtxdist[owner]] = vals
    return out


def reshard_vector(src_dg: DGraph, dst_dg: DGraph, xs: np.ndarray,
                   fill=0) -> np.ndarray:
    """Move a sharded vector between two layouts of the *same* vertex set.

    Used when fold-dup rejoins: the winning duplicate's part vector lives
    on the folded layout and is pulled back onto the full group's layout.
    """
    assert src_dg.n_global == dst_dg.n_global
    return pull_by_gid(src_dg, xs, shard_gids(dst_dg), fill=fill)


# ------------------------------------------------------------------ #
# boundary masks + deterministic coloring (alternating-color schedule)
# ------------------------------------------------------------------ #
def np_hash_mix(x: np.ndarray, *salts: int) -> np.ndarray:
    """lowbias32 chain on int arrays (numpy mirror of matching.hash_mix).

    Every shard evaluates the same pure function of global ids alone, so
    symmetric rules (conflict-repair losers, boundary colors) need no
    extra messages — the same argument as the matching protocol's coins.
    """
    def lb(v):
        v = v ^ (v >> np.uint32(16))
        v = v * np.uint32(0x7FEB352D)
        v = v ^ (v >> np.uint32(15))
        v = v * np.uint32(0x846CA68B)
        return v ^ (v >> np.uint32(16))

    h = np.full(np.shape(x), 0x9E3779B9, dtype=np.uint32)
    for v in (x,) + salts:
        v = np.asarray(v).astype(np.uint32)
        h = lb(h ^ (v * np.uint32(0x85EBCA6B) + np.uint32(1)))
    return h


def boundary_mask(dg: DGraph) -> np.ndarray:
    """(P, n_loc_max) bool: local vertices with ≥ 1 cross-shard edge.

    A vertex is *boundary* when any ELL slot addresses the ghost ring
    (compact index ≥ n_loc_max).  Interior vertices can never create a
    cross-shard 0–1 edge, so refinement schedules only need to gate the
    boundary set.
    """
    return (dg.nbr_gst >= dg.n_loc_max).any(axis=2) & valid_mask(dg)


def color_by_gid(dg: DGraph, salt: int = 0, exchange: bool = True
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic two-coloring of vertices by gid hash (§3.3 schedule).

    Returns ``(hash_ext, color_ext)``, both (P, n_loc_max + n_ghost_max):
    the full uint32 hash (for tiebreaks on monochromatic edges) and the
    color (hash & 1, int8; -1 on padding) for every local slot *and* its
    ghost ring.  Local colors are computed from ``shard_gids``; ghost
    colors are the same pure hash of ``ghost_gid``, so owner and
    neighbor always agree with no messages.  With ``exchange`` the ghost
    colors are additionally halo-exchanged from the owners and
    cross-checked against the local recomputation — callers that
    re-color every round (the alternating-color band schedule rotates
    the salt to avoid starving tiebreak losers) validate the first
    coloring this way and skip the exchange for the rest, keeping the
    per-round exchange budget flat.
    """
    gid = shard_gids(dg)
    h_loc = np_hash_mix(np.maximum(gid, 0), salt & 0x7FFFFFFF)
    h_gst = np_hash_mix(np.maximum(dg.ghost_gid, 0), salt & 0x7FFFFFFF)
    hash_ext = np.concatenate([h_loc, h_gst], axis=1)
    col_loc = np.where(gid >= 0, (h_loc & 1).astype(np.int32), -1)
    gok = dg.ghost_gid >= 0
    if exchange:
        col_ext = np.asarray(halo_exchange_fn(dg)(col_loc))
        assert np.array_equal(np.where(gok, col_ext[:, dg.n_loc_max:], 0),
                              np.where(gok, h_gst & 1, 0)), \
            "halo-exchanged ghost colors disagree with the gid hash"
    color_ext = np.concatenate(
        [col_loc, np.where(gok, (h_gst & 1).astype(np.int32), -1)],
        axis=1).astype(np.int8)
    return hash_ext, color_ext


# ------------------------------------------------------------------ #
# structure rebuilds (host-modelled Alltoallv; DESIGN.md §4)
# ------------------------------------------------------------------ #
def dgraph_arcs(dg: DGraph) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Flat directed arc triples (src_gid, dst_gid, w) of the structure.

    The staging form every rebuild routes through; both directions of
    each undirected edge are present (ELL rows are symmetric).
    """
    nlm = dg.n_loc_max
    p, li, slot = np.nonzero(dg.nbr_gst >= 0)
    c = dg.nbr_gst[p, li, slot].astype(np.int64)
    src = dg.vtxdist[p] + li
    loc = c < nlm
    dst = np.where(loc, dg.vtxdist[p] + c,
                   dg.ghost_gid[p, np.maximum(c - nlm, 0)])
    w = dg.ewgt_gst[p, li, slot].astype(np.int64)
    return src, dst, w


def to_host(dg: DGraph) -> Graph:
    """Gather the distributed structure back into one centralized Graph.

    The §3.1 centralization step: below the sequential threshold the
    subgraph is gathered onto one process and ordered there.  Instrumented
    (see ``track_gathers``): the gather-free pipeline only calls this on
    sub-threshold subgraphs, coarsest graphs, and band graphs.
    """
    _note_gather("to_host", dg.n_global)
    src, dst, w = dgraph_arcs(dg)
    keep = src < dst                      # one direction; from_edges mirrors
    vwgt = _raster_flat(dg, dg.vwgt)
    return Graph.from_edges(dg.n_global,
                            np.stack([src[keep], dst[keep]], 1),
                            vwgt=vwgt, ewgt=w[keep])


def dgraph_induced(dg: DGraph, keep_sh: np.ndarray,
                   nparts: Optional[int] = None,
                   payloads: Sequence[np.ndarray] = (),
                   fills: Sequence = (),
                   bucket: bool = True
                   ) -> Tuple[DGraph, List[np.ndarray]]:
    """Distributed induced subgraph (paper §3.1, gather-free form).

    Args:
      keep_sh: (P, n_loc_max) bool mask of kept vertices (padding slots
        ignored).
      nparts: target shard count.  ``None`` keeps every kept vertex on its
        current owner (in-place extraction — the band path); an integer
        redistributes onto balanced blocks over that many shards (the
        paper folds each separated part onto its child process group).
      payloads: per-vertex (P, n_loc_max) arrays (e.g. original-id
        vectors) to carry onto the new layout.
      fills: padding fill value per payload (default 0).

    Kept vertices are renumbered by ascending global id, so the induced
    numbering is independent of the shard layout; new ownership ranges
    come from a prefix sum over per-shard keep counts (the offset
    exchange of the paper's redistribution).  Returns the sub-DGraph and
    the payloads mapped onto its layout.
    """
    keep = np.asarray(keep_sh, dtype=bool) & valid_mask(dg)
    counts = keep.sum(axis=1).astype(np.int64)
    n_new = int(counts.sum())
    if nparts is None:
        new_vtxdist = np.concatenate([[0], np.cumsum(counts)])
    else:
        new_vtxdist = np.linspace(0, n_new, nparts + 1).astype(np.int64)

    # rank kept vertices in shard-major raster order == ascending gid
    flatk = keep.reshape(-1)
    newid_flat = -np.ones(dg.n_global, dtype=np.int64)
    old_gid = shard_gids(dg).reshape(-1)[flatk]          # ascending
    newid_flat[old_gid] = np.arange(n_new)

    src, dst, w = dgraph_arcs(dg)
    ns, nd = newid_flat[src], newid_flat[dst]
    ka = (ns >= 0) & (nd >= 0)
    vwgt_new = dg.vwgt.reshape(-1)[flatk]
    sub = _build_dgraph(new_vtxdist, ns[ka], nd[ka], w[ka], vwgt_new,
                        bucket=bucket)
    mapped = []
    for i, pay in enumerate(payloads):
        fill = fills[i] if i < len(fills) else 0
        flat = np.asarray(pay).reshape(-1)[flatk]        # by new gid
        mapped.append(shard_vector(sub, flat, fill=fill))
    return sub, mapped


def dgraph_fold(dg: DGraph, bucket: bool = True) -> DGraph:
    """Fold the structure onto ⌈P/2⌉ shards (paper §3.2).

    Adjacent shard pairs merge (ownership ranges stay contiguous); global
    vertex ids are unchanged, so sharded vectors move between the two
    layouts with ``reshard_vector``.  Each fold-dup half runs an
    independent multilevel instance on (a duplicate of) the folded
    structure.
    """
    new_vtxdist = np.concatenate([dg.vtxdist[:-1:2], dg.vtxdist[-1:]])
    src, dst, w = dgraph_arcs(dg)
    vwgt = _raster_flat(dg, dg.vwgt)
    return _build_dgraph(new_vtxdist, src, dst, w, vwgt, bucket=bucket)


def dgraph_coarsen(dg: DGraph, match_sh: np.ndarray,
                   bucket: bool = True) -> Tuple[DGraph, np.ndarray]:
    """Distributed coarse-graph build from a sharded matching (§3.2).

    ``match_sh`` is (P, n_loc_max) mate global ids (self for singletons,
    as ``distributed_matching(..., flat=False)`` returns).  Each coarse
    vertex lives on the owner of its *representative* (min endpoint of
    the matched pair), so no vertex migrates at a coarsening step; coarse
    ownership ranges are the prefix sum of per-shard representative
    counts (identical to ``coarsen.coarse_vtxdist``), and the coarse
    numbering matches the centralized ``coarsen_once`` bit-for-bit.

    Returns ``(coarse_dg, cmap_sh)`` with cmap_sh[p, i] = coarse global
    id of fine local vertex i on shard p (-1 on padding).
    """
    gid = shard_gids(dg)
    valid = gid >= 0
    match = np.where(valid, np.asarray(match_sh, dtype=np.int64), -1)
    match = np.where(valid & (match >= 0) & (match < dg.n_global),
                     match, gid)
    rep = np.minimum(gid, match)
    is_rep = valid & (rep == gid)
    counts = is_rep.sum(axis=1).astype(np.int64)
    cvtxdist = np.concatenate([[0], np.cumsum(counts)])

    crank = (np.cumsum(is_rep.reshape(-1)) - 1).reshape(is_rep.shape)
    cmap_rep = np.where(is_rep, crank, np.int64(-1))
    # non-representatives read their mate's coarse id from its owner (the
    # mate is always the representative: rep = min of the pair)
    cmap_mate = pull_by_gid(dg, cmap_rep, match, fill=-1)
    cmap_sh = np.where(is_rep, cmap_rep, cmap_mate)
    assert int((cmap_sh[valid] < 0).sum()) == 0, \
        "match_sh is not an involution (mate's mate differs); pass a " \
        "matching from distributed_matching or repair symmetry first"
    cmap_sh = np.where(valid, cmap_sh, -1)

    cmap_flat = cmap_sh.reshape(-1)[valid.reshape(-1)]   # by fine gid
    nc = int(cvtxdist[-1])
    cvwgt = np.zeros(nc, dtype=np.int64)
    np.add.at(cvwgt, cmap_flat, _raster_flat(dg, dg.vwgt))
    src, dst, w = dgraph_arcs(dg)
    cs, cd = cmap_flat[src], cmap_flat[dst]
    ka = cs != cd                        # drop collapsed pairs
    cdg = _build_dgraph(cvtxdist, cs[ka], cd[ka], w[ka], cvwgt,
                        bucket=bucket)
    return cdg, cmap_sh


# ------------------------------------------------------------------ #
# lane-stacked halo exchange
# ------------------------------------------------------------------ #
def dgraph_bucket(dg: DGraph) -> Tuple[int, int, int, int]:
    """Jit bucket of a DGraph: ``(nparts, n_loc_max, dmax, n_ghost_max)``.

    Same-bucket graphs share compiled collectives AND may lane-stack into
    one launch (``distribute(bucket=True)`` pads shard shapes to powers
    of two precisely so sibling subgraphs of a recursion land together).
    """
    return (dg.nparts, dg.n_loc_max, dg.nbr_gst.shape[2],
            dg.ghost_gid.shape[1])


def _lane_pad(arrs: Sequence[np.ndarray]) -> Tuple[np.ndarray, int]:
    """Stack per-lane arrays, padding the lane axis to a power of two.

    Padding lanes duplicate lane 0 (real, discarded work — no garbage
    values reach reductions) so the jit cache sees O(log L) lane counts
    instead of one entry per frontier width.  Returns ``(stacked, L)``
    with L the real lane count.
    """
    L = len(arrs)
    pad = pow2(L, 1) - L
    return np.stack(list(arrs) + [arrs[0]] * pad), L


def _halo_gather(x, gids, vtxdist):
    """Lane-stacked per-shard halo body: ONE fused all_gather, all lanes.

    ``x`` (L, n_loc_max) this shard's values per lane; ``gids`` (L, G)
    per-lane ghost manifests; ``vtxdist`` (L, P+1) per-lane ranges.
    Returns (L, n_loc_max + G).  Shared by the standalone halo launch,
    the BFS sweep and the matching protocol (all run inside
    ``shard_map`` over the parts axis).
    """
    allx = jax.lax.all_gather(x, "parts")            # (P, L, n_loc_max)
    owner = jnp.clip(
        jax.vmap(functools.partial(jnp.searchsorted, side="right"))(
            vtxdist, gids) - 1, 0, allx.shape[0] - 1)
    local = jnp.clip(gids - jnp.take_along_axis(vtxdist, owner, axis=1),
                     0, allx.shape[2] - 1)
    lane = jnp.arange(x.shape[0])[:, None]
    vals = jnp.where(gids >= 0, allx[owner, lane, local], 0)
    return jnp.concatenate([x, vals], axis=1)


def _halo_stack_jit(nparts: int, n_loc_max: int, n_ghost_max: int,
                    lanes: int, dtype: str):
    mesh = make_parts_mesh(nparts)

    def body(x, gids, vtxdist):
        return _halo_gather(x[:, 0], gids[:, 0], vtxdist)[:, None]

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None, "parts", None), P(None, "parts", None),
                             P(None, None)),
                   out_specs=P(None, "parts", None))
    return jax.jit(fn)


def halo_exchange_stacked(dgs: Sequence[DGraph],
                          xs: Sequence[np.ndarray],
                          tags: Optional[Sequence] = None
                          ) -> List[np.ndarray]:
    """Halo-exchange many same-bucket graphs in ONE shard_map launch.

    ``xs[i]`` is graph i's (P, n_loc_max) sharded vector (one dtype for
    the whole stack); returns the (P, n_loc_max + n_ghost_max) extended
    vectors.  Lane i's result is bit-identical to a singleton exchange
    on ``dgs[i]`` — the gather indices are per-lane, the one fused
    ``all_gather`` only amortizes launch latency.  ``tags`` (optional,
    one per lane) records each lane's originating request in the launch
    metadata — the wave router's cross-request attribution.
    """
    key = dgraph_bucket(dgs[0])
    assert all(dgraph_bucket(d) == key for d in dgs), \
        "halo_exchange_stacked needs same-bucket graphs"
    nparts, nlm, _, G = key
    xs = [np.asarray(x) for x in xs]
    assert all(x.dtype == xs[0].dtype for x in xs)
    x_st, L = _lane_pad(xs)
    gid_st, _ = _lane_pad([d.ghost_gid.astype(np.int32) for d in dgs])
    vtx_st, _ = _lane_pad([d.vtxdist.astype(np.int32) for d in dgs])
    jkey = ("dhalo", nparts, nlm, G, x_st.shape[0], str(x_st.dtype))
    fn = _JIT_CACHE.get(jkey, lambda: _halo_stack_jit(
        nparts, nlm, G, x_st.shape[0], str(x_st.dtype)))
    out = obs.timed_dispatch(
        "halo", "dhalo", jkey,
        lambda: np.asarray(fn(jnp.asarray(x_st), jnp.asarray(gid_st),
                              jnp.asarray(vtx_st))),
        lanes=L, lanes_pad=x_st.shape[0], bucket=key)
    _note_launch("dhalo", nparts, L, x_st.shape[0], key[1:], 1,
                 x_st.shape[0] * nparts * nlm,
                 **({"tags": list(tags)} if tags is not None else {}))
    for _ in range(L):                   # per-work sync budget (see doc)
        _note_halo(nparts * nlm)
    return [out[i] for i in range(L)]


def halo_exchange_fn(dg: DGraph):
    """Returns halo(x (P, n_loc_max)) -> (P, n_loc_max + n_ghost_max).

    The one-lane convenience wrapper over ``halo_exchange_stacked``; the
    underlying jitted collective is cached per (bucket, lane count,
    dtype) and takes the ghost manifest / ranges as traced arguments, so
    it is reused by every same-bucket graph.
    """
    def halo(x):
        return halo_exchange_stacked([dg], [x])[0]
    return halo


def halo_reference(dg: DGraph, x: np.ndarray) -> np.ndarray:
    """Host oracle for tests."""
    Pn, G = dg.ghost_gid.shape
    out = np.zeros((Pn, dg.n_loc_max + G), dtype=x.dtype)
    flat = np.zeros(dg.vtxdist[-1], dtype=x.dtype)
    for p in range(Pn):
        lo, hi = dg.vtxdist[p], dg.vtxdist[p + 1]
        flat[lo:hi] = x[p, :hi - lo]
    for p in range(Pn):
        out[p, :dg.n_loc_max] = x[p]
        for k, gid in enumerate(dg.ghost_gid[p]):
            if gid >= 0:
                out[p, dg.n_loc_max + k] = flat[gid]
    return out


# ------------------------------------------------------------------ #
# distributed band-BFS (lane-stacked)
# ------------------------------------------------------------------ #
def _bfs_stack_jit(nparts: int, n_loc_max: int, dmax: int, n_ghost_max: int,
                   width: int, lanes: int):
    from repro.kernels.ops import ell_relax_step
    mesh = make_parts_mesh(nparts)

    def body(nbr, src, gids, vtxdist):
        nbr, src, gids = nbr[:, 0], src[:, 0], gids[:, 0]
        BIG = jnp.int32(2 ** 30)
        dist = jnp.where(src != 0, 0, BIG).astype(jnp.int32)

        def step(dist, _):
            ext = _halo_gather(dist, gids, vtxdist)
            return jnp.minimum(dist, ell_relax_step(nbr, ext, BIG)), None

        dist, _ = jax.lax.scan(step, dist, None, length=width)
        return dist[:, None]

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None, "parts", None, None),
                             P(None, "parts", None), P(None, "parts", None),
                             P(None, None)),
                   out_specs=P(None, "parts", None))
    return jax.jit(fn)


def distributed_bfs_stacked(dgs: Sequence[DGraph],
                            srcs: Sequence[np.ndarray],
                            width: int,
                            tags: Optional[Sequence] = None
                            ) -> List[np.ndarray]:
    """Band-distance sweeps of many same-bucket graphs in ONE launch.

    One fused ``all_gather`` per relaxation step serves every lane; the
    per-lane min-plus relaxations (``ell_relax_step`` with a lane axis)
    never mix lanes, so each lane equals its singleton sweep bit-for-bit.
    ``tags`` attributes lanes to requests (see ``halo_exchange_stacked``).
    """
    key = dgraph_bucket(dgs[0])
    assert all(dgraph_bucket(d) == key for d in dgs), \
        "distributed_bfs_stacked needs same-bucket graphs"
    nparts, nlm, dmax, G = key
    nbr_st, L = _lane_pad([d.nbr_gst for d in dgs])
    src_st, _ = _lane_pad([np.asarray(s, np.int32) for s in srcs])
    gid_st, _ = _lane_pad([d.ghost_gid.astype(np.int32) for d in dgs])
    vtx_st, _ = _lane_pad([d.vtxdist.astype(np.int32) for d in dgs])
    jkey = ("dbfs", nparts, nlm, dmax, G, width, nbr_st.shape[0])
    fn = _JIT_CACHE.get(jkey, lambda: _bfs_stack_jit(
        nparts, nlm, dmax, G, width, nbr_st.shape[0]))
    dist = obs.timed_dispatch(
        "bfs", "dbfs", jkey,
        lambda: np.asarray(fn(jnp.asarray(nbr_st), jnp.asarray(src_st),
                              jnp.asarray(gid_st), jnp.asarray(vtx_st))),
        lanes=L, lanes_pad=nbr_st.shape[0], bucket=key, width=width)
    _note_launch("dbfs", nparts, L, nbr_st.shape[0], key[1:], width,
                 width * nbr_st.shape[0] * nparts * nlm,
                 **({"tags": list(tags)} if tags is not None else {}))
    return [dist[i] for i in range(L)]


def distributed_bfs(dg: DGraph, src_mask: np.ndarray,
                    width: int) -> np.ndarray:
    """Band-graph distance sweep (§3.3) on the distributed structure: one
    halo exchange per relaxation — the paper's 'spreading distance
    information from all of the separator vertices, using our halo exchange
    routine'.  One-lane wrapper over ``distributed_bfs_stacked``."""
    return distributed_bfs_stacked([dg], [src_mask], width)[0]


# ------------------------------------------------------------------ #
# distributed heavy-edge matching (paper §3.2, lane-stacked)
# ------------------------------------------------------------------ #
def _matching_stack_jit(nparts: int, n_loc_max: int, dmax: int,
                        n_ghost_max: int, rounds: int, lanes: int,
                        cap: int = 0):
    """``cap`` > 0 compacts the per-round proposal gather: each shard
    scatters its (tgt, w, gid) proposals into (L, cap) compact buffers
    before the ``all_gather``, so the gathered width is the proposer
    *bound*, not the dense ``n_loc_max``.  The proposer gid travels as
    an explicit third buffer (the dense layout recovers it from the row
    position).  With a cap that bounds every round's true proposal
    count the winner tables — segment max/min over the same (score,
    gid, target) set — are bit-identical to the dense protocol's.
    ``cap`` = 0 keeps the dense positional layout."""
    mesh = make_parts_mesh(nparts)
    INT_MAX = jnp.iinfo(jnp.int32).max
    nseg = nparts * n_loc_max + 1       # winner-table slots (+1 dump)

    def body(nbr, ew, gids, vtxdist, nloc, seeds):
        nbr, ew, gids, nloc = nbr[:, 0], ew[:, 0], gids[:, 0], nloc[:, 0]
        L = nbr.shape[0]
        lane = jnp.arange(L)
        pidx = jax.lax.axis_index("parts")
        lo = vtxdist[:, pidx]                             # (L,)
        li = jnp.arange(n_loc_max, dtype=jnp.int32)
        valid_loc = li[None, :] < nloc[:, None]
        my_gid = jnp.where(valid_loc, lo[:, None] + li[None, :], -1)
        ext_gid = jnp.concatenate([my_gid, gids], axis=1)
        valid_e = nbr >= 0
        nb = jnp.where(valid_e, nbr, 0)                   # (L, nlm, d)
        ewf = ew.astype(jnp.float32)
        # proposer gid of every (shard, row) of the gathered proposal
        # buffers; every shard can compute it from vtxdist alone
        prop_gid_flat = (vtxdist[:, :nparts, None]
                         + li[None, None, :]).reshape(L, -1)

        def gather_flat(x):
            return jnp.moveaxis(jax.lax.all_gather(x, "parts"),
                                0, 1).reshape(x.shape[0], -1)

        def ext_at(ext, idx):
            # per-lane gather: ext (L, m), idx (L, n, d) -> (L, n, d)
            return jnp.take_along_axis(
                ext, idx.reshape(L, -1), axis=1).reshape(idx.shape)

        def owner_loc(t):
            # (L, K) global ids -> (owner shard, local slot) per lane
            tsafe = jnp.maximum(t, 0)
            ow = jnp.clip(
                jax.vmap(functools.partial(jnp.searchsorted, side="right"))(
                    vtxdist, tsafe) - 1, 0, nparts - 1)
            lc = jnp.clip(tsafe - jnp.take_along_axis(vtxdist, ow, axis=1),
                          0, n_loc_max - 1)
            return ow, lc

        def round_fn(match, r):
            unmatched = (match < 0) & valid_loc
            ext_unm = _halo_gather(unmatched.astype(jnp.int32), gids,
                                   vtxdist) != 0
            # hash coin: any shard can evaluate any vertex's side locally
            is_prop_ext = (hash_mix(ext_gid, r, seeds[:, None]) & 1) == 1
            # --- propose: heaviest unmatched acceptor neighbor
            tgt_slots = ext_at(ext_gid, nb)               # (L, nlm, d)
            cand = (valid_e & ext_at(ext_unm, nb) & ~ext_at(is_prop_ext, nb)
                    & (tgt_slots >= 0))
            tie = hash_unit(my_gid[:, :, None], tgt_slots, r + 17)
            score = jnp.where(cand, ewf + tie, -jnp.inf)
            slot = jnp.argmax(score, axis=2)[:, :, None]
            has = (jnp.any(cand, axis=2) & unmatched
                   & is_prop_ext[:, :n_loc_max])
            prop_tgt = jnp.where(
                has, jnp.take_along_axis(tgt_slots, slot, 2)[..., 0], -1)
            prop_w = jnp.where(
                has, jnp.take_along_axis(ewf, slot, 2)[..., 0], 0.0)

            # --- grant: ONE gather of the proposals; every shard then
            # derives the same per-acceptor winner table locally (pure
            # function of the gathered buffers), so no grant buffer is
            # ever gathered back — the notify leg costs zero words
            if cap:
                # compact the ≤ cap live proposals to the row front and
                # gather (tgt, w, gid) at width cap instead of n_loc_max.
                # pos ≥ cap (a non-proposing row, or overflow past the
                # bound — impossible by construction) drops.
                pos = jnp.where(
                    has, jnp.cumsum(has.astype(jnp.int32), axis=1) - 1,
                    cap)
                lane2 = jnp.broadcast_to(lane[:, None], pos.shape)
                ctgt = jnp.full((L, cap), -1, jnp.int32) \
                    .at[lane2, pos].set(prop_tgt, mode="drop")
                cw = jnp.zeros((L, cap), jnp.float32) \
                    .at[lane2, pos].set(prop_w, mode="drop")
                cgid = jnp.full((L, cap), -1, jnp.int32) \
                    .at[lane2, pos].set(my_gid, mode="drop")
                allt = gather_flat(ctgt)                  # (L, P·cap)
                allw = gather_flat(cw)
                allg = gather_flat(cgid)
            else:
                allt = gather_flat(prop_tgt)              # (L, P·nlm)
                allw = gather_flat(prop_w)
                allg = prop_gid_flat
            okp = allt >= 0
            ow, lc = owner_loc(allt)
            seg = jnp.where(okp, ow * n_loc_max + lc, nseg - 1)
            seg_l = (lane[:, None] * nseg + seg).reshape(-1)
            gsc = allw + hash_unit(allg, allt, r + 31)
            gsc = jnp.where(okp, gsc, -jnp.inf).reshape(-1)
            best = jax.ops.segment_max(gsc, seg_l, num_segments=L * nseg)
            is_best = okp.reshape(-1) & (gsc >= best[seg_l])
            winner = jax.ops.segment_min(
                jnp.where(is_best, allg.reshape(-1), INT_MAX),
                seg_l, num_segments=L * nseg).reshape(L, nseg)

            # acceptors: my slots of the winner table
            win_mine = jax.lax.dynamic_slice_in_dim(
                winner, pidx * n_loc_max, n_loc_max, axis=1)
            can_accept = unmatched & ~is_prop_ext[:, :n_loc_max]
            grant = jnp.where(can_accept & (win_mine < INT_MAX),
                              win_mine, -1)
            # proposers: the winner of the slot they proposed to (a
            # proposal existing implies the target could accept this
            # round — ``cand`` checked the exchanged unmatched mask and
            # the acceptor-side coin, the same values the owner sees)
            ow_p, lc_p = owner_loc(prop_tgt)
            win_t = jnp.take_along_axis(winner, ow_p * n_loc_max + lc_p,
                                        axis=1)
            got = (prop_tgt >= 0) & (win_t == my_gid)
            match = jnp.where(got, prop_tgt, match)
            match = jnp.where(grant >= 0, grant, match)
            return match, None

        match0 = jnp.full((L, n_loc_max), -1, dtype=jnp.int32)
        match, _ = jax.lax.scan(round_fn, match0,
                                jnp.arange(rounds, dtype=jnp.int32))
        return match[:, None]

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None, "parts", None, None),
                             P(None, "parts", None, None),
                             P(None, "parts", None), P(None, None),
                             P(None, "parts"), P(None)),
                   out_specs=P(None, "parts", None))
    return jax.jit(fn)


def _match_proposal_cap(dgs: Sequence[DGraph], nlm: int) -> int:
    """Lossless per-shard proposal bound of a matching lane stack.

    A vertex can propose in *any* round only if it is valid and has at
    least one valid ELL edge (``cand`` requires one), so the max over
    shards and lanes of that count bounds every round's true proposal
    width — compaction at this cap never drops a proposal, keeping the
    compact protocol bit-identical to the dense one regardless of which
    lanes happen to share the launch.  Quantized up to sub-pow2 steps
    (``max(8, nlm // 8)``) so the jit key space stays coarse.
    """
    k = 1
    for d in dgs:
        can = (shard_gids(d) >= 0) & (d.nbr_gst >= 0).any(axis=2)
        k = max(k, int(can.sum(axis=1).max()))
    q = max(8, nlm // 8)
    return min(nlm, -(-k // q) * q)


def distributed_matching_stacked(dgs: Sequence[DGraph],
                                 seeds: Sequence[int],
                                 rounds: int = 8,
                                 tags: Optional[Sequence] = None
                                 ) -> List[np.ndarray]:
    """Match many same-bucket graphs in ONE shard_map launch.

    Returns, per graph, the sharded (P, n_loc_max) mate global ids
    (``flat=False`` contract: -1→self masking and owner-routed symmetry
    repair applied).  Coins, tiebreaks and the per-lane grant reductions
    are functions of each lane's own (gids, seed) alone, so lane i's
    matching is bit-identical to ``distributed_matching(dgs[i], ...)``.

    When compaction is on (``set_match_compact`` / RouterConfig) and the
    proposer bound is small enough to pay (3·cap < 2·n_loc_max, i.e. the
    compact round — halo + 3 cap-wide buffers — beats the dense round's
    3 n_loc_max-wide buffers), the proposal gather runs at the lossless
    cap of ``_match_proposal_cap``; the launch record then carries
    ``cap`` and the counterfactual ``words_dense``.  ``tags`` attributes
    lanes to requests (see ``halo_exchange_stacked``).
    """
    key = dgraph_bucket(dgs[0])
    assert all(dgraph_bucket(d) == key for d in dgs), \
        "distributed_matching_stacked needs same-bucket graphs"
    nparts, nlm, dmax, G = key
    nbr_st, L = _lane_pad([d.nbr_gst for d in dgs])
    ew_st, _ = _lane_pad([d.ewgt_gst.astype(np.int32) for d in dgs])
    gid_st, _ = _lane_pad([d.ghost_gid.astype(np.int32) for d in dgs])
    vtx_st, _ = _lane_pad([d.vtxdist.astype(np.int32) for d in dgs])
    nloc_st, _ = _lane_pad([d.n_loc.astype(np.int32) for d in dgs])
    seed_st, _ = _lane_pad([np.int32(s & 0x7FFFFFFF) for s in seeds])
    cap = 0
    if _MATCH_COMPACT:
        c = _match_proposal_cap(dgs, nlm)
        if 3 * c < 2 * nlm:
            cap = c
    jkey = ("dmatch", nparts, nlm, dmax, G, rounds, nbr_st.shape[0], cap)
    fn = _JIT_CACHE.get(jkey, lambda: _matching_stack_jit(
        nparts, nlm, dmax, G, rounds, nbr_st.shape[0], cap))
    m = obs.timed_dispatch(
        "match", "dmatch", jkey,
        lambda: np.asarray(fn(jnp.asarray(nbr_st), jnp.asarray(ew_st),
                              jnp.asarray(gid_st), jnp.asarray(vtx_st),
                              jnp.asarray(nloc_st), jnp.asarray(seed_st))),
        lanes=L, lanes_pad=nbr_st.shape[0], bucket=key, rounds=rounds,
        cap=cap)
    # per dense round: unmatched-mask halo + proposal targets + proposal
    # weights (the grant gather-back of the pre-frontier protocol is
    # gone); a compact round gathers the halo at n_loc_max plus three
    # cap-wide buffers (targets, weights, proposer gids)
    words_dense = rounds * 3 * nbr_st.shape[0] * nparts * nlm
    words = (rounds * nbr_st.shape[0] * nparts * (nlm + 3 * cap)
             if cap else words_dense)
    _note_launch("dmatch", nparts, L, nbr_st.shape[0], key[1:], rounds,
                 words, cap=cap, words_dense=words_dense,
                 **({"tags": list(tags)} if tags is not None else {}))
    out = []
    for i, dg in enumerate(dgs):
        gid = shard_gids(dg)
        valid = gid >= 0
        m_sh = m[i].astype(np.int64)
        m_sh = np.where(valid & (m_sh >= 0) & (m_sh < dg.n_global),
                        m_sh, gid)
        # defensive symmetry repair (protocol is symmetric by
        # construction): each vertex checks its mate's mate via an
        # owner-routed pull
        mate_of_mate = pull_by_gid(dg, m_sh, m_sh, fill=-1)
        out.append(np.where(valid & (mate_of_mate == gid), m_sh, gid))
    return out


def distributed_matching(dg: DGraph, seed: int, rounds: int = 8,
                         flat: bool = True) -> np.ndarray:
    """Synchronous probabilistic heavy-edge matching across shards.

    The paper's request/grant protocol (§3.2) with the collectives of this
    file: each round, unmatched proposers pick their heaviest unmatched
    acceptor neighbor (ghosts included, via halo exchange of the unmatched
    mask); proposals are gathered once, and every shard derives the same
    per-acceptor winner table from the gathered buffers — acceptors grant
    from their slots, proposers read their target's slot, and both ends
    commit with **no grant gather-back** (the notify leg of the
    pre-frontier protocol cost a dense (P, n_loc_max) all_gather per
    round).  Coin flips and tiebreaks are hashes of (gid, round, seed),
    so every shard evaluates any vertex's state without extra messages —
    and the result is independent of the shard layout.

    With ``flat`` (legacy contract) the matching is gathered into a flat
    global (n,) array with match[v] = v for singletons — same contract as
    ``matching.heavy_edge_matching``.  With ``flat=False`` it stays
    sharded: (P, n_loc_max) mate global ids (-1 on padding), the form
    ``dgraph_coarsen`` consumes — no centralization at any size.
    One-lane wrapper over ``distributed_matching_stacked``.
    """
    m_sh = distributed_matching_stacked([dg], [seed], rounds)[0]
    if flat:
        return unshard_vector(dg, m_sh)
    return m_sh
