"""Distributed graph structure + halo exchange (paper §2.1), shard_map form.

The paper's structure maps onto JAX as stacked per-shard arrays with a
``parts`` mesh axis:

  * ``vtxdist``      — the paper's ``procvrttab``: global vertex ranges per
    shard (duplicated everywhere, owner lookup by range search);
  * ``nbr_gst``      — the paper's ``edgegsttab``: ELL adjacency in *compact
    local indexing* where indices < n_loc_max are local and indices ≥
    n_loc_max address the ghost slots, numbered by (owner, global id) — the
    cache-friendly agglomeration order of §2.1;
  * ``ewgt_gst``     — matching ELL edge weights (heavy-edge matching on
    coarse levels needs them);
  * ``ghost_gid``    — global ids of ghost slots per shard (the receive
    manifest of the halo exchange).

``halo_exchange`` diffuses local vertex values to the ghost copies on
neighboring shards: the reference implementation is an ``all_gather`` over
the parts axis + gather (dense collective — the TPU-idiomatic replacement
for MPI point-to-point; DESIGN.md §2 discusses the trade).

All device functions take the per-graph arrays (``vtxdist``, ``ghost_gid``,
…) as *traced arguments* and are cached per padded shape, so the jit cache
is shared across every subgraph of a nested-dissection recursion that lands
in the same power-of-two bucket (same bucketing the centralized data plane
uses, ``repro.util.pow2``).

Scalability note (matching the paper): no shard stores ghost *adjacency* —
only ghost values — so per-shard memory is O(local arcs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.graph import Graph
from repro.core.matching import hash_mix, hash_unit
from repro.util import pow2


@dataclasses.dataclass
class DGraph:
    """Host-resident description of a P-way distributed graph."""
    vtxdist: np.ndarray        # (P+1,) global ranges
    nbr_gst: np.ndarray        # (P, n_loc_max, dmax) compact local/ghost ids
    ewgt_gst: np.ndarray       # (P, n_loc_max, dmax) edge weights (0 pad)
    ghost_gid: np.ndarray      # (P, n_ghost_max) global ids of ghosts (-1 pad)
    n_loc: np.ndarray          # (P,) real local counts
    n_ghost: np.ndarray        # (P,) real ghost counts
    vwgt: np.ndarray           # (P, n_loc_max)

    @property
    def nparts(self) -> int:
        return len(self.vtxdist) - 1

    @property
    def n_loc_max(self) -> int:
        return self.nbr_gst.shape[1]

    @property
    def n_global(self) -> int:
        return int(self.vtxdist[-1])


def distribute(g: Graph, nparts: int,
               vtxdist: Optional[np.ndarray] = None,
               bucket: bool = True) -> DGraph:
    """Distribute a host graph (the paper's user-defined ranges).

    ``vtxdist`` optionally supplies custom ownership ranges (the coarse
    graphs of distributed coarsening keep coarse vertices on the owner of
    their representative); the default is a block distribution.  With
    ``bucket`` the padded shard shapes are rounded up to powers of two so
    jitted collectives are reused across same-bucket subgraphs.
    """
    n = g.n
    if vtxdist is None:
        vtxdist = np.linspace(0, n, nparts + 1).astype(np.int64)
    else:
        vtxdist = np.asarray(vtxdist, dtype=np.int64)
        assert len(vtxdist) == nparts + 1 and vtxdist[-1] == n
    n_loc = np.diff(vtxdist)
    n_loc_max = int(n_loc.max()) if nparts else 1
    deg = g.degrees()
    dmax = int(deg.max()) if n and len(g.adjncy) else 1
    if bucket:
        n_loc_max = pow2(max(n_loc_max, 1), 8)
        dmax = pow2(max(dmax, 1), 4)
    n_loc_max = max(n_loc_max, 1)

    owner = np.searchsorted(vtxdist, np.arange(n), side="right") - 1
    src = np.repeat(np.arange(n, dtype=np.int64), deg)
    dst = g.adjncy.astype(np.int64)
    p_src = owner[src]
    col = np.arange(len(dst)) - g.xadj[src]
    li_src = src - vtxdist[p_src]
    remote = p_src != owner[dst]

    # ghost manifests: unique (shard, gid) pairs among remote arc heads.
    # Ascending gid is ascending (owner, gid) because vtxdist is sorted —
    # the §2.1 cache-friendly agglomeration order.
    keys = p_src[remote] * np.int64(max(n, 1)) + dst[remote]
    uk = np.unique(keys)
    gp = uk // max(n, 1)
    ggid = uk % max(n, 1)
    counts = np.bincount(gp, minlength=nparts)
    offs = np.concatenate([[0], np.cumsum(counts)])
    gslot = np.arange(len(uk)) - offs[gp]
    n_ghost = counts.astype(np.int64)
    n_ghost_max = max(int(n_ghost.max()) if nparts else 0, 1)
    if bucket:
        n_ghost_max = pow2(n_ghost_max, 4)
    ghost_gid = -np.ones((nparts, n_ghost_max), dtype=np.int64)
    ghost_gid[gp, gslot] = ggid

    nbr_gst = -np.ones((nparts, n_loc_max, dmax), dtype=np.int32)
    ewgt_gst = np.zeros((nparts, n_loc_max, dmax), dtype=np.int32)
    cidx = dst - vtxdist[owner[dst]]
    if len(uk):
        cidx[remote] = n_loc_max + gslot[np.searchsorted(uk, keys)]
    nbr_gst[p_src, li_src, col] = cidx
    ewgt_gst[p_src, li_src, col] = g.adjwgt

    vwgt = np.zeros((nparts, n_loc_max), dtype=np.int64)
    vwgt[owner, np.arange(n) - vtxdist[owner]] = g.vwgt
    return DGraph(vtxdist, nbr_gst, ewgt_gst, ghost_gid, n_loc, n_ghost,
                  vwgt)


@functools.lru_cache(maxsize=None)
def make_parts_mesh(nparts: int) -> Mesh:
    devs = jax.devices()[:nparts]
    assert len(devs) == nparts, (
        f"need {nparts} devices, have {len(jax.devices())} "
        "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return Mesh(np.array(devs), ("parts",))


# ------------------------------------------------------------------ #
# sharded <-> flat host vectors
# ------------------------------------------------------------------ #
def shard_vector(dg: DGraph, x: np.ndarray, fill=0) -> np.ndarray:
    """Flat global (n,) -> sharded (P, n_loc_max) (padding = fill)."""
    out = np.full((dg.nparts, dg.n_loc_max), fill, dtype=np.asarray(x).dtype)
    for p in range(dg.nparts):
        lo, hi = dg.vtxdist[p], dg.vtxdist[p + 1]
        out[p, :hi - lo] = x[lo:hi]
    return out


def unshard_vector(dg: DGraph, xs: np.ndarray) -> np.ndarray:
    """Sharded (P, n_loc_max) -> flat global (n,)."""
    return np.concatenate([xs[p, :dg.vtxdist[p + 1] - dg.vtxdist[p]]
                           for p in range(dg.nparts)])


def to_host(dg: DGraph) -> Graph:
    """Gather the distributed structure back into one centralized Graph.

    The §3.1 centralization step: below the sequential threshold the
    subgraph is gathered onto one process and ordered there.
    """
    Pn, nlm, d = dg.nbr_gst.shape
    p, li, slot = np.nonzero(dg.nbr_gst >= 0)
    c = dg.nbr_gst[p, li, slot]
    src = dg.vtxdist[p] + li
    loc = c < nlm
    dst = np.empty(len(c), dtype=np.int64)
    dst[loc] = dg.vtxdist[p[loc]] + c[loc]
    dst[~loc] = dg.ghost_gid[p[~loc], c[~loc] - nlm]
    w = dg.ewgt_gst[p, li, slot]
    keep = src < dst                      # one direction; from_edges mirrors
    vwgt = unshard_vector(dg, dg.vwgt)
    return Graph.from_edges(dg.n_global,
                            np.stack([src[keep], dst[keep]], 1),
                            vwgt=vwgt, ewgt=w[keep].astype(np.int64))


# ------------------------------------------------------------------ #
# halo exchange
# ------------------------------------------------------------------ #
def _halo_local(x, gids, vtxdist):
    """Per-shard halo body: all_gather owned slabs + gather by global id.

    ``x`` (n_loc_max,) this shard's values; returns (n_loc_max + G,).
    Shared by the standalone halo fn, the BFS sweep and the matching
    protocol (all run inside ``shard_map`` over the parts axis).
    """
    allx = jax.lax.all_gather(x, "parts")               # (P, n_loc_max)
    owner = jnp.clip(jnp.searchsorted(vtxdist, gids, side="right") - 1,
                     0, allx.shape[0] - 1)
    local = jnp.clip(gids - vtxdist[owner], 0, allx.shape[1] - 1)
    vals = jnp.where(gids >= 0, allx[owner, local], 0)
    return jnp.concatenate([x, vals])


@functools.lru_cache(maxsize=None)
def _halo_jit(nparts: int, n_loc_max: int, n_ghost_max: int, dtype: str):
    mesh = make_parts_mesh(nparts)

    def body(x, gids, vtxdist):
        return _halo_local(x[0], gids[0], vtxdist)[None]

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("parts", None), P("parts", None), P(None)),
                   out_specs=P("parts", None))
    return jax.jit(fn)


def halo_exchange_fn(dg: DGraph):
    """Returns halo(x (P, n_loc_max)) -> (P, n_loc_max + n_ghost_max).

    The underlying jitted collective is cached per (nparts, padded shapes,
    dtype) and takes the ghost manifest / ranges as traced arguments, so it
    is reused by every same-bucket graph.
    """
    gids = jnp.asarray(dg.ghost_gid, jnp.int32)
    vtxdist = jnp.asarray(dg.vtxdist, jnp.int32)

    def halo(x):
        x = jnp.asarray(x)
        fn = _halo_jit(dg.nparts, dg.n_loc_max, dg.ghost_gid.shape[1],
                       str(x.dtype))
        return fn(x, gids, vtxdist)
    return halo


def halo_reference(dg: DGraph, x: np.ndarray) -> np.ndarray:
    """Host oracle for tests."""
    Pn, G = dg.ghost_gid.shape
    out = np.zeros((Pn, dg.n_loc_max + G), dtype=x.dtype)
    flat = np.zeros(dg.vtxdist[-1], dtype=x.dtype)
    for p in range(Pn):
        lo, hi = dg.vtxdist[p], dg.vtxdist[p + 1]
        flat[lo:hi] = x[p, :hi - lo]
    for p in range(Pn):
        out[p, :dg.n_loc_max] = x[p]
        for k, gid in enumerate(dg.ghost_gid[p]):
            if gid >= 0:
                out[p, dg.n_loc_max + k] = flat[gid]
    return out


# ------------------------------------------------------------------ #
# distributed band-BFS
# ------------------------------------------------------------------ #
@functools.lru_cache(maxsize=None)
def _bfs_jit(nparts: int, n_loc_max: int, dmax: int, n_ghost_max: int,
             width: int):
    from repro.kernels.ops import ell_relax_step
    mesh = make_parts_mesh(nparts)

    def body(nbr, src, gids, vtxdist):
        nbr, src, gids = nbr[0], src[0], gids[0]
        BIG = jnp.int32(2 ** 30)
        dist = jnp.where(src != 0, 0, BIG).astype(jnp.int32)

        def step(dist, _):
            ext = _halo_local(dist, gids, vtxdist)
            return jnp.minimum(dist, ell_relax_step(nbr, ext, BIG)), None

        dist, _ = jax.lax.scan(step, dist, None, length=width)
        return dist[None]

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("parts", None, None), P("parts", None),
                             P("parts", None), P(None)),
                   out_specs=P("parts", None))
    return jax.jit(fn)


def distributed_bfs(dg: DGraph, src_mask: np.ndarray,
                    width: int) -> np.ndarray:
    """Band-graph distance sweep (§3.3) on the distributed structure: one
    halo exchange per relaxation — the paper's 'spreading distance
    information from all of the separator vertices, using our halo exchange
    routine'."""
    fn = _bfs_jit(dg.nparts, dg.n_loc_max, dg.nbr_gst.shape[2],
                  dg.ghost_gid.shape[1], width)
    dist = fn(jnp.asarray(dg.nbr_gst), jnp.asarray(src_mask, jnp.int32),
              jnp.asarray(dg.ghost_gid, jnp.int32),
              jnp.asarray(dg.vtxdist, jnp.int32))
    return np.asarray(dist)


# ------------------------------------------------------------------ #
# distributed heavy-edge matching (paper §3.2)
# ------------------------------------------------------------------ #
@functools.lru_cache(maxsize=None)
def _matching_jit(nparts: int, n_loc_max: int, dmax: int, n_ghost_max: int,
                  rounds: int):
    mesh = make_parts_mesh(nparts)
    INT_MAX = jnp.iinfo(jnp.int32).max

    def body(nbr, ew, gids, vtxdist, nloc, seed):
        nbr, ew, gids, nloc = nbr[0], ew[0], gids[0], nloc[0]
        pidx = jax.lax.axis_index("parts")
        lo = vtxdist[pidx]
        li = jnp.arange(n_loc_max, dtype=jnp.int32)
        valid_loc = li < nloc
        my_gid = jnp.where(valid_loc, lo + li, -1)
        ext_gid = jnp.concatenate([my_gid, gids])       # (n_loc_max + G,)
        valid_e = nbr >= 0
        nb = jnp.where(valid_e, nbr, 0)
        ewf = ew.astype(jnp.float32)
        # proposer gid of every (shard, row) of the gathered proposal
        # buffers; every shard can compute it from vtxdist alone
        prop_gid_flat = (vtxdist[:nparts, None]
                         + jnp.arange(n_loc_max, dtype=jnp.int32)[None, :]
                         ).reshape(-1)

        def round_fn(match, r):
            unmatched = (match < 0) & valid_loc
            ext_unm = _halo_local(unmatched.astype(jnp.int32), gids,
                                  vtxdist) != 0
            # hash coin: any shard can evaluate any vertex's side locally
            is_prop_ext = (hash_mix(ext_gid, r, seed) & 1) == 1
            # --- propose: heaviest unmatched acceptor neighbor
            tgt_slots = ext_gid[nb]                     # (n_loc_max, d)
            cand = (valid_e & ext_unm[nb] & ~is_prop_ext[nb]
                    & (tgt_slots >= 0))
            tie = hash_unit(my_gid[:, None], tgt_slots, r + 17)
            score = jnp.where(cand, ewf + tie, -jnp.inf)
            slot = jnp.argmax(score, axis=1)
            has = jnp.any(cand, axis=1) & unmatched & is_prop_ext[:n_loc_max]
            prop_tgt = jnp.where(has, tgt_slots[li, slot], -1)
            prop_w = jnp.where(has, ewf[li, slot], 0.0)

            # --- grant: every shard grants for its own local acceptors
            allt = jax.lax.all_gather(prop_tgt, "parts").reshape(-1)
            allw = jax.lax.all_gather(prop_w, "parts").reshape(-1)
            mine = (allt >= lo) & (allt < lo + nloc)
            seg = jnp.where(mine, allt - lo, n_loc_max)
            gsc = allw + hash_unit(prop_gid_flat, allt, r + 31)
            gsc = jnp.where(mine, gsc, -jnp.inf)
            best = jax.ops.segment_max(gsc, seg,
                                       num_segments=n_loc_max + 1)
            is_best = mine & (gsc >= best[seg])
            winner = jax.ops.segment_min(
                jnp.where(is_best, prop_gid_flat, INT_MAX), seg,
                num_segments=n_loc_max + 1)[:n_loc_max]
            can_accept = unmatched & ~is_prop_ext[:n_loc_max]
            grant = jnp.where(can_accept & (winner < INT_MAX), winner, -1)

            # --- notify: proposers read their target's grant
            allg = jax.lax.all_gather(grant, "parts")   # (P, n_loc_max)
            tsafe = jnp.maximum(prop_tgt, 0)
            owner_t = jnp.clip(
                jnp.searchsorted(vtxdist, tsafe, side="right") - 1,
                0, nparts - 1)
            loc_t = jnp.clip(tsafe - vtxdist[owner_t], 0, n_loc_max - 1)
            got = (prop_tgt >= 0) & (allg[owner_t, loc_t] == my_gid)
            match = jnp.where(got, prop_tgt, match)
            match = jnp.where(grant >= 0, grant, match)
            return match, None

        match0 = jnp.full((n_loc_max,), -1, dtype=jnp.int32)
        match, _ = jax.lax.scan(round_fn, match0,
                                jnp.arange(rounds, dtype=jnp.int32))
        return match[None]

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("parts", None, None), P("parts", None, None),
                             P("parts", None), P(None), P("parts"), P(None)),
                   out_specs=P("parts", None))
    return jax.jit(fn)


def distributed_matching(dg: DGraph, seed: int, rounds: int = 8
                         ) -> np.ndarray:
    """Synchronous probabilistic heavy-edge matching across shards.

    The paper's request/grant protocol (§3.2) with the collectives of this
    file: each round, unmatched proposers pick their heaviest unmatched
    acceptor neighbor (ghosts included, via halo exchange of the unmatched
    mask); proposals are gathered; every shard grants the best proposal for
    each of its local acceptors; grants are gathered back and both ends
    commit.  Coin flips and tiebreaks are hashes of (gid, round, seed), so
    every shard evaluates any vertex's state without extra messages.

    Returns the matching as a flat global (n,) array with match[v] = v for
    singletons — same contract as ``matching.heavy_edge_matching``.
    """
    fn = _matching_jit(dg.nparts, dg.n_loc_max, dg.nbr_gst.shape[2],
                       dg.ghost_gid.shape[1], rounds)
    m = fn(jnp.asarray(dg.nbr_gst), jnp.asarray(dg.ewgt_gst, jnp.int32),
           jnp.asarray(dg.ghost_gid, jnp.int32),
           jnp.asarray(dg.vtxdist, jnp.int32),
           jnp.asarray(dg.n_loc, jnp.int32),
           jnp.asarray([seed & 0x7FFFFFFF], jnp.int32))
    mg = unshard_vector(dg, np.asarray(m)).astype(np.int64)
    v = np.arange(dg.n_global, dtype=np.int64)
    mg = np.where((mg < 0) | (mg >= dg.n_global), v, mg)
    # defensive symmetry repair (protocol is symmetric by construction)
    bad = mg[mg] != v
    mg[bad] = v[bad]
    return mg
