"""Parallel nested dissection driver (paper §3.1) + separator pipeline (§3.2–3.3).

Control plane: host recursion with fold bookkeeping (process counts halve at
every dissection level, as in the paper's fold of induced subgraphs onto
⌈p/2⌉ / ⌊p/2⌋ processes).  Data plane: JAX matching / BFS / FM kernels.

``nproc`` only drives the *quality-relevant* parallel mechanisms — fold-dup
instance counts and the number of multi-sequential FM/initial-partition
instances — exactly the knobs through which process count affects ordering
quality in the paper (its Tables 2–3 vary nothing else).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.band import extract_band, project_band
from repro.core.coarsen import coarsen_multilevel
from repro.core.fm import refine_parts, separator_is_valid
from repro.core.graph import Graph
from repro.core.initsep import initial_separator
from repro.core.ordering import Ordering
from repro.sparse.mindeg import min_degree


@dataclasses.dataclass
class NDConfig:
    leaf_size: int = 96             # switch to minimum degree below this
    coarse_target: int = 120        # coarsest-graph size
    fold_threshold: int = 100       # vertices/process before fold-dup (paper)
    band_width: int = 3             # paper's principled default
    eps_frac: float = 0.12          # balance tolerance
    k_fm_cap: int = 16              # max multi-sequential FM instances
    k_init: int = 8                 # initial-partition tries (per instance)
    fm_passes: int = 3
    use_band: bool = True           # ablation switch (§3.3)
    fold_dup: bool = True           # ablation switch (§3.2)
    seq_threshold: int = 0          # below this n, pretend nproc=1
    # --- ParMETIS-like baseline knobs (paper §3.3's description of [20]) ---
    refine_strict: bool = False     # only strictly-improving moves
    freeze_interface: bool = False  # vertices with remote neighbors frozen


def _project(part_coarse: np.ndarray, cmap: np.ndarray) -> np.ndarray:
    """Separator projection: coarse separator vertex -> both fine children."""
    return part_coarse[cmap].astype(np.int8)


def compute_separator(g: Graph, seed: int, nproc: int, cfg: NDConfig
                      ) -> Optional[np.ndarray]:
    """Multilevel + band-FM vertex separator of g.  Returns part or None."""
    if g.n < 4:
        return None
    state = coarsen_multilevel(
        g, seed, nproc=nproc if cfg.fold_dup else 1,
        coarse_target=cfg.coarse_target, fold_threshold=cfg.fold_threshold,
        max_instances=cfg.k_fm_cap)
    coarsest = state.coarsest
    n_inst = state.levels[-1].n_instances
    k_init = min(cfg.k_init * n_inst, 32)
    part, _ = initial_separator(coarsest, seed, k_tries=k_init,
                                eps_frac=cfg.eps_frac)
    if cfg.refine_strict:
        k_fm = 1
    else:
        k_fm = int(np.clip(nproc, 1, cfg.k_fm_cap)) if cfg.fold_dup else 1
        k_fm = max(k_fm, 2)
    # uncoarsen: project, band-extract, multi-sequential FM
    for lvl in range(len(state.levels) - 1, 0, -1):
        cmap = state.levels[lvl].cmap
        fine = state.levels[lvl - 1].graph
        part = _project(part, cmap)
        part = _refine_level(fine, part, seed * 101 + lvl, k_fm, nproc, cfg)
    return part


def _interface_frozen(g: Graph, nproc: int) -> np.ndarray:
    """Vertices with neighbors on another process of a block distribution.

    Models the parallel-FM communication constraint the paper attributes to
    ParMETIS [20]: a move whose gain update would need remote coordination
    is not attempted.
    """
    blk = (np.arange(g.n, dtype=np.int64) * nproc) // max(g.n, 1)
    src = np.repeat(np.arange(g.n), g.degrees())
    remote = blk[src] != blk[g.adjncy]
    frozen = np.zeros(g.n, bool)
    frozen[np.unique(src[remote])] = True
    return frozen


def _refine_level(fine: Graph, part: np.ndarray, seed: int, k_fm: int,
                  nproc: int, cfg: NDConfig) -> np.ndarray:
    pos_only = cfg.refine_strict
    n_pert = 0 if pos_only else 8
    if cfg.use_band:
        band, bpart, locked, old_ids = extract_band(fine, part,
                                                    width=cfg.band_width)
        nbr, _ = band.to_ell()
        bpart, _, _ = refine_parts(nbr, band.vwgt, bpart, locked, seed,
                                   k_inst=k_fm, eps_frac=cfg.eps_frac,
                                   passes=cfg.fm_passes, n_pert=n_pert,
                                   pos_only=pos_only)
        assert separator_is_valid(nbr, bpart)
        return project_band(part, bpart, old_ids)
    locked = np.zeros(fine.n, bool)
    if cfg.freeze_interface and nproc > 1:
        locked |= _interface_frozen(fine, nproc)
    nbr, _ = fine.to_ell()
    out, _, _ = refine_parts(nbr, fine.vwgt, part, locked, seed,
                             k_inst=k_fm, eps_frac=cfg.eps_frac,
                             passes=cfg.fm_passes, n_pert=n_pert,
                             pos_only=pos_only)
    assert separator_is_valid(nbr, out)
    return out


def _fallback_separator(g: Graph, seed: int) -> Optional[np.ndarray]:
    from repro.core.mapping import edge_bisect
    half = edge_bisect(g, seed=seed, k_tries=2, passes=2)
    part = half.astype(np.int8)
    src = np.repeat(np.arange(g.n), g.degrees())
    touch = (part[src] == 0) & (part[g.adjncy] == 1)
    part[np.unique(g.adjncy[touch])] = 2
    return part


def nested_dissection(g: Graph, seed: int = 0, nproc: int = 1,
                      cfg: Optional[NDConfig] = None) -> np.ndarray:
    """Full ordering.  Returns perm (perm[k] = vertex eliminated k-th)."""
    from repro.util import enable_compile_cache
    enable_compile_cache()
    cfg = cfg or NDConfig()
    ordering = Ordering(g.n)
    _nd_rec(g, np.arange(g.n, dtype=np.int64), seed, nproc, cfg,
            ordering, ordering.root, 0)
    perm = ordering.assemble()
    assert np.array_equal(np.sort(perm), np.arange(g.n)), "not a permutation"
    return perm


def _nd_rec(g: Graph, gids: np.ndarray, seed: int, nproc: int, cfg: NDConfig,
            ordering: Ordering, node, start: int) -> None:
    n = g.n
    if n <= cfg.leaf_size:
        perm = min_degree(g, tie_seed=seed)
        ordering.add_leaf(node, start, gids[perm])
        return
    comp = g.components()
    ncomp = int(comp.max()) + 1
    if ncomp > 1:                       # independent parts: no separator
        off = start
        for c in range(ncomp):
            sub, old = g.induced_subgraph(comp == c)
            child = ordering.add_internal(node, off, sub.n)
            _nd_rec(sub, gids[old], seed * 7 + c, nproc, cfg, ordering,
                    child, off)
            off += sub.n
        return
    eff_proc = 1 if n <= cfg.seq_threshold else nproc
    part = compute_separator(g, seed, eff_proc, cfg)
    if part is None or min((part == 0).sum(), (part == 1).sum()) == 0:
        if n > 4 * cfg.leaf_size:
            # separator heuristic failed on a big subgraph: fall back to a
            # balanced edge bisection (boundary -> separator) rather than
            # handing O(n) vertices to sequential minimum degree.
            part = _fallback_separator(g, seed)
        if part is None or min((part == 0).sum(), (part == 1).sum()) == 0:
            perm = min_degree(g, tie_seed=seed)     # could not split
            ordering.add_leaf(node, start, gids[perm])
            return
    g0, old0 = g.induced_subgraph(part == 0)
    g1, old1 = g.induced_subgraph(part == 1)
    gs, olds = g.induced_subgraph(part == 2)
    # paper §3.1: part 0 onto ⌈p/2⌉ processes, part 1 onto ⌊p/2⌋
    p0, p1 = (nproc + 1) // 2, max(nproc // 2, 1)
    c0 = ordering.add_internal(node, start, g0.n)
    _nd_rec(g0, gids[old0], seed * 2 + 1, p0, cfg, ordering, c0, start)
    c1 = ordering.add_internal(node, start + g0.n, g1.n)
    _nd_rec(g1, gids[old1], seed * 2 + 2, p1, cfg, ordering, c1,
            start + g0.n)
    # separator ordered last (highest indices); minimum degree internally
    # (paper couples ND with MD [10]); very large separators (circuit-like
    # graphs) would stall the host MD — profile-order them instead.
    if gs.n <= 2:
        sperm = np.arange(gs.n, dtype=np.int64)
    elif gs.n <= 600:
        sperm = min_degree(gs, tie_seed=seed)
    else:
        from repro.core.baselines import rcm
        sperm = rcm(gs)
    ordering.add_leaf(node, start + g0.n + g1.n, gids[olds[sperm]], "sep")
