"""Parallel nested dissection driver (paper §3.1) + separator pipeline (§3.2–3.3).

Control plane: host recursion with fold bookkeeping (process counts halve at
every dissection level, as in the paper's fold of induced subgraphs onto
⌈p/2⌉ / ⌊p/2⌋ processes).  Data plane: JAX matching / BFS / FM kernels.

``nproc`` only drives the *quality-relevant* parallel mechanisms — fold-dup
instance counts and the number of multi-sequential FM/initial-partition
instances — exactly the knobs through which process count affects ordering
quality in the paper (its Tables 2–3 vary nothing else).

Since the batched-service PR the separator pipeline is *stage-separated*:
``separator_task`` is a generator that runs the host control plane (coarsen
→ initial separator → per-level band extract + FM) but **yields** its device
work (``BFSWork`` / ``FMWork``) instead of dispatching it.  The sequential
driver (``compute_separator``) executes each yielded work immediately; the
ordering service (``repro.service``) drives many tasks breadth-first and
executes all outstanding work of a depth as bucketed batches.  Both paths
run identical per-work computations, so they produce identical orderings.
"""
from __future__ import annotations

import dataclasses
from typing import Generator, Optional, Tuple, Union

import numpy as np

from repro.core.band import BFSWork, execute_bfs_works, extract_band, \
    project_band
from repro.core.coarsen import MatchWork, coarsen_multilevel_task, \
    execute_match_works
from repro.core.fm import FMWork, execute_fm_works, fm_lane_count, \
    separator_is_valid
from repro.core.graph import Graph
from repro.core.initsep import initial_parts
from repro.core.ordering import Ordering
from repro.sparse.mindeg import min_degree
from repro.util import mix_seeds

Work = Union[BFSWork, FMWork, MatchWork]


@dataclasses.dataclass
class NDConfig:
    leaf_size: int = 96             # switch to minimum degree below this
    coarse_target: int = 120        # coarsest-graph size
    fold_threshold: int = 100       # vertices/process before fold-dup (paper)
    band_width: int = 3             # paper's principled default
    eps_frac: float = 0.12          # balance tolerance
    k_fm_cap: int = 16              # max multi-sequential FM instances
    k_init: int = 8                 # initial-partition tries (per instance)
    fm_passes: int = 3
    use_band: bool = True           # ablation switch (§3.3)
    fold_dup: bool = True           # ablation switch (§3.2)
    seq_threshold: int = 0          # below this n, pretend nproc=1
    # --- ParMETIS-like baseline knobs (paper §3.3's description of [20]) ---
    refine_strict: bool = False     # only strictly-improving moves
    freeze_interface: bool = False  # vertices with remote neighbors frozen


def _project(part_coarse: np.ndarray, cmap: np.ndarray) -> np.ndarray:
    """Separator projection: coarse separator vertex -> both fine children."""
    return part_coarse[cmap].astype(np.int8)


# ------------------------------------------------------------------ #
# stage-separated separator pipeline
# ------------------------------------------------------------------ #
def valid_warm_part(g: Graph, part) -> Optional[np.ndarray]:
    """Validate a cached split as a warm-start separator for ``g``.

    A part vector recorded from a *different* graph's ordering tree is
    a sound separator here iff it matches ``g``'s vertex count, leaves
    both sides non-empty, and no 0–1 edge crosses it — all
    topology-only properties, so any isomorphic-modulo-weights cache
    neighbor's split qualifies while anything else (stale entry, hash
    collision, divergent recursion shape) is rejected and the caller
    runs the cold pipeline.  Returns the validated int8 part or None.
    """
    if part is None or len(part) != g.n:
        return None
    part = np.asarray(part, dtype=np.int8)
    if min(int((part == 0).sum()), int((part == 1).sum())) == 0:
        return None
    src = np.repeat(np.arange(g.n), g.degrees())
    # symmetric CSR: checking 0->1 arcs covers 1->0 too
    if np.any((part[src] == 0) & (part[g.adjncy] == 1)):
        return None
    return part


def separator_task(g: Graph, seed: int, nproc: int, cfg: NDConfig,
                   warm_part: Optional[np.ndarray] = None
                   ) -> Generator[Work, object, Optional[np.ndarray]]:
    """Multilevel + band-FM separator pipeline as a work-yielding generator.

    Yields ``BFSWork`` / ``FMWork`` items; the driver sends back each
    result (``np.ndarray`` dist for BFS, ``(part, sep_w, imb)`` for FM).
    Returns the final part vector, or None when g is too small.

    ``warm_part`` (optional) is a cached split from a structurally
    identical graph's completed ordering tree (the warm-start index,
    DESIGN.md §7): when it validates via ``valid_warm_part`` the task
    returns it immediately — no coarsening, no initial separator, no
    band FM — which is what makes a topology-modulo-weights cache
    near-hit cost a fraction of a cold multilevel run (Holtgrewe/
    Sanders/Schulz: reuse a prior solution as the multilevel starting
    point).  An invalid hint falls through to the full cold pipeline.
    """
    if warm_part is not None:
        cached = valid_warm_part(g, warm_part)
        if cached is not None:
            return cached
    if g.n < 4:
        return None
    # matching works of the coarsening loop propagate to the driver too:
    # the service batches them per ELL bucket across all live subproblems
    state = yield from coarsen_multilevel_task(
        g, seed, nproc=nproc if cfg.fold_dup else 1,
        coarse_target=cfg.coarse_target, fold_threshold=cfg.fold_threshold,
        max_instances=cfg.k_fm_cap)
    coarsest = state.coarsest
    n_inst = state.levels[-1].n_instances
    k_init = min(cfg.k_init * n_inst, 32)

    # initial separator on the coarsest graph (multi-sequential tries)
    parts0 = initial_parts(coarsest, seed, k_tries=k_init)
    nbr_c, _ = coarsest.to_ell()
    part, _, _ = yield FMWork(
        nbr=nbr_c, vwgt=coarsest.vwgt, part=parts0[0],
        locked=np.zeros(coarsest.n, bool), seed=mix_seeds(seed, 0),
        k_inst=k_init, eps_frac=cfg.eps_frac, passes=3, n_pert=4,
        parts_init=parts0)
    assert separator_is_valid(nbr_c, part)

    k_fm = fm_lane_count(nproc, cfg.k_fm_cap, cfg.fold_dup,
                         strict=cfg.refine_strict)
    pos_only = cfg.refine_strict
    n_pert = 0 if pos_only else 8

    # uncoarsen: project, band-extract, multi-sequential FM
    for lvl in range(len(state.levels) - 1, 0, -1):
        cmap = state.levels[lvl].cmap
        fine = state.levels[lvl - 1].graph
        part = _project(part, cmap)
        lvl_seed = mix_seeds(seed, lvl)
        if cfg.use_band:
            nbr_f, _ = fine.to_ell()
            dist = yield BFSWork(nbr=nbr_f, src=part == 2,
                                 width=cfg.band_width)
            band, bpart, locked, old_ids = extract_band(
                fine, part, width=cfg.band_width, dist=dist)
            nbr_b, _ = band.to_ell()
            bpart, _, _ = yield FMWork(
                nbr=nbr_b, vwgt=band.vwgt, part=bpart, locked=locked,
                seed=lvl_seed, k_inst=k_fm, eps_frac=cfg.eps_frac,
                passes=cfg.fm_passes, n_pert=n_pert, pos_only=pos_only)
            assert separator_is_valid(nbr_b, bpart)
            part = project_band(part, bpart, old_ids)
        else:
            locked = np.zeros(fine.n, bool)
            if cfg.freeze_interface and nproc > 1:
                locked |= _interface_frozen(fine, nproc)
            nbr_f, _ = fine.to_ell()
            part, _, _ = yield FMWork(
                nbr=nbr_f, vwgt=fine.vwgt, part=part, locked=locked,
                seed=lvl_seed, k_inst=k_fm, eps_frac=cfg.eps_frac,
                passes=cfg.fm_passes, n_pert=n_pert, pos_only=pos_only)
            assert separator_is_valid(nbr_f, part)
    return part


def execute_work(work: Work):
    """Synchronous single-work execution (the non-batched driver)."""
    if isinstance(work, FMWork):
        return execute_fm_works([work])[0]
    if isinstance(work, MatchWork):
        return execute_match_works([work])[0]
    return execute_bfs_works([work])[0]


def compute_separator(g: Graph, seed: int, nproc: int, cfg: NDConfig
                      ) -> Optional[np.ndarray]:
    """Multilevel + band-FM vertex separator of g.  Returns part or None.

    Drives ``separator_task`` one work at a time; the ordering service
    drives the same generator with bucketed batch execution instead.
    """
    gen = separator_task(g, seed, nproc, cfg)
    try:
        work = next(gen)
        while True:
            work = gen.send(execute_work(work))
    except StopIteration as stop:
        return stop.value


def _interface_frozen(g: Graph, nproc: int) -> np.ndarray:
    """Vertices with neighbors on another process of a block distribution.

    Models the parallel-FM communication constraint the paper attributes to
    ParMETIS [20]: a move whose gain update would need remote coordination
    is not attempted.
    """
    blk = (np.arange(g.n, dtype=np.int64) * nproc) // max(g.n, 1)
    src = np.repeat(np.arange(g.n), g.degrees())
    remote = blk[src] != blk[g.adjncy]
    frozen = np.zeros(g.n, bool)
    frozen[np.unique(src[remote])] = True
    return frozen


def _fallback_separator(g: Graph, seed: int) -> Optional[np.ndarray]:
    from repro.core.mapping import edge_bisect
    half = edge_bisect(g, seed=seed, k_tries=2, passes=2)
    part = half.astype(np.int8)
    src = np.repeat(np.arange(g.n), g.degrees())
    touch = (part[src] == 0) & (part[g.adjncy] == 1)
    part[np.unique(g.adjncy[touch])] = 2
    return part


# ------------------------------------------------------------------ #
# shared ND building blocks (host recursion AND the service scheduler)
# ------------------------------------------------------------------ #
def leaf_perm(g: Graph, seed: int) -> np.ndarray:
    """Order a leaf subgraph with sequential minimum degree."""
    return min_degree(g, tie_seed=seed)


def separator_perm(gs: Graph, seed: int) -> np.ndarray:
    """Order the separator vertices themselves (highest indices).

    Minimum degree internally (paper couples ND with MD [10]); very large
    separators (circuit-like graphs) would stall the host MD —
    profile-order them instead.
    """
    if gs.n <= 2:
        return np.arange(gs.n, dtype=np.int64)
    if gs.n <= 600:
        return min_degree(gs, tie_seed=seed)
    from repro.core.baselines import rcm
    return rcm(gs)


def resolve_separator(g: Graph, seed: int, part: Optional[np.ndarray],
                      cfg: NDConfig) -> Optional[np.ndarray]:
    """Apply the fallback policy to a (possibly degenerate) separator."""
    if part is None or min((part == 0).sum(), (part == 1).sum()) == 0:
        if g.n > 4 * cfg.leaf_size:
            # separator heuristic failed on a big subgraph: fall back to a
            # balanced edge bisection (boundary -> separator) rather than
            # handing O(n) vertices to sequential minimum degree.
            part = _fallback_separator(g, seed)
        if part is None or min((part == 0).sum(), (part == 1).sum()) == 0:
            return None
    return part


def split_by_separator(g: Graph, part: np.ndarray
                       ) -> Tuple[Tuple[Graph, np.ndarray],
                                  Tuple[Graph, np.ndarray],
                                  Tuple[Graph, np.ndarray]]:
    """Induced subgraphs of the two sides and the separator."""
    return (g.induced_subgraph(part == 0),
            g.induced_subgraph(part == 1),
            g.induced_subgraph(part == 2))


def effective_nproc(n: int, nproc: int, cfg: NDConfig) -> int:
    return 1 if n <= cfg.seq_threshold else nproc


def child_nprocs(nproc: int) -> Tuple[int, int]:
    """Paper §3.1: part 0 onto ⌈p/2⌉ processes, part 1 onto ⌊p/2⌋."""
    return (nproc + 1) // 2, max(nproc // 2, 1)


def child_seeds(seed: int) -> Tuple[int, int]:
    """Seeds of the two dissection children (splitmix over the node path).

    Shared by the sequential driver, the service scheduler, and the
    distributed driver so all three stay ordering-identical.
    """
    return mix_seeds(seed, 1), mix_seeds(seed, 2)


def component_seed(seed: int, c: int) -> int:
    """Seed of the c-th connected component of a node."""
    return mix_seeds(seed, 3 + c)


# ------------------------------------------------------------------ #
# sequential driver
# ------------------------------------------------------------------ #
def nested_dissection(g: Graph, seed: int = 0, nproc: int = 1,
                      cfg: Optional[NDConfig] = None) -> np.ndarray:
    """Full ordering.  Returns perm (perm[k] = vertex eliminated k-th)."""
    from repro.util import enable_compile_cache
    enable_compile_cache()
    cfg = cfg or NDConfig()
    ordering = Ordering(g.n)
    _nd_rec(g, np.arange(g.n, dtype=np.int64), seed, nproc, cfg,
            ordering, ordering.root, 0)
    perm = ordering.assemble()
    assert np.array_equal(np.sort(perm), np.arange(g.n)), "not a permutation"
    return perm


def _nd_rec(g: Graph, gids: np.ndarray, seed: int, nproc: int, cfg: NDConfig,
            ordering: Ordering, node, start: int) -> None:
    n = g.n
    if n <= cfg.leaf_size:
        ordering.add_leaf(node, start, gids[leaf_perm(g, seed)])
        return
    comp = g.components()
    ncomp = int(comp.max()) + 1
    if ncomp > 1:                       # independent parts: no separator
        off = start
        for c in range(ncomp):
            sub, old = g.induced_subgraph(comp == c)
            child = ordering.add_internal(node, off, sub.n)
            _nd_rec(sub, gids[old], component_seed(seed, c), nproc, cfg,
                    ordering, child, off)
            off += sub.n
        return
    part = compute_separator(g, seed, effective_nproc(n, nproc, cfg), cfg)
    part = resolve_separator(g, seed, part, cfg)
    if part is None:
        ordering.add_leaf(node, start, gids[leaf_perm(g, seed)])
        return
    (g0, old0), (g1, old1), (gs, olds) = split_by_separator(g, part)
    p0, p1 = child_nprocs(nproc)
    s0, s1 = child_seeds(seed)
    c0 = ordering.add_internal(node, start, g0.n)
    _nd_rec(g0, gids[old0], s0, p0, cfg, ordering, c0, start)
    c1 = ordering.add_internal(node, start + g0.n, g1.n)
    _nd_rec(g1, gids[old1], s1, p1, cfg, ordering, c1,
            start + g0.n)
    # separator ordered last (highest indices)
    sperm = separator_perm(gs, seed)
    ordering.add_leaf(node, start + g0.n + g1.n, gids[olds[sperm]], "sep")
