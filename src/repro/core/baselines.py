"""Ordering baselines the paper compares against (or that frame its results).

* ``parmetis_like``  — nested dissection with the parallel-refinement
  restrictions the paper attributes to ParMETIS [20]: no fold-dup
  duplication, single refinement instance, *strictly-improving moves only*
  (no hill-climbing), refinement on the full graph (no band), and interface
  vertices of the block distribution frozen.  This is the degradation
  mechanism of §3.3, implemented inside the same multilevel machinery so the
  comparison isolates exactly those features.
* ``mindeg_ordering`` — pure sequential minimum degree (paper's other
  classical method, §1).
* ``rcm`` / ``natural`` — profile-ordering reference points.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import Graph
from repro.core.nd import NDConfig, nested_dissection
from repro.sparse.mindeg import min_degree


def pt_scotch_like(g: Graph, seed: int = 0, nproc: int = 1,
                   cfg: NDConfig | None = None) -> np.ndarray:
    """The paper's method (default strategy of §4)."""
    return nested_dissection(g, seed=seed, nproc=nproc, cfg=cfg or NDConfig())


def parmetis_like(g: Graph, seed: int = 0, nproc: int = 1) -> np.ndarray:
    cfg = NDConfig(use_band=False, fold_dup=False, refine_strict=True,
                   freeze_interface=True)
    return nested_dissection(g, seed=seed, nproc=nproc, cfg=cfg)


def mindeg_ordering(g: Graph, seed: int = 0) -> np.ndarray:
    return min_degree(g, tie_seed=seed)


def natural(g: Graph) -> np.ndarray:
    return np.arange(g.n, dtype=np.int64)


def rcm(g: Graph) -> np.ndarray:
    """Reverse Cuthill–McKee (BFS from a pseudo-peripheral vertex)."""
    n = g.n
    visited = np.zeros(n, bool)
    order = []
    deg = g.degrees()
    for comp_seed in np.argsort(deg):
        if visited[comp_seed]:
            continue
        # pseudo-peripheral: two BFS sweeps
        far = comp_seed
        for _ in range(2):
            frontier = [far]
            seen = {int(far)}
            while frontier:
                nxt = []
                for v in frontier:
                    for u in g.neighbors(v):
                        if int(u) not in seen:
                            seen.add(int(u))
                            nxt.append(int(u))
                if nxt:
                    far = min(nxt, key=lambda v: deg[v])
                frontier = nxt
        start = far
        visited[start] = True
        order.append(start)
        frontier = [start]
        while frontier:
            nxt = []
            for v in frontier:
                nbrs = sorted((int(u) for u in g.neighbors(v)
                               if not visited[u]), key=lambda u: deg[u])
                for u in nbrs:
                    visited[u] = True
                    order.append(u)
                    nxt.append(u)
            frontier = nxt
    return np.array(order[::-1], dtype=np.int64)
