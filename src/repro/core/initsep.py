"""Initial separator on the coarsest graph (paper §3.2, "multi-sequential
computation of initial partitions").

Greedy graph growing from a random seed vertex until half the total weight
is absorbed; the frontier of the grown region becomes the vertex separator.
K independent tries (one per fold-dup instance) are refined by FM and the
best wins — the paper's independent multilevel instances collapse to
independent initial partitions + refinements once the graph is centralized.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.graph import Graph
from repro.core.fm import refine_parts, separator_is_valid
from repro.util import mix_seeds


def grow_part(g: Graph, seed: int) -> np.ndarray:
    """One greedy-growing try.  Returns part vector (0/1/2)."""
    rng = np.random.default_rng(seed)
    n = g.n
    total = g.total_vwgt()
    part = np.ones(n, dtype=np.int8)          # all side 1
    start = int(rng.integers(n))
    w0 = 0
    in0 = np.zeros(n, bool)
    frontier = [start]
    # BFS-order growing with slight random shuffling of each layer
    while frontier and w0 * 2 < total:
        rng.shuffle(frontier)
        nxt = []
        for v in frontier:
            if in0[v] or w0 * 2 >= total:
                continue
            in0[v] = True
            w0 += int(g.vwgt[v])
            nxt.extend(int(u) for u in g.neighbors(v) if not in0[u])
        frontier = nxt
    part[in0] = 0
    # separator = side-1 vertices adjacent to side 0
    src = np.repeat(np.arange(n), g.degrees())
    touch = (part[src] == 0) & (part[g.adjncy] == 1)
    part[np.unique(g.adjncy[touch])] = 2
    return part


def initial_parts(g: Graph, seed: int, k_tries: int = 8) -> np.ndarray:
    """Stacked greedy-growing tries (K, n) — the host half of the stage.

    The FM refinement of these tries is a separate ``FMWork`` so the
    ordering service can bucket it with work from other subproblems.
    """
    return np.stack([grow_part(g, seed * 1009 + k) for k in range(k_tries)])


def initial_separator(g: Graph, seed: int, k_tries: int = 8,
                      eps_frac: float = 0.1) -> Tuple[np.ndarray, float]:
    """Best-of-K greedy+FM separator of the (small) coarsest graph.

    All K tries are refined in a single batched FM call (one instance per
    fold-dup working copy).
    """
    nbr, _ = g.to_ell()
    parts0 = initial_parts(g, seed, k_tries)
    part, sep_w, _ = refine_parts(
        nbr, g.vwgt, parts0[0], np.zeros(g.n, bool), mix_seeds(seed, 0),
        k_inst=k_tries, eps_frac=eps_frac, passes=3, n_pert=4,
        parts_init=parts0)
    assert separator_is_valid(nbr, part)
    return part, sep_w
