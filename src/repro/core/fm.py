"""Vertex-separator FM refinement, multi-sequential (paper §3.3), in JAX.

State per vertex: part ∈ {0, 1, 2=separator, 3=padding}.  Invariant: no edge
joins part 0 to part 1.  A move takes a separator vertex v to side p; every
neighbor of v in side 1−p is pulled into the separator (preserving the
invariant).  Gain = vwgt[v] − Σ pulled weights.  Moves may be negative
(hill-climbing); the best state seen is restored at end of pass.

The paper's *multi-sequential* refinement — "centralized copies of this band
graph ... serve to run fully independent instances of our sequential FM
algorithm; the perturbation of the initial state ... allows us to explore
slightly different solution spaces" — is a ``vmap`` over independent
instances.  Since the service PR, the batch axis is a flat *lane* axis that
may mix instances of *different* graphs padded to the same ELL bucket: the
ordering service gathers band-FM work from every ND node at the same depth
and executes one batched dispatch per shape bucket (DESIGN.md §3) — by
default the fused on-device pass loop (``kernels.fm_fused``), with this
module's ``fm_refine_multi`` as the bit-identical hoisted reference path
(``REPRO_FM_MODE``).  Per-lane results are independent of batch
composition, so bucketed execution is bit-compatible with
one-work-at-a-time execution.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from collections import defaultdict
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fm_fused import fm_move_loop as _fm_pass
from repro.util import pow2 as _pow2    # shared bucketing: one definition

NEG_INF = -jnp.inf
BIG_NOISE = 1e9


# --------------------------------------------------------------------- #
# device data plane
# --------------------------------------------------------------------- #
# The per-lane move loop (``_fm_pass``) lives in ``kernels.fm_fused``:
# it is shared verbatim between this hoisted path (vmapped below) and
# the fused on-device pass loop, so the two cannot drift.


def _pulled_jnp(nbrs, valid, vwgt_f, part):
    """pulled_to{0,1}[l, v] = weight of N(v) in side {1, 0} (O(L·n·d))."""
    L, n, d = nbrs.shape
    flat = nbrs.reshape(L, n * d)
    pn = jnp.take_along_axis(part, flat, axis=1).reshape(L, n, d)
    wn = jnp.take_along_axis(vwgt_f, flat, axis=1).reshape(L, n, d)
    wn = jnp.where(valid, wn, 0.0)
    return (jnp.sum(wn * (pn == 1), axis=2),
            jnp.sum(wn * (pn == 0), axis=2))


def _pulled_all(nbrs, valid, vwgt_f, part, gain_mode: str):
    """Per-pass gain recompute over all lanes of a bucket.

    ``pallas`` routes through the batched Mosaic gain kernel
    (``repro.kernels.band_batch.sep_gain_multi``); ``jnp`` is the fused-XLA
    reference (identical reduction order, so results are bit-equal).
    """
    if gain_mode == "pallas":
        from repro.kernels.ops import sep_gain_batch
        return sep_gain_batch(jnp.where(valid, nbrs, -1), vwgt_f,
                              part.astype(jnp.int32))
    return _pulled_jnp(nbrs, valid, vwgt_f, part)


def fm_lane_count(nproc: int, cap: int, fold_dup: bool,
                  strict: bool = False) -> int:
    """Multi-sequential FM lane count for a process group of ``nproc``.

    The paper runs one independent sequential FM instance per process of
    the group refining a band (§3.3); ``cap`` bounds the lane memory,
    ``fold_dup=False`` (ablation) keeps the host floor of two lanes, and
    ``strict`` (the ParMETIS-like baseline) runs a single lane.  Shared by
    the sequential pipeline and the distributed band refinement so both
    derive identical lane counts.
    """
    if strict:
        return 1
    k = int(np.clip(nproc, 1, cap)) if fold_dup else 1
    return max(k, 2)


def gain_mode_default() -> str:
    """FM gain-recompute backend: REPRO_FM_GAIN=jnp|pallas|auto.

    ``auto`` compiles the Mosaic kernel on TPU and keeps the fused-XLA path
    on CPU hosts (where Pallas would run in interpret mode anyway).
    """
    mode = os.environ.get("REPRO_FM_GAIN", "auto")
    if mode == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return mode


@functools.partial(jax.jit, static_argnames=("passes", "pos_only",
                                             "gain_mode"))
def fm_refine_multi(nbr, vwgt, parts_init, locked, keys, eps_frac,
                    max_moves, n_pert, passes: int = 3,
                    pos_only: bool = False, gain_mode: str = "jnp"):
    """FM over a flat lane axis: any mix of (graph, instance) pairs.

    Shapes (L = lanes): nbr (L, n, d) int32; vwgt (L, n); parts_init
    (L, n) int8; locked (L, n) bool; keys (L, 2) uint32; eps_frac (L,)
    f32; max_moves, n_pert (L,) int32.  Returns (parts, sep_w, imb) with
    leading lane axis.  The pass loop is hoisted out of the per-lane body
    so the O(L·n·d) gain recompute runs as ONE batched kernel per pass.

    This is the *hoisted* reference path (``REPRO_FM_MODE=hoisted``);
    the default production path is the fused on-device pass loop
    (``kernels.fm_fused.fm_fused_multi``), bit-identical to this one —
    the differential parity suite (``tests/test_fm_fused.py``) holds
    both against the independent jnp oracle in ``kernels.ref``.
    """
    L, n, d = nbr.shape
    valid = nbr >= 0
    nbrs = jnp.where(valid, nbr, 0)
    vwgt_f = vwgt.astype(jnp.float32)
    total = vwgt_f.sum(axis=1)
    eps_abs = eps_frac.astype(jnp.float32) * total

    def sums(part):
        w0 = jnp.sum(vwgt_f * (part == 0), axis=1)
        w1 = jnp.sum(vwgt_f * (part == 1), axis=1)
        ws = jnp.sum(vwgt_f * (part == 2), axis=1)
        return w0, w1, ws

    part = parts_init
    w0, w1, ws = sums(part)
    bpart, bws, bimb = part, ws, jnp.abs(w0 - w1)
    pert = n_pert                       # perturbation active in pass 1 only
    pass_fn = functools.partial(_fm_pass, pos_only=pos_only)
    for p in range(passes):
        both = jax.vmap(jax.random.split)(keys)             # (L, 2, 2)
        keys, subs = both[:, 0], both[:, 1]
        # per-pass tiebreak noise (moved-locks make per-move noise redundant)
        noise = jax.vmap(lambda k: jax.random.uniform(k, (2, n)))(subs)
        pulled0, pulled1 = _pulled_all(nbrs, valid, vwgt_f, part, gain_mode)
        (part, w0, w1, ws, bpart, bws, bimb) = jax.vmap(pass_fn)(
            nbrs, valid, vwgt_f, locked, eps_abs, part, pulled0, pulled1,
            w0, w1, ws, bpart, bws, bimb, noise, pert, max_moves)
        part = bpart                                        # revert to best
        w0, w1, ws = sums(part)
        pert = jnp.zeros_like(pert)
    return bpart, bws, bimb


# --------------------------------------------------------------------- #
# host work descriptors + bucketed executor
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class FMWork:
    """One multi-instance FM refinement request (unpadded host arrays).

    The pipeline stages in ``core.nd`` *yield* these instead of dispatching
    directly; ``execute_fm_works`` pads each to its power-of-two ELL bucket
    and runs every work sharing a bucket in a single ``fm_refine_multi``
    dispatch (one lane per FM instance).

    ``locked`` and ``max_moves`` are *lane data*, not part of
    ``bucket_key``: works whose locked masks or move budgets differ
    (e.g. the per-phase boundary-color masks of the sharded-band
    alternating schedule, ``dnd._sharded_band_task``) still batch into
    one dispatch, because every lane's mask and budget ride in as input
    arrays of the kernel — only fields that change the compiled program
    (padded n / d, passes, pos_only) key the bucket.  A locked vertex
    cannot be *selected* for a move, but a move may still *pull* it into
    the separator; schedulers that lock remote-owned copies must
    propagate such pulls themselves.
    """
    nbr: np.ndarray                     # (n, d) int32 ELL ids, -1 pad
    vwgt: np.ndarray                    # (n,) vertex weights
    part: np.ndarray                    # (n,) int8 initial state
    locked: np.ndarray                  # (n,) bool
    seed: int
    k_inst: int = 8
    eps_frac: float = 0.1
    passes: int = 3
    max_moves: Optional[int] = None
    n_pert: int = 8
    parts_init: Optional[np.ndarray] = None    # (K, n) distinct starts
    pos_only: bool = False

    def effective_max_moves(self) -> int:
        n_pad = _pow2(self.nbr.shape[0])
        max_moves = self.max_moves
        if max_moves is None:
            if self.parts_init is None:
                sep_sz = int((self.part == 2).sum())
            else:
                sep_sz = int((np.asarray(self.parts_init) == 2).sum(1).max())
            max_moves = 2 * sep_sz + 16
        return min(int(max_moves), n_pad, 4096)

    def bucket_key(self) -> Tuple[int, int, int, bool]:
        n, d = self.nbr.shape
        # max_moves is adaptive per lane, NOT sub-bucketed: the fused
        # kernel's grid runs one lane at a time, so each lane's move
        # loop terminates at its own budget — mixing small budgets with
        # large ones serializes nothing.  (The hoisted path's vmapped
        # while_loop select-masks finished lanes, so per-lane results
        # are budget-composition-independent there too.)  Fewer buckets
        # ⇒ fewer compiles and wider lane stacks per dispatch.
        return (_pow2(n), _pow2(max(d, 1), 8), self.passes, self.pos_only)


@dataclasses.dataclass
class _Lanes:
    """One work's padded per-lane arrays (k_inst lanes)."""
    nbr: np.ndarray                     # (k, n_pad, d_pad) — broadcast view
    vwgt: np.ndarray
    locked: np.ndarray
    parts0: np.ndarray
    keys: np.ndarray
    eps: np.ndarray
    max_moves: np.ndarray
    n_pert: np.ndarray


def _prepare_lanes(w: FMWork) -> _Lanes:
    n, d = w.nbr.shape
    n_pad, d_pad = w.bucket_key()[:2]
    k_inst = _pow2(w.k_inst, 2)
    nbr_p = -np.ones((n_pad, d_pad), np.int32)
    nbr_p[:n, :d] = w.nbr
    vw_p = np.zeros(n_pad, np.int32)
    vw_p[:n] = w.vwgt
    lock_p = np.ones(n_pad, bool)
    lock_p[:n] = w.locked
    if w.parts_init is None:
        parts_init = np.broadcast_to(np.asarray(w.part, np.int8)[None, :],
                                     (k_inst, n))
    else:
        parts_init = np.asarray(w.parts_init, np.int8)[
            np.arange(k_inst) % len(w.parts_init)]
    max_moves = w.effective_max_moves()
    parts0 = np.full((k_inst, n_pad), 3, np.int8)
    parts0[:, :n] = parts_init
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(w.seed), k_inst))
    return _Lanes(
        nbr=np.broadcast_to(nbr_p, (k_inst, n_pad, d_pad)),
        vwgt=np.broadcast_to(vw_p, (k_inst, n_pad)),
        locked=np.broadcast_to(lock_p, (k_inst, n_pad)),
        parts0=parts0, keys=keys,
        eps=np.full(k_inst, w.eps_frac, np.float32),
        max_moves=np.full(k_inst, max_moves, np.int32),
        n_pert=np.full(k_inst, w.n_pert, np.int32))


def _select_best(w: FMWork, parts: np.ndarray, sep_w: np.ndarray,
                 imb: np.ndarray) -> Tuple[np.ndarray, float, float]:
    """Paper's selection: min separator weight among balance-feasible."""
    total = float(np.asarray(w.vwgt).sum())
    feas = imb <= max(w.eps_frac * total, float(imb.min()))
    score = np.where(feas, sep_w, sep_w + total)            # infeasible last
    best = int(np.argmin(score))
    return parts[best], float(sep_w[best]), float(imb[best])


def execute_fm_works(works: Sequence[FMWork],
                     gain_mode: Optional[str] = None,
                     mode: Optional[str] = None
                     ) -> List[Tuple[np.ndarray, float, float]]:
    """Run FM works, one batched dispatch per (n_pad, d_pad) bucket.

    Returns, for each work in input order, the best ``(part, sep_w, imb)``
    across its instances — exactly what ``refine_parts`` returns.  Lane
    results do not depend on which other works share the dispatch, so this
    is equivalent to (but much cheaper than) per-work execution.

    ``mode`` picks the fused on-device pass loop vs the hoisted path
    (default ``ops.fm_mode_default()``, i.e. ``REPRO_FM_MODE``); both
    are bit-identical.  An explicit ``gain_mode`` without an explicit
    ``mode`` forces the hoisted path — the gain backend only exists
    there, and callers comparing gain backends mean to compare them.
    """
    from repro.kernels.ops import fm_mode_default, fm_refine_batch
    if mode is None:
        mode = "hoisted" if gain_mode is not None else fm_mode_default()
    if mode == "hoisted" and gain_mode is None:
        gain_mode = gain_mode_default()
    results: List[Optional[Tuple[np.ndarray, float, float]]] = \
        [None] * len(works)
    groups = defaultdict(list)
    for i, w in enumerate(works):
        groups[w.bucket_key()].append(i)
    for (n_pad, d_pad, passes, pos_only), idxs in groups.items():
        lanes = [_prepare_lanes(works[i]) for i in idxs]
        counts = [ln.parts0.shape[0] for ln in lanes]
        L_real = sum(counts)
        # Lane padding to a multiple of 8: dead lanes still pay the vmapped
        # move-loop body every trip, so pow2 padding would waste up to 2×.
        L_pad = -(-L_real // 8) * 8
        pad = L_pad - L_real

        def cat(get, fill_from_first):
            arrs = [get(ln) for ln in lanes]
            if pad:
                arrs.append(np.broadcast_to(get(lanes[0])[:1],
                                            (pad,) + get(lanes[0]).shape[1:])
                            if fill_from_first else
                            np.zeros((pad,) + arrs[0].shape[1:],
                                     arrs[0].dtype))
            return np.concatenate(arrs, axis=0)

        nbr_b = cat(lambda ln: ln.nbr, True)
        vw_b = cat(lambda ln: ln.vwgt, True)
        lock_b = cat(lambda ln: ln.locked, True)
        parts_b = cat(lambda ln: ln.parts0, True)
        keys_b = cat(lambda ln: ln.keys, True)
        eps_b = cat(lambda ln: ln.eps, True)
        mm_b = cat(lambda ln: ln.max_moves, False)  # dummies: 0 moves
        np_b = cat(lambda ln: ln.n_pert, True)
        from repro import obs
        from repro.core.dgraph import _note_launch

        def dispatch():
            parts, sep_w, imb = fm_refine_batch(
                jnp.asarray(nbr_b), jnp.asarray(vw_b), jnp.asarray(parts_b),
                jnp.asarray(lock_b), jnp.asarray(keys_b), jnp.asarray(eps_b),
                jnp.asarray(mm_b), jnp.asarray(np_b), passes=passes,
                pos_only=pos_only, mode=mode, gain_mode=gain_mode)
            return np.asarray(parts), np.asarray(sep_w), np.asarray(imb)

        # the compiled program does not depend on the lanes' move
        # budgets (max_moves is traced lane data in both modes), so the
        # jit key — which decides the compile/dispatch billing split —
        # carries only program-shaping fields.  One dispatch:fm span
        # covers all ``passes`` on-device passes of the bucket.
        parts, sep_w, imb = obs.timed_dispatch(
            "fm", "fm",
            ("fm", mode, n_pad, d_pad, passes, pos_only, gain_mode, L_pad),
            dispatch, lanes=L_real, lanes_pad=L_pad, mode=mode,
            max_moves=int(mm_b.max()),
            bucket=(n_pad, d_pad, passes, pos_only))
        _note_launch("fm", 0, L_real, L_pad,
                     (n_pad, d_pad, passes, pos_only), passes, 0)
        off = 0
        for i, k in zip(idxs, counts):
            n = works[i].nbr.shape[0]
            results[i] = _select_best(
                works[i], parts[off:off + k, :n],
                sep_w[off:off + k], imb[off:off + k])
            off += k
    return results                                           # type: ignore


def refine_parts(nbr: np.ndarray, vwgt: np.ndarray, part: np.ndarray,
                 locked: np.ndarray, seed: int, k_inst: int = 8,
                 eps_frac: float = 0.1, passes: int = 3,
                 max_moves: int | None = None, n_pert: int = 8,
                 parts_init: np.ndarray | None = None,
                 pos_only: bool = False
                 ) -> Tuple[np.ndarray, float, float]:
    """Run K FM instances on an ELL graph; return the best part vector.

    Selection is the paper's: best refined band separator wins —
    min separator weight among balance-feasible instances.
    ``parts_init`` optionally provides a distinct initial state per instance
    (K, n) — used by the initial-partition phase.  This is the one-work
    convenience wrapper over ``execute_fm_works``.
    """
    work = FMWork(nbr=nbr, vwgt=vwgt, part=part, locked=locked, seed=seed,
                  k_inst=k_inst, eps_frac=eps_frac, passes=passes,
                  max_moves=max_moves, n_pert=n_pert, parts_init=parts_init,
                  pos_only=pos_only)
    return execute_fm_works([work])[0]


def separator_is_valid(nbr: np.ndarray, part: np.ndarray) -> bool:
    """No edge joins part 0 and part 1."""
    valid = nbr >= 0
    pn = np.where(valid, part[np.where(valid, nbr, 0)], 3)
    p = part[:, None]
    bad = ((p == 0) & (pn == 1)) | ((p == 1) & (pn == 0))
    return not bool(bad.any())
