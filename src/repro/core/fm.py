"""Vertex-separator FM refinement, multi-sequential (paper §3.3), in JAX.

State per vertex: part ∈ {0, 1, 2=separator, 3=padding}.  Invariant: no edge
joins part 0 to part 1.  A move takes a separator vertex v to side p; every
neighbor of v in side 1−p is pulled into the separator (preserving the
invariant).  Gain = vwgt[v] − Σ pulled weights.  Moves may be negative
(hill-climbing); the best state seen is restored at end of pass.

The paper's *multi-sequential* refinement — "centralized copies of this band
graph ... serve to run fully independent instances of our sequential FM
algorithm; the perturbation of the initial state ... allows us to explore
slightly different solution spaces" — is here a ``vmap`` over K instances
whose first ``n_pert`` moves are randomized.  Batching over instances is the
TPU-native form of the paper's one-instance-per-process scheme.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -jnp.inf
BIG_NOISE = 1e9


def _fm_single(nbr, vwgt, part_init, locked, key, eps_frac, max_moves,
               n_pert, passes: int, pos_only: bool = False):
    n, d = nbr.shape
    valid = nbr >= 0
    nbrs = jnp.where(valid, nbr, 0)
    vwgt_f = vwgt.astype(jnp.float32)
    total = vwgt_f.sum()
    eps_abs = eps_frac * total
    vid = jnp.arange(n, dtype=jnp.int32)

    def sums(part):
        w0 = jnp.sum(vwgt_f * (part == 0))
        w1 = jnp.sum(vwgt_f * (part == 1))
        ws = jnp.sum(vwgt_f * (part == 2))
        return w0, w1, ws

    def pulled_full(part):
        """pulled_to{0,1}[v] = weight of N(v) in side {1,0} (O(n·d))."""
        pn = part[nbrs]                                     # (n, d)
        wn = jnp.where(valid, vwgt_f[nbrs], 0.0)
        return (jnp.sum(wn * (pn == 1), axis=1),
                jnp.sum(wn * (pn == 0), axis=1))

    def move_cond(carry):
        i, alive, *_ = carry
        return (i < max_moves) & alive

    def move_body(carry):
        """One FM move.  ``pulled0/1`` are maintained incrementally:
        selection is O(n) vector ops, the update is O(d²) scatters —
        (beyond-paper optimization vs the naive O(n·d) gain recompute)."""
        (i, alive, part, moved, pulled0, pulled1,
         w0, w1, ws, bpart, bws, bimb) = carry
        gain0 = vwgt_f - pulled0
        gain1 = vwgt_f - pulled1
        # --- feasibility (balance after move)
        imb = jnp.abs(w0 - w1)
        imb0 = jnp.abs((w0 + vwgt_f) - (w1 - pulled0))
        imb1 = jnp.abs((w0 - pulled1) - (w1 + vwgt_f))
        feas0 = imb0 <= jnp.maximum(eps_abs, imb)
        feas1 = imb1 <= jnp.maximum(eps_abs, imb)
        movable = (part == 2) & ~moved & ~locked
        amp = jnp.where(i < pert, BIG_NOISE, 1e-3)
        ok0, ok1 = movable & feas0, movable & feas1
        if pos_only:                    # ParMETIS-style strict improvement
            ok0, ok1 = ok0 & (gain0 > 0), ok1 & (gain1 > 0)
        s0 = jnp.where(ok0, gain0 + noise[0] * amp, NEG_INF)
        s1 = jnp.where(ok1, gain1 + noise[1] * amp, NEG_INF)
        scores = jnp.concatenate([s0, s1])
        idx = jnp.argmax(scores)
        ok = scores[idx] > NEG_INF
        side = (idx >= n).astype(jnp.int8)
        v = (idx % n).astype(jnp.int32)
        # --- apply (masked; no-op when not ok)
        nv = nbrs[v]                                        # (d,)
        nvalid = valid[v]
        pull_slot = nvalid & (part[nv] == (1 - side)) & ok  # pulled set ⊆ N(v)
        pulled_w = jnp.sum(jnp.where(pull_slot, vwgt_f[nv], 0.0))
        # part updates
        tgt_pull = jnp.where(pull_slot, nv, n)
        part = part.at[tgt_pull].set(jnp.int8(2), mode="drop")
        part = part.at[v].set(jnp.where(ok, side, part[v]))
        # pulled0/1 updates from v's side change (v: 2 -> side)
        tgt_v = jnp.where(nvalid & ok, nv, n)
        dv_w = vwgt_f[v]
        pulled0 = pulled0.at[tgt_v].add(
            jnp.where(side == 1, dv_w, 0.0), mode="drop")
        pulled1 = pulled1.at[tgt_v].add(
            jnp.where(side == 0, dv_w, 0.0), mode="drop")
        # pulled0/1 updates from the pulled set (u: 1-side -> 2)
        rows = nbrs[nv]                                     # (d, d)
        rvalid = valid[nv] & pull_slot[:, None]
        tgt_u = jnp.where(rvalid, rows, n).reshape(-1)
        amt = jnp.broadcast_to(vwgt_f[nv][:, None], rows.shape)
        amt = jnp.where(rvalid, amt, 0.0).reshape(-1)
        pulled0 = pulled0.at[tgt_u].add(
            jnp.where(side == 0, -amt, 0.0), mode="drop")
        pulled1 = pulled1.at[tgt_u].add(
            jnp.where(side == 1, -amt, 0.0), mode="drop")
        # weights
        dv = jnp.where(ok, dv_w, 0.0)
        w0 = w0 + jnp.where(side == 0, dv, 0.0) - jnp.where(side == 1, pulled_w, 0.0)
        w1 = w1 + jnp.where(side == 1, dv, 0.0) - jnp.where(side == 0, pulled_w, 0.0)
        ws = ws - dv + pulled_w
        moved = moved.at[v].set(moved[v] | ok)
        # --- best-seen tracking (feasible states only)
        imb_new = jnp.abs(w0 - w1)
        better = (ws < bws) & (imb_new <= jnp.maximum(eps_abs, bimb))
        bpart = jnp.where(better, part, bpart)
        bws = jnp.where(better, ws, bws)
        bimb = jnp.where(better, jnp.minimum(imb_new, bimb), bimb)
        return (i + 1, ok, part, moved, pulled0, pulled1,
                w0, w1, ws, bpart, bws, bimb)

    part = part_init
    w0, w1, ws = sums(part)
    bpart, bws, bimb = part, ws, jnp.abs(w0 - w1)
    pert = n_pert                       # read by move_body at trace time
    for p in range(passes):
        moved = jnp.zeros(n, bool)
        key, sub = jax.random.split(key)
        # per-pass tiebreak noise (moved-locks make per-move noise redundant)
        noise = jax.random.uniform(sub, (2, n))
        pulled0, pulled1 = pulled_full(part)
        carry = (jnp.int32(0), jnp.bool_(True), part, moved, pulled0,
                 pulled1, w0, w1, ws, bpart, bws, bimb)
        carry = jax.lax.while_loop(move_cond, move_body, carry)
        _, _, part, _, _, _, w0, w1, ws, bpart, bws, bimb = carry
        part = bpart                                        # revert to best
        w0, w1, ws = sums(part)
        pert = jnp.int32(0)                                 # 1st pass only
    return bpart, bws, bimb


@functools.partial(jax.jit, static_argnames=("passes", "pos_only"))
def fm_refine_batch(nbr, vwgt, parts_init, locked, keys, eps_frac,
                    max_moves, n_pert, passes: int = 3,
                    pos_only: bool = False):
    """vmap of FM over K perturbed instances (multi-sequential refinement)."""
    fn = functools.partial(_fm_single, passes=passes, pos_only=pos_only)
    return jax.vmap(fn, in_axes=(None, None, 0, None, 0, None, None, None))(
        nbr, vwgt, parts_init, locked, keys, eps_frac, max_moves, n_pert)


# --------------------------------------------------------------------- #
# host wrapper
# --------------------------------------------------------------------- #
def _pow2(x: int, lo: int = 64) -> int:
    """Round up to a power of two (jit-cache friendly bucketing)."""
    v = lo
    while v < x:
        v *= 2
    return v


def refine_parts(nbr: np.ndarray, vwgt: np.ndarray, part: np.ndarray,
                 locked: np.ndarray, seed: int, k_inst: int = 8,
                 eps_frac: float = 0.1, passes: int = 3,
                 max_moves: int | None = None, n_pert: int = 8,
                 parts_init: np.ndarray | None = None,
                 pos_only: bool = False
                 ) -> Tuple[np.ndarray, float, float]:
    """Run K FM instances on an ELL graph; return the best part vector.

    Selection is the paper's: best refined band separator wins —
    min separator weight among balance-feasible instances.
    ``parts_init`` optionally provides a distinct initial state per instance
    (K, n) — used by the initial-partition phase.
    """
    n, d = nbr.shape
    n_pad, d_pad = _pow2(n), _pow2(d, 8)
    k_inst = _pow2(k_inst, 2)
    nbr_p = -np.ones((n_pad, d_pad), np.int32)
    nbr_p[:n, :d] = nbr
    vw_p = np.zeros(n_pad, np.int32)
    vw_p[:n] = vwgt
    lock_p = np.ones(n_pad, bool)
    lock_p[:n] = locked
    if parts_init is None:
        parts_init = np.broadcast_to(part[None, :], (k_inst, n))
        sep_sz = int((part == 2).sum())
    else:
        parts_init = np.asarray(parts_init)[
            np.arange(k_inst) % len(parts_init)]
        sep_sz = int((parts_init == 2).sum(1).max())
    if max_moves is None:
        max_moves = 2 * sep_sz + 16
    max_moves = min(int(max_moves), n_pad, 4096)
    parts0 = np.full((k_inst, n_pad), 3, np.int8)
    parts0[:, :n] = parts_init
    keys = jax.random.split(jax.random.PRNGKey(seed), k_inst)
    parts, sep_w, imb = fm_refine_batch(
        jnp.asarray(nbr_p), jnp.asarray(vw_p), jnp.asarray(parts0),
        jnp.asarray(lock_p), keys, float(eps_frac),
        jnp.int32(max_moves), jnp.int32(n_pert), passes=passes,
        pos_only=pos_only)
    parts = np.asarray(parts)[:, :n]
    sep_w = np.asarray(sep_w)
    imb = np.asarray(imb)
    total = float(vwgt.sum())
    feas = imb <= max(eps_frac * total, float(imb.min()))
    score = np.where(feas, sep_w, sep_w + total)            # infeasible last
    best = int(np.argmin(score))
    return parts[best], float(sep_w[best]), float(imb[best])


def separator_is_valid(nbr: np.ndarray, part: np.ndarray) -> bool:
    """No edge joins part 0 and part 1."""
    valid = nbr >= 0
    pn = np.where(valid, part[np.where(valid, nbr, 0)], 3)
    p = part[:, None]
    bad = ((p == 0) & (pn == 1)) | ((p == 1) & (pn == 0))
    return not bool(bad.any())
