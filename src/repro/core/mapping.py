"""Static mapping by dual recursive bipartitioning (Scotch's k-way mapping).

The paper's §5 names static mapping as the intended extension of the same
building blocks; here it is the *first-class integration point* of the
ordering library into the LM framework: MoE experts (tasks, weighted by
co-activation traffic) are mapped onto the device hierarchy (2 pods × 256
chips, slow inter-pod links) so that heavy-traffic expert pairs land close
together — minimizing the expensive cross-pod all-to-all bytes.

Algorithm: recursively bisect the task graph (balanced min-cut via the
multilevel + FM machinery) while bisecting the device set along its slowest
axis; recurse until single devices remain.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass(frozen=True)
class DeviceTier:
    """One level of the device hierarchy: ``count`` groups, crossing such a
    group boundary costs ``link_cost`` per unit traffic."""
    count: int
    link_cost: float


def edge_bisect(g: Graph, seed: int = 0, k_tries: int = 4,
                passes: int = 4, eps: float = 0.1) -> np.ndarray:
    """Balanced 2-way partition (0/1) minimizing *weighted edge cut*.

    FM-style hill-climbing with per-pass best-prefix rollback (mapping
    needs the edge-cut objective, unlike ordering's vertex separators).
    Small task graphs (experts, stages) → plain numpy is plenty.
    """
    n = g.n
    if n <= 1:
        return np.zeros(n, dtype=np.int8)
    src = np.repeat(np.arange(n), g.degrees())
    total = g.total_vwgt()
    best_part, best_cut = None, np.inf
    for t in range(k_tries):
        rng = np.random.default_rng(seed * 97 + t)
        part = (rng.permutation(n) < n // 2).astype(np.int8)
        for _ in range(passes):
            # gain[v] = ext(v) - int(v) under current part
            w_to0 = np.zeros(n)
            np.add.at(w_to0, src, g.adjwgt * (part[g.adjncy] == 0))
            w_to1 = np.zeros(n)
            np.add.at(w_to1, src, g.adjwgt * (part[g.adjncy] == 1))
            gain = np.where(part == 0, w_to1 - w_to0, w_to0 - w_to1)
            locked = np.zeros(n, bool)
            w = np.array([g.vwgt[part == 0].sum(),
                          g.vwgt[part == 1].sum()], dtype=float)
            cut = float(g.adjwgt[part[src] != part[g.adjncy]].sum()) / 2
            trace, cur = [], cut
            order_part, order_gain = part.copy(), None
            for _move in range(n):
                cand = np.where(~locked)[0]
                if not len(cand):
                    break
                # feasibility: don't overfill the target side
                p_of = part[cand]
                neww = w[1 - p_of] + g.vwgt[cand]
                feas = neww <= total * (0.5 + eps)
                if not feas.any():
                    break
                scores = np.where(feas, gain[cand], -np.inf)
                v = cand[int(np.argmax(scores))]
                pv = part[v]
                cur -= gain[v]
                w[pv] -= g.vwgt[v]
                w[1 - pv] += g.vwgt[v]
                part[v] = 1 - pv
                locked[v] = True
                trace.append((v, cur))
                # incremental gain update for neighbors of v
                nb = g.neighbors(v)
                wv = g.adjwgt[g.xadj[v]:g.xadj[v + 1]].astype(float)
                same_new = part[nb] == part[v]
                gain[nb] += np.where(same_new, -2 * wv, 2 * wv)
                gain[v] = -gain[v]
            if not trace:
                break
            cuts = np.array([c for _, c in trace])
            k_best = int(np.argmin(cuts))
            if cuts[k_best] >= cut - 1e-9:
                # no improvement: roll everything back, stop passes
                for v, _ in trace:
                    part[v] = 1 - part[v]
                break
            for v, _ in trace[k_best + 1:]:
                part[v] = 1 - part[v]
        final_cut = cut_weight(g, part)
        imb = abs(g.vwgt[part == 0].sum() - g.vwgt[part == 1].sum())
        score = final_cut + (0 if imb <= eps * total else 1e12)
        if score < best_cut:
            best_part, best_cut = part.copy(), score
    return best_part


def cut_weight(g: Graph, assign: np.ndarray) -> float:
    src = np.repeat(np.arange(g.n), g.degrees())
    cut = assign[src] != assign[g.adjncy]
    return float(g.adjwgt[cut].sum()) / 2.0


def static_map(g: Graph, tiers: Sequence[DeviceTier], seed: int = 0
               ) -> np.ndarray:
    """Map task graph vertices onto the leaves of the device hierarchy.

    Returns assign[v] = flat device index in [0, Π tier.count).
    """
    n_dev = int(np.prod([t.count for t in tiers]))
    assign = np.zeros(g.n, dtype=np.int64)

    def rec(sub: Graph, ids: np.ndarray, dev_lo: int, n_dev_here: int,
            s: int) -> None:
        if n_dev_here <= 1 or sub.n == 0:
            assign[ids] = dev_lo
            return
        half = edge_bisect(sub, seed=s)
        left = n_dev_here // 2
        g0, old0 = sub.induced_subgraph(half == 0)
        g1, old1 = sub.induced_subgraph(half == 1)
        rec(g0, ids[old0], dev_lo, left, s * 2 + 1)
        rec(g1, ids[old1], dev_lo + left, n_dev_here - left, s * 2 + 2)

    rec(g, np.arange(g.n), 0, n_dev, seed + 1)
    return assign


def traffic_cost(g: Graph, assign: np.ndarray,
                 tiers: Sequence[DeviceTier]) -> float:
    """Σ over edges of link_cost(highest tier boundary crossed) · weight."""
    counts = [t.count for t in tiers]
    src = np.repeat(np.arange(g.n), g.degrees())
    a, b = assign[src], assign[g.adjncy]
    cost = np.zeros(len(a))
    # device index -> per-tier coordinates (row-major)
    def coords(x):
        out = []
        for c in reversed(counts):
            out.append(x % c)
            x = x // c
        return list(reversed(out))
    ca, cb = coords(a), coords(b)
    crossed = np.zeros(len(a), bool)
    for t, (xa, xb) in enumerate(zip(ca, cb)):
        newly = (~crossed) & (xa != xb)
        cost[newly] = tiers[t].link_cost
        crossed |= newly
    return float((cost * g.adjwgt).sum()) / 2.0


def expert_placement(coactivation: np.ndarray, n_pods: int, chips_per_pod: int,
                     inter_pod_cost: float = 10.0, seed: int = 0
                     ) -> np.ndarray:
    """Place E experts on (n_pods × chips_per_pod) devices.

    ``coactivation[i, j]`` = expected tokens routed through experts i and j
    in the same layer step (the all-to-all traffic proxy).
    Returns device index per expert.
    """
    E = coactivation.shape[0]
    w = np.maximum(coactivation, coactivation.T)
    iu, ju = np.nonzero(np.triu(w, 1))
    scale = max(w.max(), 1e-9)
    ew = np.maximum((w[iu, ju] / scale * 1000).astype(np.int64), 1)
    g = Graph.from_edges(E, np.stack([iu, ju], 1), ewgt=ew)
    tiers = [DeviceTier(n_pods, inter_pod_cost),
             DeviceTier(chips_per_pod, 1.0)]
    return static_map(g, tiers, seed=seed)
