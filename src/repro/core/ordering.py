"""Distributed ordering structure (paper §2.2).

A tree spreading over the (simulated) processes, whose leaves are fragments
of the *inverse permutation*: each ND node receives a global start index in
the inverse permutation array; leaves are filled with original global
indices of reordered subgraph vertices; assembly by ascending start index
yields the complete inverse permutation.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class OrderNode:
    start: int                      # global start index of this sub-ordering
    size: int
    kind: str                       # "nd" | "leaf" | "sep"
    children: List["OrderNode"] = dataclasses.field(default_factory=list)
    fragment: Optional[np.ndarray] = None   # leaf: original ids, local order


class Ordering:
    def __init__(self, n: int):
        self.n = n
        self.root = OrderNode(0, n, "nd")
        self._frags: List[OrderNode] = []

    def add_leaf(self, parent: OrderNode, start: int, original_ids: np.ndarray,
                 kind: str = "leaf") -> OrderNode:
        node = OrderNode(start, len(original_ids), kind, fragment=original_ids)
        parent.children.append(node)
        self._frags.append(node)
        return node

    def add_internal(self, parent: OrderNode, start: int, size: int
                     ) -> OrderNode:
        node = OrderNode(start, size, "nd")
        parent.children.append(node)
        return node

    def assemble(self) -> np.ndarray:
        """Concatenate fragments by ascending start index -> perm.

        perm[k] = original vertex eliminated k-th (inverse permutation in the
        paper's sense: fragment content is original global indices).
        """
        perm = np.empty(self.n, dtype=np.int64)
        seen = 0
        for node in sorted(self._frags, key=lambda f: f.start):
            assert node.start == seen, (
                f"fragment at {node.start} overlaps/gaps previous end {seen}")
            perm[node.start:node.start + node.size] = node.fragment
            seen += node.size
        assert seen == self.n, f"fragments cover {seen} of {self.n}"
        return perm

    def depth(self) -> int:
        def d(node):
            return 1 + max((d(c) for c in node.children), default=0)
        return d(self.root)
