"""Centralized ordering structure (paper §2.2, one-process form).

A tree whose leaves are fragments of the *inverse permutation*: each ND
node receives a global start index in the inverse permutation array;
leaves are filled with original global indices of reordered subgraph
vertices; assembly by ascending start index yields the complete inverse
permutation.

This is the host-recursion form used by the sequential driver
(``core.nd``) and the service scheduler (``service.scheduler``), where
one process holds every fragment.  The *distributed* form of the same
§2.2 structure — per-shard fragments with prefix-sum offsets and
column-block ranges per node — is ``core.dnd.DistOrdering``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class OrderNode:
    """One node of the ordering tree.

    ``start`` / ``size`` delimit the node's column block — the global
    index range [start, start + size) of the inverse permutation its
    subtree orders.  ``fragment`` (leaves only) holds original global
    vertex ids in elimination order.
    """
    start: int                      # global start index of this sub-ordering
    size: int
    kind: str                       # "nd" | "leaf" | "sep"
    children: List["OrderNode"] = dataclasses.field(default_factory=list)
    fragment: Optional[np.ndarray] = None   # leaf: original ids, local order


class Ordering:
    """Ordering tree under construction during an ND recursion.

    Usage contract (shared by ``core.nd`` and ``service.scheduler``):
    internal nodes are registered with their column block as soon as the
    separator fixes the child sizes; leaves attach their fragment when
    the subgraph is ordered; ``assemble`` concatenates once every index
    of [0, n) is covered.
    """

    def __init__(self, n: int):
        self.n = n
        self.root = OrderNode(0, n, "nd")
        self._frags: List[OrderNode] = []

    def add_leaf(self, parent: OrderNode, start: int, original_ids: np.ndarray,
                 kind: str = "leaf") -> OrderNode:
        """Attach a leaf covering [start, start + len(original_ids)).

        ``original_ids`` are global vertex ids in elimination order (the
        fragment content of the paper's inverse-permutation tree).
        """
        node = OrderNode(start, len(original_ids), kind, fragment=original_ids)
        parent.children.append(node)
        self._frags.append(node)
        return node

    def add_internal(self, parent: OrderNode, start: int, size: int
                     ) -> OrderNode:
        """Attach an internal ND node covering [start, start + size)."""
        node = OrderNode(start, size, "nd")
        parent.children.append(node)
        return node

    def assemble(self) -> np.ndarray:
        """Concatenate fragments by ascending start index -> perm.

        perm[k] = original vertex eliminated k-th (inverse permutation in the
        paper's sense: fragment content is original global indices).
        Asserts the fragments tile [0, n) exactly (no overlap, no gap).
        """
        perm = np.empty(self.n, dtype=np.int64)
        seen = 0
        for node in sorted(self._frags, key=lambda f: f.start):
            assert node.start == seen, (
                f"fragment at {node.start} overlaps/gaps previous end {seen}")
            perm[node.start:node.start + node.size] = node.fragment
            seen += node.size
        assert seen == self.n, f"fragments cover {seen} of {self.n}"
        return perm

    def depth(self) -> int:
        """Height of the ordering tree (root counts as 1)."""
        def d(node):
            return 1 + max((d(c) for c in node.children), default=0)
        return d(self.root)
