"""Distributed band-graph extraction (paper §3.3).

Vertices at distance ≤ ``width`` (paper's principled default: 3) from the
projected separator are kept; two *anchor* vertices per side absorb the
remainder, carrying its total vertex weight so balance is preserved, and are
connected to the last band layer of their side.  The distance sweep is the
paper's "spreading distance information from all of the separator vertices,
using our halo exchange routine" — here a vectorized ELL relaxation in JAX
(one halo exchange per width step in the distributed version).

The ordering service batches this stage: pipeline tasks yield a ``BFSWork``
per uncoarsening level and ``execute_bfs_works`` runs every work sharing a
padded ELL bucket as one batched sweep (the Mosaic kernel
``kernels.band_batch.bfs_multi`` on TPU, fused XLA on CPU hosts) —
DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from collections import defaultdict
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.util import pow2

UNREACH = np.int32(2 ** 30)


@functools.partial(jax.jit, static_argnames=("width",))
def bfs_distance(nbr: jax.Array, src_mask: jax.Array, width: int) -> jax.Array:
    """dist[v] = min(graph distance to src, width+1), by width relaxations."""
    from repro.kernels.ops import ell_relax_step
    dist = jnp.where(src_mask, 0, UNREACH).astype(jnp.int32)
    for _ in range(width):
        dist = jnp.minimum(dist, ell_relax_step(nbr, dist, UNREACH))
    return dist


@functools.partial(jax.jit, static_argnames=("width",))
def bfs_distance_multi(nbr: jax.Array, src: jax.Array, width: int
                       ) -> jax.Array:
    """Batched ``bfs_distance`` over a (L, n, d) bucket (fused-XLA path)."""
    L, n, d = nbr.shape
    valid = nbr >= 0
    idx = jnp.where(valid, nbr, 0)
    dist = jnp.where(src != 0, 0, UNREACH).astype(jnp.int32)
    for _ in range(width):
        dn = jnp.take_along_axis(dist, idx.reshape(L, n * d),
                                 axis=1).reshape(L, n, d)
        dn = jnp.where(valid, dn, UNREACH)
        dist = jnp.minimum(dist, jnp.min(dn, axis=2) + 1)
    return dist


def bfs_mode_default() -> str:
    """Band-BFS backend: REPRO_BFS_MODE=jnp|pallas|auto (TPU → Mosaic)."""
    mode = os.environ.get("REPRO_BFS_MODE", "auto")
    if mode == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return mode


#: per-graph VMEM budget for the bfs_multi kernel, which keeps one graph's
#: whole (n, d) ELL tile + distance vector resident per grid step.  Buckets
#: above this fall back to the fused-XLA path (which handles any size).
_BFS_VMEM_BUDGET_BYTES = 4 * 2 ** 20


@dataclasses.dataclass
class BFSWork:
    """One band-distance request (unpadded host arrays)."""
    nbr: np.ndarray                     # (n, d) int32 ELL ids, -1 pad
    src: np.ndarray                     # (n,) bool separator mask
    width: int

    def bucket_key(self) -> Tuple[int, int, int]:
        n, d = self.nbr.shape
        return (pow2(n), pow2(max(d, 1), 8), self.width)


def execute_bfs_works(works: Sequence[BFSWork],
                      mode: Optional[str] = None) -> List[np.ndarray]:
    """Run BFS works, one batched dispatch per (n_pad, d_pad, width) bucket."""
    if mode is None:
        mode = bfs_mode_default()
    results: List[Optional[np.ndarray]] = [None] * len(works)
    groups = defaultdict(list)
    for i, w in enumerate(works):
        groups[w.bucket_key()].append(i)
    for (n_pad, d_pad, width), idxs in groups.items():
        L = len(idxs)
        nbr_b = -np.ones((L, n_pad, d_pad), np.int32)
        src_b = np.zeros((L, n_pad), np.int32)
        for j, i in enumerate(idxs):
            n, d = works[i].nbr.shape
            nbr_b[j, :n, :d] = works[i].nbr
            src_b[j, :n] = works[i].src
        from repro import obs
        from repro.core.dgraph import _note_launch
        tile_bytes = 4 * n_pad * (d_pad + 2)    # ELL tile + dist + src
        use_pallas = (mode == "pallas"
                      and tile_bytes <= _BFS_VMEM_BUDGET_BYTES)

        def dispatch():
            if use_pallas:
                from repro.kernels.ops import band_bfs_batch
                return np.asarray(band_bfs_batch(nbr_b, src_b, width))
            return np.asarray(bfs_distance_multi(
                jnp.asarray(nbr_b), jnp.asarray(src_b), width))

        path = "pallas" if use_pallas else "xla"
        dist = obs.timed_dispatch(
            "bfs", "bfs", ("bfs", path, n_pad, d_pad, width, L),
            dispatch, lanes=L, lanes_pad=L, bucket=(n_pad, d_pad),
            width=width, path=path)
        _note_launch("bfs", 0, L, L, (n_pad, d_pad), width, 0)
        for j, i in enumerate(idxs):
            results[i] = dist[j, :works[i].nbr.shape[0]]
    return results                                           # type: ignore


def band_graph_with_anchors(sub: Graph, band_part: np.ndarray,
                            band_dist: np.ndarray, width: int,
                            w_out0: int, w_out1: int
                            ) -> Tuple[Graph, np.ndarray, np.ndarray]:
    """Attach the two side anchors to an extracted band subgraph.

    ``sub`` is the induced band graph (n_band vertices), ``band_part`` /
    ``band_dist`` its per-vertex part and separator distance, and
    ``w_out0`` / ``w_out1`` the total vertex weight that fell *outside*
    the band on each side.  Appends one anchor per side carrying that
    weight, wired to the last band layer of its side (dist == width), so
    FM cannot move a last-layer vertex across without pulling the whole
    out-of-band weight into the separator (paper §3.3 balance guard).

    Shared by the centralized ``extract_band`` and the distributed
    pipeline's band centralization (``core.dnd``), so both construct
    bit-identical band FM problems.  Returns (band, part_full, locked)
    with the two anchors appended (parts 0/1, locked).
    """
    nb = sub.n
    last = band_dist == width
    last0 = np.nonzero(last & (band_part == 0))[0]
    last1 = np.nonzero(last & (band_part == 1))[0]
    a0, a1 = nb, nb + 1
    extra = []
    if len(last0):
        extra.append(np.stack([np.full(len(last0), a0), last0], 1))
    if len(last1):
        extra.append(np.stack([np.full(len(last1), a1), last1], 1))
    src = np.repeat(np.arange(nb), sub.degrees())
    edges = np.stack([src, sub.adjncy.astype(np.int64)], 1)
    if extra:
        edges = np.concatenate([edges[edges[:, 0] < edges[:, 1]]] + extra)
    else:
        edges = edges[edges[:, 0] < edges[:, 1]]
    vwgt = np.concatenate([sub.vwgt, [max(w_out0, 0), max(w_out1, 0)]])
    ewgt = np.ones(len(edges), dtype=np.int64)
    band = Graph.from_edges(nb + 2, edges, vwgt=vwgt, ewgt=ewgt)
    band_part_full = np.concatenate([band_part, np.int8([0, 1])])
    locked = np.zeros(nb + 2, bool)
    locked[a0:] = True
    return band, band_part_full, locked


def extract_band(g: Graph, part: np.ndarray, width: int = 3,
                 dist: Optional[np.ndarray] = None
                 ) -> Tuple[Graph, np.ndarray, np.ndarray, np.ndarray]:
    """Build the band graph around the separator.

    ``dist`` optionally supplies a precomputed distance sweep (the bucketed
    service path batches it across subproblems); when absent it is computed
    here with the single-graph kernel.

    Returns (band_graph, band_part, locked, old_ids):
      * band_graph has n_band + 2 vertices; the last two are the anchors
        (side 0, side 1), weighted with the out-of-band part weights;
      * band_part / locked are the FM initial state (anchors locked);
      * old_ids maps band vertex -> original vertex (-1 for anchors).
    """
    if dist is None:
        nbr, _ = g.to_ell()
        dist = np.asarray(bfs_distance(jnp.asarray(nbr),
                                       jnp.asarray(part == 2), width))
    dist = np.asarray(dist)[:g.n]
    in_band = dist <= width
    sub, old_ids = g.induced_subgraph(in_band)
    band_part = part[old_ids].astype(np.int8)

    # anchors: out-of-band weight per side, wired to the last layer
    out_mask = ~in_band
    w_out0 = int(g.vwgt[out_mask & (part == 0)].sum())
    w_out1 = int(g.vwgt[out_mask & (part == 1)].sum())
    band, band_part_full, locked = band_graph_with_anchors(
        sub, band_part, dist[old_ids], width, w_out0, w_out1)
    old_full = np.concatenate([old_ids, [-1, -1]])
    return band, band_part_full, locked, old_full


def project_band(part: np.ndarray, band_part: np.ndarray,
                 old_ids: np.ndarray) -> np.ndarray:
    """Write the refined band partition back into the full part vector."""
    out = part.copy()
    real = old_ids >= 0
    out[old_ids[real]] = band_part[real]
    return out
