"""Distributed band-graph extraction (paper §3.3).

Vertices at distance ≤ ``width`` (paper's principled default: 3) from the
projected separator are kept; two *anchor* vertices per side absorb the
remainder, carrying its total vertex weight so balance is preserved, and are
connected to the last band layer of their side.  The distance sweep is the
paper's "spreading distance information from all of the separator vertices,
using our halo exchange routine" — here a vectorized ELL relaxation in JAX
(one halo exchange per width step in the distributed version).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph

UNREACH = np.int32(2 ** 30)


@functools.partial(jax.jit, static_argnames=("width",))
def bfs_distance(nbr: jax.Array, src_mask: jax.Array, width: int) -> jax.Array:
    """dist[v] = min(graph distance to src, width+1), by width relaxations."""
    valid = nbr >= 0
    nbrs = jnp.where(valid, nbr, 0)
    dist = jnp.where(src_mask, 0, UNREACH).astype(jnp.int32)
    for _ in range(width):
        dn = jnp.where(valid, dist[nbrs], UNREACH)
        dist = jnp.minimum(dist, jnp.min(dn, axis=1) + 1)
    return dist


def extract_band(g: Graph, part: np.ndarray, width: int = 3
                 ) -> Tuple[Graph, np.ndarray, np.ndarray, np.ndarray]:
    """Build the band graph around the separator.

    Returns (band_graph, band_part, locked, old_ids):
      * band_graph has n_band + 2 vertices; the last two are the anchors
        (side 0, side 1), weighted with the out-of-band part weights;
      * band_part / locked are the FM initial state (anchors locked);
      * old_ids maps band vertex -> original vertex (-1 for anchors).
    """
    nbr, _ = g.to_ell()
    dist = np.asarray(bfs_distance(jnp.asarray(nbr),
                                   jnp.asarray(part == 2), width))
    in_band = dist <= width
    sub, old_ids = g.induced_subgraph(in_band)
    nb = sub.n
    band_part = part[old_ids].astype(np.int8)

    # anchors: out-of-band weight per side, wired to the last layer
    out_mask = ~in_band
    w_out0 = int(g.vwgt[out_mask & (part == 0)].sum())
    w_out1 = int(g.vwgt[out_mask & (part == 1)].sum())
    last = dist[old_ids] == width
    last0 = np.nonzero(last & (band_part == 0))[0]
    last1 = np.nonzero(last & (band_part == 1))[0]
    a0, a1 = nb, nb + 1
    extra = []
    if len(last0):
        extra.append(np.stack([np.full(len(last0), a0), last0], 1))
    if len(last1):
        extra.append(np.stack([np.full(len(last1), a1), last1], 1))
    src = np.repeat(np.arange(nb), sub.degrees())
    edges = np.stack([src, sub.adjncy.astype(np.int64)], 1)
    if extra:
        edges = np.concatenate([edges[edges[:, 0] < edges[:, 1]]] + extra)
    else:
        edges = edges[edges[:, 0] < edges[:, 1]]
    vwgt = np.concatenate([sub.vwgt, [max(w_out0, 0), max(w_out1, 0)]])
    ewgt = np.ones(len(edges), dtype=np.int64)
    band = Graph.from_edges(nb + 2, edges, vwgt=vwgt, ewgt=ewgt)

    band_part_full = np.concatenate([band_part, np.int8([0, 1])])
    locked = np.zeros(nb + 2, bool)
    locked[a0:] = True
    old_full = np.concatenate([old_ids, [-1, -1]])
    return band, band_part_full, locked, old_full


def project_band(part: np.ndarray, band_part: np.ndarray,
                 old_ids: np.ndarray) -> np.ndarray:
    """Write the refined band partition back into the full part vector."""
    out = part.copy()
    real = old_ids >= 0
    out[old_ids[real]] = band_part[real]
    return out
