"""Host-side graph container mirroring PT-Scotch's centralized graph.

The paper (§2.1) represents graphs by adjacency lists (CSR).  On the host we
keep CSR in numpy; the device data plane uses padded ELL arrays (rectangular
``(n, dmax)`` neighbor / weight tables with ``-1`` fill), because TPUs want
dense rectangular tiles rather than pointer-chased CSR.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Graph:
    """Undirected graph in symmetric CSR form (both arc directions stored).

    Mirrors Scotch's centralized graph: ``xadj`` is ``vertloctab`` /
    ``vendloctab`` fused (contiguous), ``adjncy`` is ``edgeloctab``.
    """

    xadj: np.ndarray      # (n+1,) int64 — CSR row pointers
    adjncy: np.ndarray    # (2m,)  int32 — neighbor vertex ids
    vwgt: np.ndarray      # (n,)   int64 — vertex weights
    adjwgt: np.ndarray    # (2m,)  int64 — edge weights (symmetric)

    # ------------------------------------------------------------------ #
    @property
    def n(self) -> int:
        return len(self.xadj) - 1

    @property
    def nnz(self) -> int:
        """Number of arcs (2m)."""
        return len(self.adjncy)

    @property
    def m(self) -> int:
        return self.nnz // 2

    def degrees(self) -> np.ndarray:
        return np.diff(self.xadj)

    def neighbors(self, v: int) -> np.ndarray:
        return self.adjncy[self.xadj[v]:self.xadj[v + 1]]

    def total_vwgt(self) -> int:
        return int(self.vwgt.sum())

    # ------------------------------------------------------------------ #
    @staticmethod
    def from_edges(n: int, edges: np.ndarray,
                   vwgt: Optional[np.ndarray] = None,
                   ewgt: Optional[np.ndarray] = None) -> "Graph":
        """Build from an (m, 2) array of undirected edges (dedup'd, no loops)."""
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        mask = edges[:, 0] != edges[:, 1]
        edges = edges[mask]
        if ewgt is None:
            ewgt = np.ones(len(edges), dtype=np.int64)
        else:
            ewgt = np.asarray(ewgt, dtype=np.int64)[mask]
        # canonicalize + dedup (accumulating weights of parallel edges)
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        key = lo * n + hi
        order = np.argsort(key, kind="stable")
        key, lo, hi, ewgt = key[order], lo[order], hi[order], ewgt[order]
        if len(key):
            uniq = np.concatenate([[True], key[1:] != key[:-1]])
            seg = np.cumsum(uniq) - 1
            wacc = np.zeros(seg[-1] + 1, dtype=np.int64)
            np.add.at(wacc, seg, ewgt)
            lo, hi, ewgt = lo[uniq], hi[uniq], wacc
        src = np.concatenate([lo, hi])
        dst = np.concatenate([hi, lo])
        w = np.concatenate([ewgt, ewgt])
        order = np.argsort(src * np.int64(n) + dst, kind="stable")
        src, dst, w = src[order], dst[order], w[order]
        xadj = np.zeros(n + 1, dtype=np.int64)
        np.add.at(xadj, src + 1, 1)
        xadj = np.cumsum(xadj)
        if vwgt is None:
            vwgt = np.ones(n, dtype=np.int64)
        return Graph(xadj, dst.astype(np.int32), np.asarray(vwgt, np.int64), w)

    @staticmethod
    def from_dense(a: np.ndarray) -> "Graph":
        """Build from a symmetric boolean/weight adjacency matrix."""
        a = np.asarray(a)
        iu, ju = np.nonzero(np.triu(a, 1))
        return Graph.from_edges(a.shape[0], np.stack([iu, ju], 1),
                                ewgt=a[iu, ju].astype(np.int64))

    # ------------------------------------------------------------------ #
    def check(self) -> None:
        """Structural invariants (symmetry, no self loops, sorted ptrs)."""
        assert self.xadj[0] == 0 and self.xadj[-1] == len(self.adjncy)
        assert np.all(np.diff(self.xadj) >= 0)
        n = self.n
        assert np.all(self.adjncy >= 0) and np.all(self.adjncy < n)
        src = np.repeat(np.arange(n, dtype=np.int64), self.degrees())
        assert not np.any(src == self.adjncy), "self loop"
        # symmetry (pattern + weights)
        fwd = src * n + self.adjncy
        bwd = self.adjncy.astype(np.int64) * n + src
        of, ob = np.argsort(fwd, kind="stable"), np.argsort(bwd, kind="stable")
        assert np.array_equal(fwd[of], bwd[ob]), "asymmetric pattern"
        assert np.array_equal(self.adjwgt[of], self.adjwgt[ob]), "asymmetric weights"

    # ------------------------------------------------------------------ #
    def induced_subgraph(self, keep: np.ndarray) -> Tuple["Graph", np.ndarray]:
        """Subgraph induced by boolean mask ``keep``.

        Returns (subgraph, old_ids) where ``old_ids[new] = old``.  This is the
        distributed induced-subgraph routine of §3.1, centralized: vertex
        labels of selected vertices are "spread" (here: a renumbering table)
        and adjacency rows filtered.
        """
        keep = np.asarray(keep, dtype=bool)
        old_ids = np.nonzero(keep)[0]
        newid = -np.ones(self.n, dtype=np.int64)
        newid[old_ids] = np.arange(len(old_ids))
        deg = self.degrees()
        src = np.repeat(np.arange(self.n, dtype=np.int64), deg)
        emask = keep[src] & keep[self.adjncy]
        s, d, w = newid[src[emask]], newid[self.adjncy[emask]], self.adjwgt[emask]
        nn = len(old_ids)
        order = np.argsort(s * max(nn, 1) + d, kind="stable")
        s, d, w = s[order], d[order], w[order]
        xadj = np.zeros(nn + 1, dtype=np.int64)
        np.add.at(xadj, s + 1, 1)
        xadj = np.cumsum(xadj)
        return (Graph(xadj, d.astype(np.int32), self.vwgt[old_ids].copy(), w),
                old_ids)

    # ------------------------------------------------------------------ #
    def to_ell(self, dmax: Optional[int] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Padded ELL arrays ``(nbr, wgt)`` of shape (n, dmax); -1/0 fill."""
        deg = self.degrees()
        if dmax is None:
            dmax = int(deg.max()) if self.n else 1
        dmax = max(int(dmax), 1)
        nbr = -np.ones((self.n, dmax), dtype=np.int32)
        wgt = np.zeros((self.n, dmax), dtype=np.int32)
        src = np.repeat(np.arange(self.n, dtype=np.int64), deg)
        col = (np.arange(len(self.adjncy)) - self.xadj[src])
        ok = col < dmax  # truncate ultra-high-degree rows only if dmax forced
        nbr[src[ok], col[ok]] = self.adjncy[ok]
        wgt[src[ok], col[ok]] = self.adjwgt[ok]
        return nbr, wgt

    # ------------------------------------------------------------------ #
    def components(self) -> np.ndarray:
        """Connected component id per vertex (BFS, vectorized frontier)."""
        comp = -np.ones(self.n, dtype=np.int64)
        cur = 0
        for s in range(self.n):
            if comp[s] >= 0:
                continue
            comp[s] = cur
            frontier = np.array([s], dtype=np.int64)
            while len(frontier):
                nxt = []
                for v in frontier:
                    nbrs = self.neighbors(v)
                    new = nbrs[comp[nbrs] < 0]
                    comp[new] = cur
                    nxt.append(new)
                frontier = np.unique(np.concatenate(nxt)) if nxt else \
                    np.empty(0, dtype=np.int64)
            cur += 1
        return comp
