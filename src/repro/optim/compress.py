"""Gradient compression for the slow (cross-pod) all-reduce axis.

int8 quantization with error feedback (1-bit-Adam-style residual carry):
the pod-local all-reduce runs in bf16 (fast ICI), and only the inter-pod
reduction — the 10×-slower DCN/optical hop — moves int8, a 2× wire saving
vs bf16 with bias corrected over steps by the residual state.

``compressed_psum`` is written for use inside ``shard_map`` bodies; the
codec itself is pure and unit-tested on CPU.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(grads: PyTree, residual: PyTree
                ) -> Tuple[PyTree, PyTree, PyTree]:
    """Error-feedback compress: returns (q, scales, new_residual)."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return q, s, g32 - deq
    tm = jax.tree_util.tree_map
    qs = tm(lambda g, r: one(g, r)[0], grads, residual)
    ss = tm(lambda g, r: one(g, r)[1], grads, residual)
    rs = tm(lambda g, r: one(g, r)[2], grads, residual)
    return qs, ss, rs


def ef_init(grads_like: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-on-the-wire psum for use inside shard_map: quantize locally,
    integer-sum across the axis (int32 accumulate), rescale by the max
    scale (conservative shared-scale variant)."""
    q, s = quantize_int8(x.astype(jnp.float32))
    s_max = jax.lax.pmax(s, axis_name)
    # requantize against the shared scale so integer sums are consistent
    q2 = jnp.clip(jnp.round(x.astype(jnp.float32) / s_max), -127,
                  127).astype(jnp.int32)
    total = jax.lax.psum(q2, axis_name)
    return total.astype(jnp.float32) * s_max
