"""AdamW with fp32 master weights and ZeRO-1 partitioned state.

Params live in bf16 (compute dtype); the optimizer holds fp32 master
weights + first/second moments, all sharded per ``zero1_specs`` (param spec
upgraded with a data-axis shard on the largest replicated dim).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class OptState(NamedTuple):
    master: PyTree       # fp32 copy of params
    m: PyTree            # fp32
    v: PyTree            # fp32
    count: jax.Array     # ()


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100


def init(params: PyTree) -> OptState:
    f32 = lambda t: jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32), t)
    zeros = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)
    return OptState(master=f32(params), m=zeros,
                    v=jax.tree_util.tree_map(jnp.copy, zeros),
                    count=jnp.zeros((), jnp.int32))


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(grads: PyTree, state: OptState, params: PyTree,
           cfg: AdamWConfig) -> Tuple[PyTree, OptState, jax.Array]:
    """Returns (new params [original dtypes], new state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    count = state.count + 1
    lr = _schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)
    tm = jax.tree_util.tree_map
    gs = tm(lambda g: g.astype(jnp.float32) * scale, grads)
    m = tm(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g, state.m, gs)
    v = tm(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g, state.v, gs)
    master = tm(
        lambda p, m_, v_: p - lr * ((m_ / b1c) / (jnp.sqrt(v_ / b2c)
                                                  + cfg.eps)
                                    + cfg.weight_decay * p),
        state.master, m, v)
    new_params = tm(lambda mp, old: mp.astype(old.dtype), master, params)
    return new_params, OptState(master, m, v, count), gnorm