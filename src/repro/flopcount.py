"""Exact analytic FLOP counter per (arch × shape) cell.

XLA's ``cost_analysis`` counts while-loop bodies once; the SPMD partitioner
introduces windowed-einsum loops (collective matmuls) that make HLO FLOPs
under-report for sharded programs (verified: a 2-layer arctic lowers to
fewer counted FLOPs than 1-layer).  The model math here is ours, so the
compute-roofline term uses this exact counter; HLO numbers are reported
alongside (EXPERIMENTS.md §Roofline methodology).

Counts are *global* (all chips) multiply-add×2 FLOPs.
"""
from __future__ import annotations

from repro.configs.base import SHAPES, ArchConfig


def _attn_layer(cfg: ArchConfig, T: float, kv_len: float) -> float:
    d, hd, H, Hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    proj = 2 * T * d * (H * hd + 2 * Hkv * hd + H * hd)
    quad = 2 * T * kv_len * H * hd * 2           # scores + PV
    return proj + quad


def _mla_layer(cfg: ArchConfig, T: float, kv_len: float) -> float:
    d, hd, H = cfg.d_model, cfg.hd, cfg.n_heads
    r, c = cfg.rope_head_dim, cfg.kv_lora
    proj = 2 * T * d * (H * (hd + r) + c + r)
    expand = 2 * T * c * H * hd * 2              # k/v up-projections
    out = 2 * T * H * hd * d
    quad = 2 * T * kv_len * H * ((hd + r) + hd)
    return proj + expand + out + quad


def _ssm_layer(cfg: ArchConfig, T: float, chunk: int = 256) -> float:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    P = cfg.ssm_headdim
    H = inner // P
    N = cfg.ssm_state
    proj = 2 * T * d * (2 * inner + 2 * N + H) + 2 * T * inner * d
    conv = 2 * T * cfg.ssm_conv * (inner + 2 * N)
    L = min(chunk, int(T) or 1)
    intra = 2 * T * L * (N + H * P)              # CBᵀ + masked-matmul
    states = 2 * T * N * H * P * 2               # build + apply states
    return proj + conv + intra + states


def _ffn_layer(cfg: ArchConfig, T: float, kind: str) -> float:
    d = cfg.d_model
    total = 0.0
    if kind in ("dense", "moe+dense"):
        total += 2 * T * 3 * d * cfg.d_ff
    if kind in ("moe", "moe+dense"):
        E, K, f = cfg.n_experts, cfg.top_k, cfg.moe_d_ff
        C = max(8, int(T * K / E * cfg.capacity_factor))
        total += 2 * T * d * E                   # router
        total += 2 * E * C * 3 * d * f           # expert swiglu at capacity
        if cfg.n_shared_experts:
            total += 2 * T * 3 * d * f * cfg.n_shared_experts
    return total


def forward_flops(cfg: ArchConfig, T: float, kv_len: float) -> float:
    """One forward pass over T tokens with average attention span kv_len."""
    total = 2 * T * cfg.d_model * cfg.vocab      # unembed
    for mixer, ffn in zip(cfg.layer_kinds(), cfg.layer_ffn()):
        if mixer == "ssm":
            total += _ssm_layer(cfg, T)
        elif cfg.mla:
            total += _mla_layer(cfg, T, kv_len)
        else:
            total += _attn_layer(cfg, T, kv_len)
        kind = ffn
        if mixer == "ssm" and not cfg.moe and cfg.d_ff == 0:
            kind = "none"
        elif ffn == "moe" and cfg.dense_residual:
            kind = "moe+dense"
        if kind != "none":
            total += _ffn_layer(cfg, T, kind)
    if cfg.enc_dec:
        Te = cfg.enc_len * (T / max(SHAPES["train_4k"]["seq_len"], 1))
        # encoder layers + decoder cross-attention (approx: dense attn)
        total += cfg.n_enc_layers * (_attn_layer(cfg, Te, cfg.enc_len)
                                     + _ffn_layer(cfg, Te, "dense"))
        total += cfg.n_layers * 2 * T * cfg.d_model * cfg.n_heads * cfg.hd
    return total


def cell_flops(cfg: ArchConfig, shape_name: str,
               remat: str = "full") -> float:
    """FLOPs of what the implementation executes.

    Note the attention quadratic uses kv_len = S (the query-chunked kernel
    computes full (Cq, S) rectangles and masks — causal-block skipping is a
    known 2×-on-attention optimization, tracked in §Perf ideas), so this is
    the implementation's count, not the idealized causal S/2.
    """
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    if sh["kind"] == "train":
        fwd = forward_flops(cfg, B * S, S)
        factor = 4.0 if remat == "full" else 10.0 / 3.0
        return fwd * factor
    if sh["kind"] == "prefill":
        return forward_flops(cfg, B * S, S)
    # decode: one token per sequence against a cache of length S
    return forward_flops(cfg, B, S)
