"""Serving engine: prefill (cache-building) and batched decode steps.

Prefill mirrors the training forward but captures per-layer KV/state caches
through the layer-group scans; decode threads the caches through
``lm.decode_step``.  Both are pjit-able; cache shardings come from
``sharding.cache_specs`` (heads on TP when divisible, else cache sequence —
the MQA long-context case).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import lm as lm_mod
from repro.models.lm import (_block_apply, decode_step, group_descs,
                             layer_descs)
from repro.models.sharding import NO_SHARD, ShardCfg

PyTree = Any


def _prefill_block(p, x, desc, cfg, shard, enc_out, pad_to):
    """Block apply that also returns its cache (padded to pad_to)."""
    mixer, ffn = desc
    cache: Dict[str, jax.Array] = {}
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    B, S, _ = x.shape

    def pad(a, axis=1):
        if pad_to is None or a.shape[axis] == pad_to:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, pad_to - a.shape[axis])
        return jnp.pad(a, widths)

    if mixer == "attn":
        h, (k, v) = L.attn_apply(p["attn"], h, cfg, causal=True,
                                 return_kv=True)
        cache["k"], cache["v"] = pad(k), pad(v)
    elif mixer == "mla":
        ckv = h @ p["attn"]["wdkv"]
        kr = (h @ p["attn"]["wkr"]).reshape(B, S, 1, cfg.rope_head_dim)
        pos = jnp.arange(S)
        cos, sin = L.rope_tables(pos, cfg.rope_head_dim, cfg.rope_theta)
        cache["c"] = pad(ckv)
        cache["kr"] = pad(L.apply_rope(kr, cos, sin)[:, :, 0])
        h = L.mla_apply(p["attn"], h, cfg)
    else:
        h, (state, conv_tail) = M.mamba_apply(p["ssm"], h, cfg,
                                              return_state=True)
        cache["state"], cache["conv"] = state, conv_tail
    x = x + h
    if "xattn" in p:
        hq = L.rmsnorm(p["normx"], x, cfg.norm_eps)
        x = x + L.cross_attn_apply(p["xattn"], hq, enc_out, cfg)
        cache["xk"] = enc_out @ p["xattn"]["wk"]
        cache["xv"] = enc_out @ p["xattn"]["wv"]
    if ffn != "none":
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        add = jnp.zeros_like(x)
        if "moe" in p:
            mo, _ = L.moe_apply(p["moe"], h, cfg)
            add = add + mo
        if "mlp" in p:
            add = add + L.swiglu_apply(p["mlp"], h)
        x = x + add
    return shard.act_residual(x), cache


def prefill(params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            shard: ShardCfg = NO_SHARD, pad_to: int | None = None
            ) -> Tuple[jax.Array, PyTree]:
    """Full-sequence prefill.  Returns (logits, caches)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(L.PDT)
    if cfg.frontend == "patches" and "patches" in batch:
        proj = batch["patches"].astype(L.PDT) @ params["patch_proj"]
        x = jax.lax.dynamic_update_slice(
            x, proj[:, :min(cfg.n_patches, x.shape[1])], (0, 0, 0))
    x = shard.act_residual(x)
    enc_out = None
    if cfg.enc_dec:
        e = batch["frames"].astype(L.PDT)

        e = lm_mod._run_encoder(params, cfg, e, shard)
        enc_out = L.rmsnorm(params["enc_norm"], e, cfg.norm_eps)
    groups = group_descs(layer_descs(cfg))
    caches = []
    for (count, block), gp in zip(groups, params["groups"]):
        def super_block(xx, bp):
            cc = {}
            for i, desc in enumerate(block):
                xx, cc[f"p{i}"] = _prefill_block(bp[f"p{i}"], xx, desc, cfg,
                                                 shard, enc_out, pad_to)
            return xx, cc
        if count == 1:
            x, cc = super_block(x, gp)
        elif lm_mod.FORCE_UNROLL:
            ccs = []
            for i in range(count):
                x, cci = jax.checkpoint(super_block)(
                    x, jax.tree_util.tree_map(lambda a: a[i], gp))
                ccs.append(cci)
            cc = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ccs)
        else:
            @jax.checkpoint
            def scan_body(xx, bp):
                return super_block(xx, bp)
            x, cc = jax.lax.scan(scan_body, x, gp)
        caches.append(cc)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = x @ params["unembed"]
    return shard.act_logits(logits), caches


def make_decode_step(cfg: ArchConfig, shard: ShardCfg = NO_SHARD):
    def step(params, token, caches, pos):
        return decode_step(params, cfg, token, caches, pos, shard)
    return step


def greedy_generate(params, cfg: ArchConfig, prompt: jax.Array,
                    n_new: int, s_max: int) -> jax.Array:
    """Simple batched greedy decoding loop (CPU example driver)."""
    from repro.models.lm import init_caches
    B, S0 = prompt.shape
    logits, caches = prefill(params, cfg, {"tokens": prompt},
                             pad_to=s_max)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(prompt.dtype)
    out = [tok]
    step = jax.jit(make_decode_step(cfg))
    for t in range(n_new - 1):
        logits, caches = step(params, tok, caches, jnp.int32(S0 + t))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(prompt.dtype)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
