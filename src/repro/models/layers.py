"""Model layer zoo: RMSNorm, RoPE, GQA/MLA attention, SwiGLU, MoE.

Conventions:
  * parameters are nested dicts of jnp arrays (bf16), built by ``*_init``;
  * ``*_apply`` are pure functions; full-sequence (train/prefill) and
    single-token (decode, with KV cache) paths are separate functions;
  * attention is query-chunked (flash-style memory bound: the (B,H,Cq,S)
    score block is the only quadratic temp, recomputed under remat);
  * MoE uses sort-based dispatch to static-capacity expert batches
    (TPU-friendly static shapes; all-to-all inserted by SPMD partitioner).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

PyTree = Any
PDT = jnp.bfloat16           # parameter/compute dtype

#: perf knob (§Perf B): when set (a NamedSharding for (E, C, d)), the MoE
#: dispatch/combine tensors are constrained to it — sharding capacity over
#: the data axes turns the token gather into an all-to-all instead of a
#: full activation all-gather.  Configured by launch/dryrun.lower_cell_cfg.
MOE_SHARD_DISPATCH = False
MOE_DISPATCH_SPEC = None


def _dense(key, shape, scale=None):
    scale = scale or (1.0 / jnp.sqrt(shape[0]))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(PDT)


# ------------------------------------------------------------------ #
# norms / rope
# ------------------------------------------------------------------ #
def rmsnorm_init(d: int) -> PyTree:
    return {"scale": jnp.ones((d,), PDT)}


def rmsnorm(p: PyTree, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["scale"]


def rope_tables(positions: jax.Array, hd: int, theta: float
                ) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin tables (..., hd/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, hd); cos/sin (..., S, hd/2) broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c, s = cos[..., None, :], sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


# ------------------------------------------------------------------ #
# chunked softmax attention core
# ------------------------------------------------------------------ #
def _attend(q, k, v, *, causal: bool, q_pos0=0, kv_len: Optional[jax.Array]
            = None, q_chunk: int = 1024) -> jax.Array:
    """q (B,Sq,H,hd), k/v (B,Sk,Hkv,hd) -> (B,Sq,H,hd).

    Query-chunked; group-broadcast for GQA; f32 softmax.  ``kv_len`` masks a
    cache filled only up to that length (decode).
    """
    B, Sq, H, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    dv = v.shape[-1]
    g = H // Hkv
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    kpos = jnp.arange(Sk)

    def one_chunk(qc, qc_pos):
        # qc (B,Cq,H,hd) -> scores (B,Hkv,g,Cq,Sk) in f32
        qg = qc.reshape(B, qc.shape[1], Hkv, g, hd)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        mask = jnp.ones((qc.shape[1], Sk), bool)
        if causal:
            mask &= qc_pos[:, None] >= kpos[None, :]
        if kv_len is not None:
            mask &= kpos[None, :] < kv_len
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
        return o.reshape(B, qc.shape[1], H, dv).astype(q.dtype)

    if Sq <= q_chunk:
        return one_chunk(q, q_pos0 + jnp.arange(Sq))

    pad = (-Sq) % q_chunk                 # ragged tail (e.g. enc_len 1500)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (Sq + pad) // q_chunk
    # python-unrolled chunk loop (not lax.scan): keeps XLA cost_analysis
    # honest (scan bodies are counted once) and lets the scheduler overlap
    # chunks; each chunk is remat'd so backward memory stays one chunk.
    chunk_fn = jax.checkpoint(one_chunk)
    outs = []
    for i in range(n_chunks):
        qc = jax.lax.slice_in_dim(q, i * q_chunk, (i + 1) * q_chunk, axis=1)
        outs.append(chunk_fn(qc, q_pos0 + i * q_chunk + jnp.arange(q_chunk)))
    return jnp.concatenate(outs, axis=1)[:, :Sq]


# ------------------------------------------------------------------ #
# GQA attention
# ------------------------------------------------------------------ #
def attn_init(key, cfg: ArchConfig) -> PyTree:
    d, hd, H, Hkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense(ks[0], (d, H * hd)),
        "wk": _dense(ks[1], (d, Hkv * hd)),
        "wv": _dense(ks[2], (d, Hkv * hd)),
        "wo": _dense(ks[3], (H * hd, d)),
    }


def attn_qkv(p, x, cfg: ArchConfig, pos0=0):
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, S, Hkv, hd)
    pos = pos0 + jnp.arange(S)
    cos, sin = rope_tables(pos, hd, cfg.rope_theta)
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin), v


def attn_apply(p, x, cfg: ArchConfig, *, causal=True, return_kv=False):
    """Full-sequence attention (train / prefill)."""
    q, k, v = attn_qkv(p, x, cfg)
    o = _attend(q, k, v, causal=causal)
    y = o.reshape(*x.shape[:2], -1) @ p["wo"]
    return (y, (k, v)) if return_kv else y


def attn_decode(p, x, cache_k, cache_v, pos, cfg: ArchConfig):
    """One-token decode. cache_{k,v}: (B, S_max, Hkv, hd); pos (,) int."""
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    k = (x @ p["wk"]).reshape(B, 1, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, 1, Hkv, hd)
    cos, sin = rope_tables(pos[None], hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, pos, axis=1)
    o = _attend(q, cache_k, cache_v, causal=False, kv_len=pos + 1)
    y = o.reshape(B, 1, H * hd) @ p["wo"]
    return y, cache_k, cache_v


def cross_attn_apply(p, x, kv_src, cfg: ArchConfig):
    """Encoder–decoder cross attention (no cache update, no causal mask)."""
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (kv_src @ p["wk"]).reshape(B, kv_src.shape[1], Hkv, hd)
    v = (kv_src @ p["wv"]).reshape(B, kv_src.shape[1], Hkv, hd)
    o = _attend(q, k, v, causal=False)
    return o.reshape(B, S, H * hd) @ p["wo"]


# ------------------------------------------------------------------ #
# MLA (DeepSeek-V2 multi-head latent attention)
# ------------------------------------------------------------------ #
def mla_init(key, cfg: ArchConfig) -> PyTree:
    d, hd, H = cfg.d_model, cfg.hd, cfg.n_heads
    r, c = cfg.rope_head_dim, cfg.kv_lora
    ks = jax.random.split(key, 6)
    return {
        "wq": _dense(ks[0], (d, H * (hd + r))),      # q: nope + rope parts
        "wdkv": _dense(ks[1], (d, c)),               # down-proj (cached)
        "wkr": _dense(ks[2], (d, r)),                # shared rope key
        "wuk": _dense(ks[3], (c, H * hd)),           # up-proj keys
        "wuv": _dense(ks[4], (c, H * hd)),           # up-proj values
        "wo": _dense(ks[5], (H * hd, d)),
    }


def mla_apply(p, x, cfg: ArchConfig):
    """Full-sequence MLA (train/prefill): expand latents to per-head k/v."""
    B, S, d = x.shape
    H, hd, r, c = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.kv_lora
    q = (x @ p["wq"]).reshape(B, S, H, hd + r)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    ckv = x @ p["wdkv"]                              # (B,S,c) latent
    k_rope = (x @ p["wkr"]).reshape(B, S, 1, r)
    pos = jnp.arange(S)
    cos, sin = rope_tables(pos, r, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    k_nope = (ckv @ p["wuk"]).reshape(B, S, H, hd)
    v = (ckv @ p["wuv"]).reshape(B, S, H, hd)
    qf = jnp.concatenate([q_nope, q_rope], -1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, r))], -1)
    o = _attend(qf, kf, v, causal=True)
    return o.reshape(B, S, H * hd) @ p["wo"]


def mla_decode(p, x, cache_c, cache_kr, pos, cfg: ArchConfig):
    """One-token MLA decode with weight absorption: the cache holds only the
    latent (c) and the shared rope key (r) — the paper-configured memory
    saving (512+64 vs 2·H·hd per token)."""
    B = x.shape[0]
    H, hd, r, c = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.kv_lora
    q = (x @ p["wq"]).reshape(B, 1, H, hd + r)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    cos, sin = rope_tables(pos[None], r, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    ckv_t = x @ p["wdkv"]                            # (B,1,c)
    kr_t = (x @ p["wkr"]).reshape(B, 1, 1, r)
    kr_t = apply_rope(kr_t, cos, sin)
    cache_c = jax.lax.dynamic_update_slice_in_dim(cache_c, ckv_t, pos, axis=1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(
        cache_kr, kr_t[:, :, 0], pos, axis=1)
    # absorb wuk into q: q_c (B,1,H,c)
    wuk = p["wuk"].reshape(c, H, hd)
    q_c = jnp.einsum("bqhd,chd->bqhc", q_nope.astype(jnp.float32),
                     wuk.astype(jnp.float32))
    s = jnp.einsum("bqhc,bsc->bhqs", q_c, cache_c.astype(jnp.float32))
    s += jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                    cache_kr.astype(jnp.float32))
    s *= 1.0 / jnp.sqrt(hd + r).astype(jnp.float32)
    mask = jnp.arange(cache_c.shape[1])[None, None, None, :] < pos + 1
    s = jnp.where(mask, s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhqs,bsc->bqhc", pr, cache_c.astype(jnp.float32))
    wuv = p["wuv"].reshape(c, H, hd)
    o = jnp.einsum("bqhc,chd->bqhd", o_c, wuv.astype(jnp.float32))
    y = o.reshape(B, 1, H * hd).astype(x.dtype) @ p["wo"]
    return y, cache_c, cache_kr


# ------------------------------------------------------------------ #
# FFN: SwiGLU + MoE
# ------------------------------------------------------------------ #
def swiglu_init(key, d: int, f: int) -> PyTree:
    ks = jax.random.split(key, 3)
    return {"w1": _dense(ks[0], (d, f)), "w3": _dense(ks[1], (d, f)),
            "w2": _dense(ks[2], (f, d))}


def swiglu_apply(p, x):
    return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]


def moe_init(key, cfg: ArchConfig) -> PyTree:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense(ks[0], (d, E), scale=0.02),
        "w1": _dense(ks[1], (E, d, f)),
        "w3": _dense(ks[2], (E, d, f)),
        "w2": _dense(ks[3], (E, f, d)),
    }
    if cfg.n_shared_experts:
        p["shared"] = swiglu_init(ks[4], d, f * cfg.n_shared_experts)
    return p


def moe_apply(p, x, cfg: ArchConfig):
    """Sort-based static-capacity MoE.  x (B,S,d) -> (B,S,d).

    Tokens are flattened, routed top-k, sorted by expert, truncated at
    capacity C = T·k/E·cf, processed as (E, C, d) einsums against stacked
    expert weights (EP-shardable on the model axis), and combined back by
    weighted scatter-add.  Aux load-balancing loss returned as second out.
    """
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    C = max(8, int(T * K / E * cfg.capacity_factor))
    xt = x.reshape(T, d)
    logits = (xt @ p["router"]).astype(jnp.float32)          # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                      # (T,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    flat_e = idx.reshape(-1).astype(jnp.int32)               # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    pos = jnp.arange(T * K, dtype=jnp.int32) - \
        jnp.searchsorted(se, se, side="left").astype(jnp.int32)
    keep = pos < C
    slot = jnp.where(keep, pos, C)
    disp = jnp.full((E, C + 1), T, jnp.int32)
    disp = disp.at[se, slot].set(jnp.where(keep, st, T))[:, :C]
    gsc = jnp.zeros((E, C + 1), jnp.float32)
    gsc = gsc.at[se, slot].set(jnp.where(keep, sg, 0.0))[:, :C]
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], 0)
    xe = xt_pad[disp]                                        # (E,C,d)
    if MOE_SHARD_DISPATCH and MOE_DISPATCH_SPEC is not None:
        xe = jax.lax.with_sharding_constraint(xe, MOE_DISPATCH_SPEC)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w1"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["w3"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w2"])              # (E,C,d)
    if MOE_SHARD_DISPATCH and MOE_DISPATCH_SPEC is not None:
        ye = jax.lax.with_sharding_constraint(ye, MOE_DISPATCH_SPEC)
    y = jnp.zeros((T + 1, d), jnp.float32)
    y = y.at[disp.reshape(-1)].add(
        (ye.astype(jnp.float32) * gsc[..., None]).reshape(-1, d))[:T]
    y = y.astype(x.dtype).reshape(B, S, d)
    if cfg.n_shared_experts:
        y = y + swiglu_apply(p["shared"], x)
    # GShard aux loss: E * Σ_e (token-frac_e · prob-frac_e)
    frac_tokens = jnp.mean((jax.nn.one_hot(idx, E)).sum(1), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) / K
    return y, aux
