"""Unified causal LM over per-layer patterns, with enc-dec support.

One model class covers all 10 assigned architectures:
  * per-layer descriptors (mixer ∈ {attn, mla, ssm}, ffn ∈ {dense, moe,
    moe+dense, none}) derived from the ArchConfig;
  * homogeneous runs of layers are stacked and executed with
    ``lax.scan`` over a (possibly multi-layer) super-block, wrapped in
    ``jax.checkpoint`` (remat) — compile-time and activation memory stay
    bounded for 88-layer models;
  * decode threads per-layer caches through the same scan structure.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.sharding import NO_SHARD, ShardCfg

PyTree = Any

#: when True, layer groups run as python loops instead of lax.scan.
#: Used (a) by the dry-run cost extrapolation (XLA cost_analysis counts
#: scan bodies once) and (b) as a scan-vs-unroll perf ablation knob.
FORCE_UNROLL = False

#: remat policy for the per-layer checkpoint: "full" recomputes everything
#: (min memory, max recompute flops); "dots" saves matmul outputs
#: (≈1/3 less recompute for ~2× activation memory).  Perf-iteration knob.
REMAT_POLICY = "full"


def _remat(fn):
    if REMAT_POLICY == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _take(tree: PyTree, i: int) -> PyTree:
    return jax.tree_util.tree_map(lambda a: a[i], tree)


# ------------------------------------------------------------------ #
# layer descriptors and grouping
# ------------------------------------------------------------------ #
def layer_descs(cfg: ArchConfig) -> List[Tuple[str, str]]:
    descs = []
    for kind, ffn in zip(cfg.layer_kinds(), cfg.layer_ffn()):
        mixer = "ssm" if kind == "ssm" else ("mla" if cfg.mla else "attn")
        if kind == "ssm" and not cfg.moe and cfg.d_ff == 0:
            ffn = "none"                       # pure mamba block
        elif ffn == "moe" and cfg.dense_residual:
            ffn = "moe+dense"
        descs.append((mixer, ffn))
    return descs


def group_descs(descs: List[Tuple[str, str]]
                ) -> List[Tuple[int, List[Tuple[str, str]]]]:
    """-> [(repeat_count, super_block_descs), ...] with minimal period."""
    groups = []
    rest = list(descs)
    while rest:
        found = None
        for p in range(1, len(rest) + 1):
            if len(rest) % p == 0 and rest == rest[:p] * (len(rest) // p):
                found = p
                break
        if found is not None and len(rest) // found > 1:
            groups.append((len(rest) // found, rest[:found]))
            rest = []
        else:
            groups.append((1, rest[:1]))       # peel non-repeating head
            rest = rest[1:]
    # merge trailing singleton pattern case: single group of count 1
    return groups


# ------------------------------------------------------------------ #
# per-layer init / apply
# ------------------------------------------------------------------ #
def _block_init(key, desc: Tuple[str, str], cfg: ArchConfig,
                cross: bool = False) -> PyTree:
    mixer, ffn = desc
    ks = jax.random.split(key, 6)
    p: Dict[str, PyTree] = {"norm1": L.rmsnorm_init(cfg.d_model)}
    if mixer == "attn":
        p["attn"] = L.attn_init(ks[0], cfg)
    elif mixer == "mla":
        p["attn"] = L.mla_init(ks[0], cfg)
    else:
        p["ssm"] = M.mamba_init(ks[0], cfg)
    if cross:
        p["normx"] = L.rmsnorm_init(cfg.d_model)
        p["xattn"] = L.attn_init(ks[2], cfg)
    if ffn != "none":
        p["norm2"] = L.rmsnorm_init(cfg.d_model)
    if ffn in ("moe", "moe+dense"):
        p["moe"] = L.moe_init(ks[1], cfg)
    if ffn in ("dense", "moe+dense"):
        p["mlp"] = L.swiglu_init(ks[3], cfg.d_model,
                                 cfg.d_ff if ffn != "moe" else cfg.d_ff)
    return p


def _block_apply(p, x, desc, cfg: ArchConfig, shard: ShardCfg,
                 enc_out=None, causal=True):
    """Full-sequence block.  Returns (x, aux_loss)."""
    mixer, ffn = desc
    aux = jnp.float32(0.0)
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if mixer == "attn":
        h = L.attn_apply(p["attn"], h, cfg, causal=causal)
    elif mixer == "mla":
        h = L.mla_apply(p["attn"], h, cfg)
    else:
        h = M.mamba_apply(p["ssm"], h, cfg)
    x = x + h
    if "xattn" in p:
        h = L.rmsnorm(p["normx"], x, cfg.norm_eps)
        x = x + L.cross_attn_apply(p["xattn"], h, enc_out, cfg)
    if ffn != "none":
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        add = jnp.zeros_like(x)
        if "moe" in p:
            mo, a = L.moe_apply(p["moe"], h, cfg)
            add, aux = add + mo, aux + a
        if "mlp" in p:
            add = add + L.swiglu_apply(p["mlp"], h)
        x = x + add
    return shard.act_residual(x), aux


def _block_cache_init(desc, cfg: ArchConfig, B: int, S_max: int,
                      cross: bool = False) -> PyTree:
    mixer, _ = desc
    c: Dict[str, jax.Array] = {}
    if mixer == "attn":
        c["k"] = jnp.zeros((B, S_max, cfg.n_kv_heads, cfg.hd), L.PDT)
        c["v"] = jnp.zeros((B, S_max, cfg.n_kv_heads, cfg.hd), L.PDT)
    elif mixer == "mla":
        c["c"] = jnp.zeros((B, S_max, cfg.kv_lora), L.PDT)
        c["kr"] = jnp.zeros((B, S_max, cfg.rope_head_dim), L.PDT)
    else:
        inner, H, P_, N = M.ssm_dims(cfg)
        c["state"] = jnp.zeros((B, H, N, P_), jnp.float32)
        c["conv"] = jnp.zeros((B, cfg.ssm_conv - 1, inner + 2 * N), L.PDT)
    if cross:
        c["xk"] = jnp.zeros((B, cfg.enc_len, cfg.n_kv_heads * cfg.hd), L.PDT)
        c["xv"] = jnp.zeros((B, cfg.enc_len, cfg.n_kv_heads * cfg.hd), L.PDT)
    return c


def _block_decode(p, x, cache, pos, desc, cfg: ArchConfig):
    mixer, ffn = desc
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if mixer == "attn":
        h, k, v = L.attn_decode(p["attn"], h, cache["k"], cache["v"], pos, cfg)
        cache = dict(cache, k=k, v=v)
    elif mixer == "mla":
        h, c, kr = L.mla_decode(p["attn"], h, cache["c"], cache["kr"], pos, cfg)
        cache = dict(cache, c=c, kr=kr)
    else:
        h, st, cv = M.mamba_decode(p["ssm"], h, cache["state"],
                                   cache["conv"], cfg)
        cache = dict(cache, state=st, conv=cv)
    x = x + h
    if "xattn" in p:                           # cross-attn from cached enc KV
        hq = L.rmsnorm(p["normx"], x, cfg.norm_eps)
        B = x.shape[0]
        H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        q = (hq @ p["xattn"]["wq"]).reshape(B, 1, H, hd)
        k = cache["xk"].reshape(B, -1, Hkv, hd)
        v = cache["xv"].reshape(B, -1, Hkv, hd)
        o = L._attend(q, k, v, causal=False)
        x = x + o.reshape(B, 1, H * hd) @ p["xattn"]["wo"]
    if ffn != "none":
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        add = jnp.zeros_like(x)
        if "moe" in p:
            mo, _ = L.moe_apply(p["moe"], h, cfg)
            add = add + mo
        if "mlp" in p:
            add = add + L.swiglu_apply(p["mlp"], h)
        x = x + add
    return x, cache


# ------------------------------------------------------------------ #
# model init
# ------------------------------------------------------------------ #
def init_params(key, cfg: ArchConfig) -> PyTree:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    params: Dict[str, PyTree] = {
        "embed": L._dense(ks[0], (cfg.vocab, d), scale=0.02),
        "final_norm": L.rmsnorm_init(d),
        "unembed": L._dense(ks[1], (d, cfg.vocab)),
    }
    groups = group_descs(layer_descs(cfg))
    cross = cfg.enc_dec
    gparams = []
    gkey = ks[2]
    for count, block in groups:
        gkey, sub = jax.random.split(gkey)

        def one(k, block=block):
            bks = jax.random.split(k, len(block))
            return {f"p{i}": _block_init(bk, desc, cfg, cross=cross)
                    for i, (bk, desc) in enumerate(zip(bks, block))}
        if count == 1:
            gparams.append(one(sub))
        else:
            gparams.append(jax.vmap(one)(jax.random.split(sub, count)))
    params["groups"] = gparams
    if cfg.enc_dec:
        enc_desc = ("attn", "dense")

        def one_enc(k):
            return {"p0": _block_init(k, enc_desc, cfg, cross=False)}
        params["enc"] = jax.vmap(one_enc)(
            jax.random.split(ks[3], cfg.n_enc_layers))
        params["enc_norm"] = L.rmsnorm_init(d)
    if cfg.frontend == "patches":
        params["patch_proj"] = L._dense(ks[4], (d, d))
    return params


# ------------------------------------------------------------------ #
# forward (train / prefill)
# ------------------------------------------------------------------ #
def _run_encoder(params, cfg, e, shard):
    @jax.checkpoint
    def enc_body(xx, bp):
        xx, _ = _block_apply(bp["p0"], xx, ("attn", "dense"), cfg,
                             shard, causal=False)
        return xx, None
    if FORCE_UNROLL:
        for i in range(cfg.n_enc_layers):
            e, _ = enc_body(e, _take(params["enc"], i))
        return e
    e, _ = jax.lax.scan(enc_body, e, params["enc"])
    return e


def _run_groups(params, cfg, x, shard, enc_out=None, causal=True,
                collect_caches=False):
    groups = group_descs(layer_descs(cfg))
    aux_total = jnp.float32(0.0)
    caches = []
    for (count, block), gp in zip(groups, params["groups"]):
        def super_block(xx, bp):
            a_tot = jnp.float32(0.0)
            for i, desc in enumerate(block):
                xx, a = _block_apply(bp[f"p{i}"], xx, desc, cfg, shard,
                                     enc_out=enc_out, causal=causal)
                a_tot += a
            return xx, a_tot
        if count == 1:
            x, a = super_block(x, gp)
            aux_total += a
        elif FORCE_UNROLL:
            for i in range(count):
                x, a = _remat(super_block)(x, _take(gp, i))
                aux_total += a
        else:
            def scan_body(xx, bp):
                return super_block(xx, bp)
            x, a_s = jax.lax.scan(_remat(scan_body), x, gp)
            aux_total += a_s.sum()
    return x, aux_total


def forward(params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            shard: ShardCfg = NO_SHARD) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(L.PDT)
    if cfg.frontend == "patches" and "patches" in batch:
        proj = batch["patches"].astype(L.PDT) @ params["patch_proj"]
        x = jax.lax.dynamic_update_slice(
            x, proj[:, :min(cfg.n_patches, x.shape[1])], (0, 0, 0))
    x = shard.act_residual(x)
    enc_out = None
    if cfg.enc_dec:
        e = batch["frames"].astype(L.PDT)      # frontend stub: embeddings
        e = shard.act_residual(e)
        e = _run_encoder(params, cfg, e, shard)
        enc_out = L.rmsnorm(params["enc_norm"], e, cfg.norm_eps)
    x, aux = _run_groups(params, cfg, x, shard, enc_out=enc_out)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = x @ params["unembed"]
    return shard.act_logits(logits), aux


# ------------------------------------------------------------------ #
# decode
# ------------------------------------------------------------------ #
def init_caches(cfg: ArchConfig, B: int, S_max: int) -> PyTree:
    groups = group_descs(layer_descs(cfg))
    caches = []
    for count, block in groups:
        def one(block=block):
            return {f"p{i}": _block_cache_init(desc, cfg, B, S_max,
                                               cross=cfg.enc_dec)
                    for i, desc in enumerate(block)}
        if count == 1:
            caches.append(one())
        else:
            caches.append(jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (count,) + a.shape),
                one()))
    return caches


def decode_step(params, cfg: ArchConfig, token: jax.Array, caches: PyTree,
                pos: jax.Array, shard: ShardCfg = NO_SHARD
                ) -> Tuple[jax.Array, PyTree]:
    """One decode step.  token (B,1) int32; pos () int32."""
    x = params["embed"][token].astype(L.PDT)
    groups = group_descs(layer_descs(cfg))
    new_caches = []
    for (count, block), gp, gc in zip(groups, params["groups"], caches):
        def super_block(xx, bp, bc):
            nc = {}
            for i, desc in enumerate(block):
                xx, nc[f"p{i}"] = _block_decode(bp[f"p{i}"], xx,
                                                bc[f"p{i}"], pos, desc, cfg)
            return xx, nc
        if count == 1:
            x, nc = super_block(x, gp, gc)
        elif FORCE_UNROLL:
            ncs = []
            for i in range(count):
                x, nci = super_block(x, _take(gp, i), _take(gc, i))
                ncs.append(nci)
            nc = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ncs)
        else:
            def scan_body(xx, pc):
                bp, bc = pc
                xx, nc = super_block(xx, bp, bc)
                return xx, nc
            x, nc = jax.lax.scan(scan_body, x, (gp, gc))
        new_caches.append(nc)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = x @ params["unembed"]
    return shard.act_logits(logits), new_caches
