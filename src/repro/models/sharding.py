"""Sharding rules: parameter specs, activation constraints, batch specs.

Mesh axes: ``("data","model")`` per pod, ``("pod","data","model")`` multi-pod.
  * TP ("model"): attention heads, FFN hidden, vocab, experts (EP).
  * DP ("pod","data"): batch; ZeRO-1 shards optimizer state further.
  * SP: the residual stream is sequence-sharded on "model" between blocks
    (Megatron-SP style; SPMD inserts the all-gather/reduce-scatter pairs).
Rules degrade gracefully: any dim not divisible by its axis size falls back
to replication (so reduced smoke configs run on 1 device with no mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ShardCfg:
    mesh: Optional[Mesh]
    dp: Tuple[str, ...] = ("data",)
    tp: str = "model"
    seq_shard: bool = True          # Megatron-SP on the residual stream

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp] if self.mesh else 1

    @property
    def dp_size(self) -> int:
        if not self.mesh:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.dp]))

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # -------------------------------------------------------------- #
    def constrain(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.named(spec))

    def act_residual(self, x):
        """(B,S,d) residual stream: batch on dp, seq on tp (SP)."""
        if self.mesh is None:
            return x
        B, S = x.shape[0], x.shape[1]
        bspec = self.dp if B % self.dp_size == 0 else None
        sspec = self.tp if (self.seq_shard and S % self.tp_size == 0
                            and S > 1) else None
        return self.constrain(x, P(bspec, sspec, None))

    def act_logits(self, x):
        if self.mesh is None:
            return x
        B = x.shape[0]
        bspec = self.dp if B % self.dp_size == 0 else None
        return self.constrain(x, P(bspec, None, self.tp))


NO_SHARD = ShardCfg(mesh=None)


# ------------------------------------------------------------------ #
# parameter specs by path rules
# ------------------------------------------------------------------ #
def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _param_spec(path: str, shape: Tuple[int, ...], tp: str, tp_size: int
                ) -> P:
    """Rule table.  ``shape`` may have a leading scan/stack dim — rules match
    on the trailing dims; leading dims get None."""
    lead = (None,) * (len(shape) - 2)

    def ok(dim_idx_from_end: int) -> bool:
        return shape[len(shape) - dim_idx_from_end] % tp_size == 0

    name = path.rsplit("/", 1)[-1]
    expert = "/moe/" in path and "/shared/" not in path
    if name in ("embed",):                       # (V, d)
        return P(tp if shape[0] % tp_size == 0 else None, None)
    if name in ("unembed",):                     # (d, V)
        return P(None, tp if shape[-1] % tp_size == 0 else None)
    if name in ("w1", "w3", "w2") and expert:    # (.., E, d, f): EP on E
        lead3 = (None,) * (len(shape) - 3)
        return P(*lead3, tp if ok(3) else None, None, None)
    if name in ("w1", "w3"):                     # (.., d, f)
        return P(*lead, None, tp if ok(1) else None)
    if name == "w2":                             # (.., f, d)
        return P(*lead, tp if ok(2) else None, None)
    if name in ("wq", "wk", "wv", "wz", "wx", "wuk", "wuv"):
        return P(*lead, None, tp if ok(1) else None)
    if name in ("wo",):
        return P(*lead, tp if ok(2) else None, None)
    if name in ("router", "wdkv", "wkr", "wB", "wC", "wdt", "patch_proj",
                "pos_emb"):
        return P(*lead, None, None)
    # 1-D / small leftovers (norms, A_log, D, dt_bias, conv) -> replicate
    return P(*((None,) * len(shape)))


def param_specs(params: PyTree, shard: ShardCfg) -> PyTree:
    """PartitionSpec pytree matching ``params``.

    Stacked (scanned) groups carry leading scan dims; rules apply to the
    trailing two dims.  Expert stacks (E, d, f) are detected by rule name.
    """
    def spec_of(path, leaf):
        return _param_spec(_path_str(path), tuple(getattr(leaf, "shape", ())),
                           shard.tp, shard.tp_size)
    return jax.tree_util.tree_map_with_path(spec_of, params)


def zero1_specs(params: PyTree, pspecs: PyTree, shard: ShardCfg) -> PyTree:
    """Optimizer-state specs: param spec + shard the largest replicated dim
    over the data axes (ZeRO-1)."""
    dp_size = shard.dp_size

    def has_dp(parts) -> bool:
        for ps in parts:
            if ps is None:
                continue
            axes = ps if isinstance(ps, tuple) else (ps,)
            if set(axes) & set(shard.dp):
                return True
        return False

    def upgrade(leaf, spec):
        shape = tuple(getattr(leaf, "shape", ()))
        parts = list(spec)
        if len(shape) != len(parts):
            parts = [None] * len(shape)
        if has_dp(parts):              # already dp-sharded (e.g. fsdp)
            return P(*parts)
        for i, (dim, ps) in enumerate(zip(shape, parts)):
            if ps is None and dim % dp_size == 0 and dim >= dp_size > 1:
                parts[i] = shard.dp
                break
        return P(*parts)
    return jax.tree_util.tree_map(upgrade, params, pspecs)


def batch_specs(batch: PyTree, shard: ShardCfg) -> PyTree:
    def spec_of(leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        if not shape:
            return P()
        b = shard.dp if shape[0] % shard.dp_size == 0 else None
        return P(b, *([None] * (len(shape) - 1)))
    return jax.tree_util.tree_map(spec_of, batch)


def cache_specs(cache: PyTree, shard: ShardCfg) -> PyTree:
    """KV caches: (B, S, Hkv, hd) -> heads on tp when divisible, else the
    sequence dim (MQA long-context: cache sequence-sharded)."""
    tp, tps = shard.tp, shard.tp_size

    def spec_of(path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        name = _path_str(path).rsplit("/", 1)[-1]

        def bspec(idx_from_end):
            dim = shape[len(shape) - idx_from_end]
            return shard.dp if dim % shard.dp_size == 0 else None

        if name in ("k", "v"):                   # (B,S,Hkv,hd) [+lead scan]
            lead = (None,) * (len(shape) - 4)
            if shape[-2] % tps == 0:
                return P(*lead, bspec(4), None, tp, None)
            return P(*lead, bspec(4), tp if shape[-3] % tps == 0 else None,
                     None, None)
        if name in ("c", "kr", "enc_out", "xk", "xv"):   # (B,S,*)
            lead = (None,) * (len(shape) - 3)
            return P(*lead, bspec(3),
                     tp if shape[-2] % tps == 0 else None, None)
        if name == "state":                      # (B,H,N,P) [+lead]
            lead = (None,) * (len(shape) - 4)
            return P(*lead, bspec(4), tp if shape[-3] % tps == 0 else None,
                     None, None)
        if name == "conv":                       # (B,W,ch)
            lead = (None,) * (len(shape) - 3)
            return P(*lead, bspec(3), None,
                     tp if shape[-1] % tps == 0 else None)
        return P(*([None] * len(shape)))
    return jax.tree_util.tree_map_with_path(spec_of, cache)
