"""Mamba-2 SSD (state-space duality) block — chunked matmul form.

The SSD algorithm (arXiv:2405.21060) is the TPU-friendly formulation of the
selective SSM: the sequence is split into chunks; within a chunk the
recurrence is computed as a masked (L×L) matmul ("attention-like" dual), and
states are passed between chunks with a tiny scan — so virtually all FLOPs
land on the MXU.  Decode keeps an (H, N, P) state per layer, O(1) per token.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import PDT, _dense, rmsnorm, rmsnorm_init

PyTree = Any


def ssm_dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_headdim
    H = inner // P
    N = cfg.ssm_state
    return inner, H, P, N


def mamba_init(key, cfg: ArchConfig) -> PyTree:
    d = cfg.d_model
    inner, H, P, N = ssm_dims(cfg)
    ks = jax.random.split(key, 9)
    return {
        "wz": _dense(ks[0], (d, inner)),
        "wx": _dense(ks[1], (d, inner)),
        "wB": _dense(ks[2], (d, N)),
        "wC": _dense(ks[3], (d, N)),
        "wdt": _dense(ks[4], (d, H)),
        "dt_bias": jnp.zeros((H,), PDT),
        "A_log": jnp.zeros((H,), jnp.float32),            # A = -exp(A_log)
        "D": jnp.ones((H,), jnp.float32),
        "conv": (jax.random.normal(ks[5], (cfg.ssm_conv, inner + 2 * N),
                                   jnp.float32) * 0.2).astype(PDT),
        "norm": rmsnorm_init(inner),
        "wo": _dense(ks[6], (inner, d)),
    }


def _causal_conv(u: jax.Array, kern: jax.Array) -> jax.Array:
    """Depthwise causal conv. u (B,S,ch), kern (W,ch)."""
    W = kern.shape[0]
    acc = u * kern[-1]
    for i in range(1, W):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :-i or None]
        acc = acc + shifted * kern[W - 1 - i]
    return acc


def ssd_chunked(x, dt, A_log, B_, C_, chunk: int):
    """Chunked SSD scan.

    x (B,S,H,P), dt (B,S,H) (post-softplus), A_log (H,), B_/C_ (B,S,N).
    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    nc = S // chunk
    assert S % chunk == 0, "caller pads sequence to chunk multiple"
    A = -jnp.exp(A_log)                                    # (H,) negative
    xc = x.reshape(Bb, nc, chunk, H, P)
    dtc = dt.reshape(Bb, nc, chunk, H).astype(jnp.float32)
    Bc = B_.reshape(Bb, nc, chunk, N).astype(jnp.float32)
    Cc = C_.reshape(Bb, nc, chunk, N).astype(jnp.float32)
    dA = dtc * A                                           # (B,nc,L,H)
    cum = jnp.cumsum(dA, axis=2)
    # --- intra-chunk (quadratic dual form)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,L,L,H)
    ltri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(ltri[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)             # (B,nc,L,L)
    w = cb[..., None] * decay * dtc[:, :, None, :, :]      # (B,nc,L,L,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w,
                         xc.astype(jnp.float32))
    # --- chunk states
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,L,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, decay_end * dtc,
                        xc.astype(jnp.float32))            # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B,nc,H)

    def scan_fn(prev, inp):
        st, cd = inp
        new = prev * cd[..., None, None] + st
        return new, prev

    states_t = states.swapaxes(0, 1)                       # (nc,B,H,N,P)
    cd_t = chunk_decay.swapaxes(0, 1)                      # (nc,B,H)
    init = jnp.zeros((Bb, H, N, P), jnp.float32)
    final, prevs = jax.lax.scan(scan_fn, init, (states_t, cd_t))
    prev_states = prevs.swapaxes(0, 1)                     # (B,nc,H,N,P)
    # --- inter-chunk contribution
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", Cc, jnp.exp(cum),
                         prev_states)
    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    return y.astype(x.dtype), final


def mamba_apply(p, x, cfg: ArchConfig, chunk: int = 256,
                return_state: bool = False):
    """Full-sequence Mamba-2 block (train / prefill)."""
    Bb, S, d = x.shape
    inner, H, P, N = ssm_dims(cfg)
    z = x @ p["wz"]                                        # (B,S,inner)
    xs = x @ p["wx"]
    Bv = x @ p["wB"]
    Cv = x @ p["wC"]
    u = jnp.concatenate([xs, Bv, Cv], -1)
    u = jax.nn.silu(_causal_conv(u, p["conv"]))
    xs, Bv, Cv = jnp.split(u, [inner, inner + N], axis=-1)
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"]
                         .astype(jnp.float32))             # (B,S,H)
    xh = xs.reshape(Bb, S, H, P)
    ch = min(chunk, S) if S % chunk else chunk
    y, state = ssd_chunked(xh, dt, p["A_log"], Bv, Cv, ch)
    y = y + p["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(Bb, S, inner)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["wo"]
    if return_state:
        conv_tail = jnp.concatenate(
            [x @ p["wx"], x @ p["wB"], x @ p["wC"]], -1)[:, -(cfg.ssm_conv - 1):]
        return out, (state, conv_tail)
    return out


def mamba_decode(p, x, state, conv_cache, cfg: ArchConfig):
    """One-token decode.  state (B,H,N,P); conv_cache (B,W-1,ch)."""
    Bb = x.shape[0]
    inner, H, P, N = ssm_dims(cfg)
    z = x @ p["wz"]                                        # (B,1,inner)
    u_t = jnp.concatenate([x @ p["wx"], x @ p["wB"], x @ p["wC"]], -1)
    win = jnp.concatenate([conv_cache, u_t], 1)            # (B,W,ch)
    conv_cache = win[:, 1:]
    u = jax.nn.silu(jnp.einsum("bwc,wc->bc", win.astype(jnp.float32),
                               p["conv"].astype(jnp.float32)))[:, None]
    xs, Bv, Cv = jnp.split(u, [inner, inner + N], axis=-1)
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                   # (B,H)
    xh = xs.reshape(Bb, H, P).astype(jnp.float32)
    state = state * dA[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", Bv[:, 0].astype(jnp.float32), dt, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cv[:, 0].astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(Bb, 1, inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["wo"], state, conv_cache
