"""Unified observability: span tracing, event bus, metrics (DESIGN.md §6).

Layering contract: ``repro.obs`` imports nothing from ``repro.core`` or
``repro.service`` — every layer above threads its events *down* into
this package (``dgraph.instrument()`` and its compat views are windows
over the same bus).
"""
from repro.obs.metrics import REGISTRY, MetricsCollector, Registry
from repro.obs.tracer import (Span, Tracer, current, emit, enabled,
                              first_use, forget_use, load_chrome,
                              register_collector, reset_seen_keys,
                              set_fault_hook, span, timed_dispatch,
                              tracing, unregister_collector)

# the default registry listens to every event for the life of the process
_METRICS = MetricsCollector(REGISTRY)
register_collector(_METRICS)

__all__ = [
    "REGISTRY", "MetricsCollector", "Registry", "Span", "Tracer",
    "current", "emit", "enabled", "first_use", "forget_use",
    "load_chrome", "register_collector", "reset_seen_keys",
    "set_fault_hook", "span", "timed_dispatch", "tracing",
    "unregister_collector",
]
