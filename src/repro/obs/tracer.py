"""Structured span tracing + the instrumentation event bus (DESIGN.md §6).

Two planes share this module:

* **Event bus** — the generalization of the old ``dgraph._ACTIVE`` list.
  Collectors (any object with ``on_event(kind, payload)``) register under
  a lock; ``emit`` fans every event out to all of them.  ``dgraph``'s
  ``instrument()`` registers its ``Instrumentation`` here, and a
  permanent metrics collector (``obs.metrics``) keeps global counters.
  The lock is held across the fan-out so read-modify-write updates
  (``stage_s`` accumulation) stay atomic when a service drain thread and
  the caller's thread emit concurrently.

* **Span tracer** — opt-in wall-clock attribution.  ``tracing()``
  installs a global ``Tracer``; ``span(name, **attrs)`` opens a timed
  span parented on the innermost open span of the *current thread /
  context* (a ``contextvars`` stack, so worker threads and async tasks
  nest correctly and never corrupt each other's ancestry).  When no
  tracer is installed, ``span`` returns a shared null context — the
  disabled path is one module-global read and no allocation, which is
  what keeps the disabled overhead within the ≤5% budget asserted in
  ``tests/test_obs.py``.

Compile vs dispatch attribution rides on ``first_use(key)``: callers pass
the exact key of the ``functools.lru_cache``'d jit builder they are about
to invoke; the first sighting of a key is billed as ``compile`` (trace +
lower + XLA compile, or a persistent-cache load — see
``util.enable_compile_cache``), later sightings as steady-state
``dispatch``.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import itertools
import json
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple


# ------------------------------------------------------------------ #
# event bus (collector registry)
# ------------------------------------------------------------------ #
_LOCK = threading.Lock()
_COLLECTORS: List[object] = []


def register_collector(collector: object) -> None:
    """Add a collector; it receives every subsequent ``emit``."""
    with _LOCK:
        _COLLECTORS.append(collector)


def unregister_collector(collector: object) -> None:
    """Remove a collector **by identity** (nested blocks may compare
    equal after a broadcast event; value-based removal would orphan the
    outer block)."""
    with _LOCK:
        for k in range(len(_COLLECTORS) - 1, -1, -1):
            if _COLLECTORS[k] is collector:
                del _COLLECTORS[k]
                break


def emit(kind: str, payload: dict) -> None:
    """Fan one event out to every registered collector, atomically."""
    with _LOCK:
        for c in _COLLECTORS:
            c.on_event(kind, payload)


# ------------------------------------------------------------------ #
# compile-key tracking
# ------------------------------------------------------------------ #
_SEEN_KEYS: set = set()


def first_use(key: Tuple) -> bool:
    """True the first time ``key`` is seen in this process.

    Keys mirror the jit-builder ``lru_cache`` keys, so "first use" is
    exactly the call that pays trace/lower/compile (or a persistent
    XLA-cache load) instead of a cached executable dispatch.
    """
    with _LOCK:
        if key in _SEEN_KEYS:
            return False
        _SEEN_KEYS.add(key)
        return True


def forget_use(key: Tuple) -> None:
    """Forget one compile key (jit-cache eviction hook).

    When a bounded jit cache evicts an executable, its next dispatch
    recompiles — calling this keeps the compile/dispatch billing honest
    by making that dispatch a ``first_use`` again.
    """
    with _LOCK:
        _SEEN_KEYS.discard(key)


def reset_seen_keys() -> None:
    """Test hook: forget compile-key history."""
    with _LOCK:
        _SEEN_KEYS.clear()


# ------------------------------------------------------------------ #
# spans
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class Span:
    """One timed interval; ``attrs`` may be filled while the span is
    open (e.g. lanes / bucket of a dispatch decided mid-span)."""
    span_id: int
    parent_id: Optional[int]
    name: str
    t0: float
    t1: Optional[float] = None
    tid: int = 0
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)


# Per-thread / per-context stack of open span ids.  A tuple (immutable)
# so concurrent readers never see a half-mutated stack.
_SPAN_STACK: contextvars.ContextVar[Tuple[int, ...]] = \
    contextvars.ContextVar("repro_obs_span_stack", default=())


class Tracer:
    """Collects spans; thread-safe; exports Chrome trace_event JSON."""

    def __init__(self, annotate_device: bool = False):
        self.spans: List[Span] = []
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._tids: Dict[int, int] = {}
        self._annotation_cls = None
        if annotate_device:
            try:                        # pragma: no cover - env dependent
                from jax.profiler import TraceAnnotation
                self._annotation_cls = TraceAnnotation
            except Exception:
                self._annotation_cls = None

    # -------------------------------------------------------------- #
    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            return self._tids.setdefault(ident, len(self._tids))

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Open a span parented on the current context's innermost open
        span; yields the ``Span`` so callers may add attrs."""
        sid = next(self._ids)
        stack = _SPAN_STACK.get()
        sp = Span(sid, stack[-1] if stack else None, name,
                  time.perf_counter(), tid=self._tid(), attrs=dict(attrs))
        token = _SPAN_STACK.set(stack + (sid,))
        ann = (self._annotation_cls(name)
               if self._annotation_cls is not None else None)
        if ann is not None:
            ann.__enter__()
        try:
            yield sp
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            _SPAN_STACK.reset(token)
            sp.t1 = time.perf_counter()
            with self._lock:
                self.spans.append(sp)

    def add_span(self, name: str, t0: float, t1: float,
                 attrs: Optional[dict] = None,
                 parent_id: Optional[int] = None) -> Span:
        """Record a retrospective span (e.g. a service request whose
        queue-wait interval is only known at resolve time)."""
        sp = Span(next(self._ids), parent_id, name, float(t0), float(t1),
                  tid=self._tid(), attrs=dict(attrs or {}))
        with self._lock:
            self.spans.append(sp)
        return sp

    def current_span_id(self) -> Optional[int]:
        stack = _SPAN_STACK.get()
        return stack[-1] if stack else None

    # -------------------------------------------------------------- #
    def export_chrome(self, path: str) -> None:
        """Write Chrome/Perfetto ``trace_event`` JSON (``ph: "X"``
        complete events; ``args`` carry span/parent ids and attrs so the
        tree round-trips through ``load_chrome``)."""
        with self._lock:
            spans = list(self.spans)
        base = min((s.t0 for s in spans), default=0.0)
        events = []
        for s in spans:
            t1 = s.t1 if s.t1 is not None else s.t0
            events.append({
                "name": s.name, "ph": "X", "pid": 1, "tid": s.tid,
                "ts": round((s.t0 - base) * 1e6, 3),
                "dur": round((t1 - s.t0) * 1e6, 3),
                "args": {"span_id": s.span_id, "parent_id": s.parent_id,
                         **s.attrs},
            })
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"},
                      f, default=str)


def load_chrome(path: str) -> List[Span]:
    """Rebuild spans from an ``export_chrome`` file (seconds, relative
    to the trace origin)."""
    with open(path) as f:
        doc = json.load(f)
    spans = []
    for ev in doc["traceEvents"]:
        if ev.get("ph") != "X":
            continue
        args = dict(ev.get("args", {}))
        sid = args.pop("span_id", None)
        pid = args.pop("parent_id", None)
        t0 = ev["ts"] / 1e6
        spans.append(Span(sid, pid, ev["name"], t0,
                          t0 + ev["dur"] / 1e6, tid=ev.get("tid", 0),
                          attrs=args))
    return spans


# ------------------------------------------------------------------ #
# global tracer
# ------------------------------------------------------------------ #
_TRACER: Optional[Tracer] = None
_NULL_CM = contextlib.nullcontext()     # stateless: shared & reentrant


def current() -> Optional[Tracer]:
    return _TRACER


def enabled() -> bool:
    return _TRACER is not None


@contextlib.contextmanager
def tracing(tracer: Optional[Tracer] = None, annotate_device: bool = False):
    """Install a global tracer for the block; yields the ``Tracer``.

    Tracing only *observes* (timestamps around the same calls) — output
    permutations are bit-identical with tracing on or off, asserted in
    ``tests/test_obs.py``.
    """
    global _TRACER
    t = tracer or Tracer(annotate_device=annotate_device)
    prev, _TRACER = _TRACER, t
    try:
        yield t
    finally:
        _TRACER = prev


def span(name: str, **attrs):
    """Open a span on the global tracer; shared no-op context when
    tracing is disabled (no allocation on the disabled path)."""
    t = _TRACER
    if t is None:
        return _NULL_CM
    return t.span(name, **attrs)


# ------------------------------------------------------------------ #
# timed dispatch helper
# ------------------------------------------------------------------ #
# Fault-injection seam (DESIGN.md §8): the service's chaos harness
# installs a wrapper here so every timed dispatch — the fm/bfs/match
# bucketed executors and the dhalo/dbfs/dmatch stacked collectives —
# is an injection boundary, without `core` ever importing the service
# layer (the same inversion as dgraph's config setters).  The wrapper
# is called as ``wrapper(kind, thunk) -> out``; None means pass-through.
_FAULT_HOOK = None


def set_fault_hook(fn):
    """Install (or clear, with None) the dispatch fault hook; returns
    the previous hook so scoped installers can restore it."""
    global _FAULT_HOOK
    prev, _FAULT_HOOK = _FAULT_HOOK, fn
    return prev


def timed_dispatch(stage: str, kind: str, jit_key: Tuple, thunk,
                   **attrs):
    """Run ``thunk`` as one traced device dispatch.

    Opens a ``dispatch:{kind}`` leaf span (attrs + ``compile`` flag),
    bills the elapsed wall-clock to ``stage`` via a ``stage`` event with
    the compile/dispatch phase decided by ``first_use(jit_key)``, and
    returns the thunk's value.  When a fault hook is installed the
    thunk runs through it (injected raises/delays/corruption happen
    *inside* the dispatch span, where a real device fault would).
    """
    is_compile = first_use(jit_key)
    hook = _FAULT_HOOK
    t0 = time.perf_counter()
    with span(f"dispatch:{kind}", compile=is_compile, **attrs):
        out = thunk() if hook is None else hook(kind, thunk)
    emit("stage", {"name": stage, "seconds": time.perf_counter() - t0,
                   "compile": is_compile})
    return out
