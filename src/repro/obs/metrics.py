"""Metrics registry: counters + histograms, Prometheus-style export.

A lightweight always-on companion to the span tracer: counters cost one
locked dict update per *event* (events fire per launch / stage / request,
never per element), so the registry stays registered on the event bus for
the life of the process.  ``snapshot()`` returns plain dicts for benches
and tests; ``render_prometheus()`` emits the text exposition format
(counters, and summaries with p50/p95 quantiles for histograms).
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Dict, Optional, Tuple

import numpy as np


def _key(name: str, labels: Dict[str, str]) -> Tuple:
    return (name,) + tuple(sorted((k, str(v)) for k, v in labels.items()))


def _fmt_labels(labels) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class Registry:
    """Thread-safe named counters and bounded-sample histograms."""

    def __init__(self, histogram_window: int = 4096):
        self._lock = threading.Lock()
        self._counters: Dict[Tuple, float] = {}
        self._hists: Dict[Tuple, deque] = {}
        self._window = histogram_window

    # -------------------------------------------------------------- #
    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        k = _key(name, labels)
        with self._lock:
            self._counters[k] = self._counters.get(k, 0.0) + value

    def observe(self, name: str, value: float, **labels) -> None:
        k = _key(name, labels)
        with self._lock:
            if k not in self._hists:
                self._hists[k] = deque(maxlen=self._window)
            self._hists[k].append(float(value))

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._hists.clear()

    # -------------------------------------------------------------- #
    def snapshot(self) -> dict:
        """Plain-dict view: counters and histogram summaries."""
        with self._lock:
            counters = {k: v for k, v in self._counters.items()}
            hists = {k: list(v) for k, v in self._hists.items()}

        def render_key(k):
            name, labels = k[0], k[1:]
            return name + _fmt_labels(labels)

        out = {"counters": {render_key(k): v for k, v in counters.items()},
               "histograms": {}}
        for k, samples in hists.items():
            arr = np.asarray(samples)
            out["histograms"][render_key(k)] = {
                "count": len(samples),
                "sum": float(arr.sum()),
                "p50": float(np.percentile(arr, 50)),
                "p95": float(np.percentile(arr, 95)),
            }
        return out

    def render_prometheus(self) -> str:
        """Text exposition: counters + summary quantiles."""
        with self._lock:
            counters = sorted(self._counters.items())
            hists = sorted((k, list(v)) for k, v in self._hists.items())
        lines = []
        seen_types = set()
        for k, v in counters:
            name, labels = k[0], k[1:]
            if name not in seen_types:
                lines.append(f"# TYPE {name} counter")
                seen_types.add(name)
            lines.append(f"{name}{_fmt_labels(labels)} {v:g}")
        for k, samples in hists:
            name, labels = k[0], k[1:]
            if name not in seen_types:
                lines.append(f"# TYPE {name} summary")
                seen_types.add(name)
            arr = np.asarray(samples)
            for q in (0.5, 0.95):
                ql = labels + (("quantile", f"{q:g}"),)
                lines.append(
                    f"{name}{_fmt_labels(ql)} "
                    f"{float(np.percentile(arr, q * 100)):g}")
            lines.append(f"{name}_count{_fmt_labels(labels)} {len(samples)}")
            lines.append(
                f"{name}_sum{_fmt_labels(labels)} {float(arr.sum()):g}")
        return "\n".join(lines) + "\n"


#: process-global default registry (benches / service read this)
REGISTRY = Registry()


class MetricsCollector:
    """Event-bus collector mapping instrumentation events onto the
    default registry.  Registered once at ``repro.obs`` import."""

    def __init__(self, registry: Optional[Registry] = None):
        self.registry = registry or REGISTRY

    def on_event(self, kind: str, payload: dict) -> None:
        r = self.registry
        if kind == "launch":
            r.inc("repro_launches_total", kind=payload["kind"])
            r.inc("repro_launch_lanes_total", payload["lanes"],
                  kind=payload["kind"])
            if payload.get("words"):
                r.inc("repro_gather_words_total", payload["words"],
                      kind=payload["kind"])
        elif kind == "stage":
            phase = "compile" if payload.get("compile") else "dispatch"
            r.inc("repro_stage_seconds_total", payload["seconds"],
                  stage=payload["name"], phase=phase)
        elif kind == "gather":
            r.inc("repro_gathers_total", kind=payload["kind"])
            r.inc("repro_gather_elements_total", payload["n"],
                  kind=payload["kind"])
        elif kind == "halo":
            r.inc("repro_halo_exchanges_total")
            r.inc("repro_halo_words_total", payload["n"])
