"""Loss and train-step factory (pjit-able, sharding-annotated)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.lm import forward
from repro.models.sharding import NO_SHARD, ShardCfg
from repro.optim import adamw

PyTree = Any


def loss_fn(params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            shard: ShardCfg = NO_SHARD, aux_weight: float = 0.01,
            z_weight: float = 1e-4) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, cfg, batch, shard)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    xent = jnp.sum((lse - gold) * mask) / denom
    zloss = jnp.sum(jnp.square(lse) * mask) / denom
    total = xent + aux_weight * aux + z_weight * zloss
    return total, {"xent": xent, "aux": aux, "zloss": zloss}


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    shard: ShardCfg = NO_SHARD):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Gradients are averaged over the batch inside the graph; with batch
    sharded over (pod, data), SPMD emits the cross-replica all-reduce —
    overlapped with backward compute by XLA's latency-hiding scheduler.
    """
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, shard), has_aux=True)(params)
        new_params, new_opt, gnorm = adamw.update(grads, opt_state, params,
                                                  opt_cfg)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_params, new_opt, metrics

    return train_step
