"""Fault tolerance and elastic scaling for the training loop.

Production contract (documented against real-TPU behavior; simulated here):

  * **Failure detection** — a heartbeat registry per host; a missed deadline
    marks the host dead and triggers restart-from-checkpoint on the
    surviving set.  (On real pods, the equivalent signal comes from the
    coordination service / barrier timeout.)
  * **Elastic re-mesh** — checkpoints are topology-independent
    (`checkpoint.py`); `plan_elastic_mesh` picks the largest feasible
    (data, model) mesh for the surviving device count and the restore path
    device_puts against it.  This mirrors PT-Scotch's fold: halve the
    data-parallel group and rebalance, never demanding powers-of-two of the
    *original* size.
  * **Straggler mitigation** — the data pipeline issues hedged reads
    (pipeline.py); at the step level, `StragglerMonitor` tracks a running
    step-time EWMA and flags outliers for hedging/eviction.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class Heartbeat:
    deadline_s: float = 30.0
    last_seen: Dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, host: int, now: Optional[float] = None) -> None:
        self.last_seen[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [h for h, t in self.last_seen.items()
                if now - t > self.deadline_s]


def plan_elastic_mesh(n_devices: int, model_parallel: int
                      ) -> Tuple[int, int]:
    """Largest (data, model) grid for the surviving devices.

    Model-parallel width is fixed by the checkpointed layout; data width is
    whatever is left — any integer ≥ 1 works (the PT-Scotch fold property:
    no power-of-two requirement)."""
    if n_devices < model_parallel:
        raise ValueError(
            f"need ≥{model_parallel} devices for TP={model_parallel}")
    return n_devices // model_parallel, model_parallel


class StragglerMonitor:
    """EWMA step timer; flags steps slower than ``factor``× the mean."""

    def __init__(self, factor: float = 2.0, alpha: float = 0.1):
        self.factor = factor
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        is_straggler = (self.ewma is not None
                        and dt > self.factor * self.ewma)
        self.ewma = dt if self.ewma is None else \
            (1 - self.alpha) * self.ewma + self.alpha * dt
        if is_straggler:
            self.flagged += 1
        return is_straggler


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 10
    backoff_s: float = 1.0
    restarts: int = 0

    def should_restart(self) -> bool:
        return self.restarts < self.max_restarts

    def record(self) -> float:
        self.restarts += 1
        return self.backoff_s * min(2 ** (self.restarts - 1), 32)
