"""Topology-independent checkpointing with atomic manifests.

Leaves are saved as flat ``.npy`` entries inside an ``.npz`` keyed by tree
path; the manifest records step, config digest and leaf index.  Restores are
independent of device mesh / host count (the elastic-scaling contract:
resharding happens at load via ``jax.device_put`` against the new mesh).
Writes are atomic (tmp file + rename) so a preempted host never leaves a
corrupt latest checkpoint.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _np_safe(x: np.ndarray):
    """npz can't hold ml_dtypes (bf16 etc.) — store a uint view + dtype tag."""
    if x.dtype.kind == "V" or x.dtype.name == "bfloat16":
        return x.view(np.uint16), "bfloat16"
    return x, x.dtype.name


def save(path: str, step: int, tree: PyTree, extra: Optional[dict] = None
         ) -> str:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays, dtypes = {}, []
    for i, x in enumerate(leaves):
        arr, tag = _np_safe(np.asarray(x))
        arrays[f"leaf_{i}"] = arr
        dtypes.append(tag)
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp[:-4], **arrays)        # np.savez appends .npz
    os.replace(tmp, fname)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "file": os.path.basename(fname),
        "dtypes": dtypes,
        "extra": extra or {},
    }
    mtmp = fname + ".manifest.tmp"
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, os.path.join(path, "manifest.json"))
    return fname


def latest_step(path: str) -> Optional[int]:
    mf = os.path.join(path, "manifest.json")
    if not os.path.exists(mf):
        return None
    return json.load(open(mf))["step"]


def restore(path: str, tree_like: PyTree, shardings: Optional[PyTree] = None
            ) -> Tuple[int, PyTree]:
    """Restore into the structure of ``tree_like`` (shapes must match).

    ``shardings``: optional NamedSharding pytree — leaves are device_put
    against it, implementing elastic re-sharding onto a new mesh.
    """
    mf = json.load(open(os.path.join(path, "manifest.json")))
    data = np.load(os.path.join(path, mf["file"]))
    leaves, treedef = _flatten(tree_like)
    assert len(leaves) == mf["n_leaves"], \
        f"checkpoint has {mf['n_leaves']} leaves, model has {len(leaves)}"
    import ml_dtypes
    new_leaves = []
    dtypes = mf.get("dtypes", [])
    for i, like in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        tag = dtypes[i] if i < len(dtypes) else None
        if tag == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        elif hasattr(like, "dtype"):
            arr = arr.astype(like.dtype)
        new_leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(jax.device_put, tree, shardings)
    return mf["step"], tree
