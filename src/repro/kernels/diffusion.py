"""Pallas TPU kernel: fused banded-diffusion smoothing step.

Implements one step of the parallelizable diffusion scheme the paper points
to as the scalable replacement for sequential FM (its ref [28], Pellegrini,
Euro-Par 2007): two "liquids" are injected at the side anchors (+σ at side
0, −σ at side 1), diffuse along edges, and evaporate; the sign of the
steady-state marks the parts and the near-zero belt the separator.

One step is
    y = x + dt · (Σ_j w_ij·x_j − deg_i·x_i) − dt·μ·sign(x)   (evaporation)
        + injection at anchors,
fused into a single VMEM pass over the ELL tiles (SpMV + AXPY + clamp),
instead of three HBM round-trips — the TPU adaptation of a kernel a GPU
code would write as CSR SpMV + two elementwise passes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _diffusion_kernel(nbr_ref, val_ref, x_ref, inj_ref, y_ref, *, dt, mu):
    nbr = nbr_ref[...]
    val = val_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    inj = inj_ref[...].astype(jnp.float32)     # (bn,) per-vertex injection
    mask = nbr >= 0
    idx = jnp.where(mask, nbr, 0)
    xv = jnp.take(x, idx.reshape(-1), axis=0).reshape(nbr.shape)
    wv = jnp.where(mask, val, 0.0)
    flow = jnp.sum(wv * xv, axis=1)
    deg = jnp.sum(wv, axis=1)
    i0 = pl.program_id(0) * y_ref.shape[0]
    xi = jax.lax.dynamic_slice(x, (i0,), (y_ref.shape[0],))
    y = xi + dt * (flow - deg * xi) - dt * mu * jnp.sign(xi) + inj
    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("dt", "mu", "block_rows", "interpret"))
def diffusion_step(nbr: jax.Array, val: jax.Array, x: jax.Array,
                   inj: jax.Array, dt: float = 0.25, mu: float = 0.1,
                   block_rows: int = 256, interpret: bool = True
                   ) -> jax.Array:
    """One fused diffusion step on the ELL graph (shapes as ell_spmv)."""
    n, d = nbr.shape
    assert n % block_rows == 0
    grid = (n // block_rows,)
    kern = functools.partial(_diffusion_kernel, dt=dt, mu=mu)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),                # x resident
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(nbr, val, x, inj)
