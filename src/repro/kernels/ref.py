"""Pure-jnp oracles for the Pallas kernels (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_spmv_ref(nbr: jax.Array, val: jax.Array, x: jax.Array) -> jax.Array:
    mask = nbr >= 0
    idx = jnp.where(mask, nbr, 0)
    xv = x[idx]
    acc = jnp.sum(jnp.where(mask, val * xv, 0).astype(jnp.float32), axis=1)
    return acc.astype(x.dtype)


def diffusion_step_ref(nbr: jax.Array, val: jax.Array, x: jax.Array,
                       inj: jax.Array, dt: float = 0.25,
                       mu: float = 0.1) -> jax.Array:
    mask = nbr >= 0
    idx = jnp.where(mask, nbr, 0)
    xf = x.astype(jnp.float32)
    wv = jnp.where(mask, val.astype(jnp.float32), 0.0)
    flow = jnp.sum(wv * xf[idx], axis=1)
    deg = jnp.sum(wv, axis=1)
    y = (xf + dt * (flow - deg * xf) - dt * mu * jnp.sign(xf)
         + inj.astype(jnp.float32))
    return y.astype(x.dtype)
