"""Pure-jnp oracles for the Pallas kernels (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_spmv_ref(nbr: jax.Array, val: jax.Array, x: jax.Array) -> jax.Array:
    mask = nbr >= 0
    idx = jnp.where(mask, nbr, 0)
    xv = x[idx]
    acc = jnp.sum(jnp.where(mask, val * xv, 0).astype(jnp.float32), axis=1)
    return acc.astype(x.dtype)


def bfs_multi_ref(nbr: jax.Array, src: jax.Array, width: int) -> jax.Array:
    """Batched min-plus BFS relaxation (oracle for band_batch.bfs_multi)."""
    UNREACH = jnp.int32(2 ** 30)
    L, n, d = nbr.shape
    valid = nbr >= 0
    idx = jnp.where(valid, nbr, 0)
    dist = jnp.where(src != 0, 0, UNREACH).astype(jnp.int32)
    for _ in range(width):
        dn = jnp.take_along_axis(dist, idx.reshape(L, n * d),
                                 axis=1).reshape(L, n, d)
        dn = jnp.where(valid, dn, UNREACH)
        dist = jnp.minimum(dist, jnp.min(dn, axis=2) + 1)
    return dist


def sep_gain_multi_ref(nbr: jax.Array, vwgt: jax.Array, part: jax.Array):
    """Batched pulled-weight gains (oracle for band_batch.sep_gain_multi)."""
    L, n, d = nbr.shape
    valid = nbr >= 0
    flat = jnp.where(valid, nbr, 0).reshape(L, n * d)
    pn = jnp.take_along_axis(part, flat, axis=1).reshape(L, n, d)
    wn = jnp.take_along_axis(vwgt.astype(jnp.float32), flat,
                             axis=1).reshape(L, n, d)
    wn = jnp.where(valid, wn, 0.0)
    return (jnp.sum(wn * (pn == 1), axis=2),
            jnp.sum(wn * (pn == 0), axis=2))


def fm_fused_ref(nbr: jax.Array, vwgt: jax.Array, parts_init: jax.Array,
                 locked: jax.Array, noise: jax.Array, eps_abs: jax.Array,
                 max_moves: jax.Array, n_pert: jax.Array, passes: int = 3,
                 pos_only: bool = False):
    """Oracle for the fused FM pass loop (``fm_fused.fm_fused_multi``).

    An independent jnp implementation — it shares no code with the
    kernel or the hoisted path, which is what makes the differential
    parity suite (``tests/test_fm_fused.py``) meaningful.  Takes the
    kernel's *device* inputs: precomputed tiebreak ``noise``
    (L, passes, 2, n) from ``fm_fused.fm_noise`` and absolute balance
    slack ``eps_abs`` (L,).  All float sums are over integer-valued
    float32 weights, so any reduction order is exact and bit-parity with
    the kernel is well-defined.  Returns (parts int8, sep_w, imb).
    """
    L, n, d = nbr.shape

    def one_lane(nbr, vwgt_f, part, locked, noise_all, eps_abs,
                 max_moves, n_pert):
        valid = nbr >= 0
        nbrs = jnp.where(valid, nbr, 0)

        def sums(part):
            return (jnp.sum(vwgt_f * (part == 0)),
                    jnp.sum(vwgt_f * (part == 1)),
                    jnp.sum(vwgt_f * (part == 2)))

        def move_body(carry):
            (i, alive, part, moved, pulled0, pulled1,
             w0, w1, ws, bpart, bws, bimb, noise, pert) = carry
            imb = jnp.abs(w0 - w1)
            feas0 = jnp.abs((w0 + vwgt_f) - (w1 - pulled0)) \
                <= jnp.maximum(eps_abs, imb)
            feas1 = jnp.abs((w0 - pulled1) - (w1 + vwgt_f)) \
                <= jnp.maximum(eps_abs, imb)
            movable = (part == 2) & ~moved & ~locked
            ok0, ok1 = movable & feas0, movable & feas1
            if pos_only:
                ok0 = ok0 & (vwgt_f - pulled0 > 0)
                ok1 = ok1 & (vwgt_f - pulled1 > 0)
            amp = jnp.where(i < pert, 1e9, 1e-3)
            scores = jnp.concatenate([
                jnp.where(ok0, vwgt_f - pulled0 + noise[0] * amp, -jnp.inf),
                jnp.where(ok1, vwgt_f - pulled1 + noise[1] * amp, -jnp.inf)])
            idx = jnp.argmax(scores)
            ok = scores[idx] > -jnp.inf
            side = (idx >= n).astype(part.dtype)
            v = (idx % n).astype(jnp.int32)
            nv, nvalid = nbrs[v], valid[v]
            pull = nvalid & (part[nv] == (1 - side)) & ok
            pulled_w = jnp.sum(jnp.where(pull, vwgt_f[nv], 0.0))
            part = part.at[jnp.where(pull, nv, n)].set(2, mode="drop")
            part = part.at[v].set(jnp.where(ok, side, part[v]))
            tgt_v = jnp.where(nvalid & ok, nv, n)
            dv_w = vwgt_f[v]
            pulled0 = pulled0.at[tgt_v].add(
                jnp.where(side == 1, dv_w, 0.0), mode="drop")
            pulled1 = pulled1.at[tgt_v].add(
                jnp.where(side == 0, dv_w, 0.0), mode="drop")
            rows = nbrs[nv]
            rvalid = valid[nv] & pull[:, None]
            tgt_u = jnp.where(rvalid, rows, n).reshape(-1)
            amt = jnp.where(rvalid, jnp.broadcast_to(
                vwgt_f[nv][:, None], rows.shape), 0.0).reshape(-1)
            pulled0 = pulled0.at[tgt_u].add(
                jnp.where(side == 0, -amt, 0.0), mode="drop")
            pulled1 = pulled1.at[tgt_u].add(
                jnp.where(side == 1, -amt, 0.0), mode="drop")
            dv = jnp.where(ok, dv_w, 0.0)
            w0 = w0 + jnp.where(side == 0, dv, 0.0) \
                - jnp.where(side == 1, pulled_w, 0.0)
            w1 = w1 + jnp.where(side == 1, dv, 0.0) \
                - jnp.where(side == 0, pulled_w, 0.0)
            ws = ws - dv + pulled_w
            moved = moved.at[v].set(moved[v] | ok)
            imb_new = jnp.abs(w0 - w1)
            better = (ws < bws) & (imb_new <= jnp.maximum(eps_abs, bimb))
            bpart = jnp.where(better, part, bpart)
            bws = jnp.where(better, ws, bws)
            bimb = jnp.where(better, jnp.minimum(imb_new, bimb), bimb)
            return (i + 1, ok, part, moved, pulled0, pulled1,
                    w0, w1, ws, bpart, bws, bimb, noise, pert)

        def pass_body(p, carry):
            part, bpart, bws, bimb = carry
            w0, w1, ws = sums(part)
            flat = nbrs.reshape(-1)
            pn = jnp.take(part, flat, axis=0).reshape(nbr.shape)
            wn = jnp.where(valid, jnp.take(vwgt_f, flat,
                                           axis=0).reshape(nbr.shape), 0.0)
            pulled0 = jnp.sum(wn * (pn == 1), axis=1)
            pulled1 = jnp.sum(wn * (pn == 0), axis=1)
            carry0 = (jnp.int32(0), jnp.bool_(True), part,
                      jnp.zeros(n, bool), pulled0, pulled1, w0, w1, ws,
                      bpart, bws, bimb, noise_all[p],
                      jnp.where(p == 0, n_pert, 0))
            out = jax.lax.while_loop(
                lambda c: (c[0] < max_moves) & c[1], move_body, carry0)
            return (out[9], out[9], out[10], out[11])   # part <- best

        w0, w1, ws = sums(part)
        carry = (part, part, ws, jnp.abs(w0 - w1))
        part, bpart, bws, bimb = jax.lax.fori_loop(0, passes, pass_body,
                                                   carry)
        return bpart, bws, bimb

    parts, bws, bimb = jax.vmap(one_lane)(
        jnp.asarray(nbr, jnp.int32), vwgt.astype(jnp.float32),
        parts_init.astype(jnp.int32), jnp.asarray(locked, bool),
        noise, eps_abs.astype(jnp.float32),
        jnp.asarray(max_moves, jnp.int32), jnp.asarray(n_pert, jnp.int32))
    return parts.astype(jnp.int8), bws, bimb


def diffusion_step_ref(nbr: jax.Array, val: jax.Array, x: jax.Array,
                       inj: jax.Array, dt: float = 0.25,
                       mu: float = 0.1) -> jax.Array:
    mask = nbr >= 0
    idx = jnp.where(mask, nbr, 0)
    xf = x.astype(jnp.float32)
    wv = jnp.where(mask, val.astype(jnp.float32), 0.0)
    flow = jnp.sum(wv * xf[idx], axis=1)
    deg = jnp.sum(wv, axis=1)
    y = (xf + dt * (flow - deg * xf) - dt * mu * jnp.sign(xf)
         + inj.astype(jnp.float32))
    return y.astype(x.dtype)
