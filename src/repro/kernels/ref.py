"""Pure-jnp oracles for the Pallas kernels (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_spmv_ref(nbr: jax.Array, val: jax.Array, x: jax.Array) -> jax.Array:
    mask = nbr >= 0
    idx = jnp.where(mask, nbr, 0)
    xv = x[idx]
    acc = jnp.sum(jnp.where(mask, val * xv, 0).astype(jnp.float32), axis=1)
    return acc.astype(x.dtype)


def bfs_multi_ref(nbr: jax.Array, src: jax.Array, width: int) -> jax.Array:
    """Batched min-plus BFS relaxation (oracle for band_batch.bfs_multi)."""
    UNREACH = jnp.int32(2 ** 30)
    L, n, d = nbr.shape
    valid = nbr >= 0
    idx = jnp.where(valid, nbr, 0)
    dist = jnp.where(src != 0, 0, UNREACH).astype(jnp.int32)
    for _ in range(width):
        dn = jnp.take_along_axis(dist, idx.reshape(L, n * d),
                                 axis=1).reshape(L, n, d)
        dn = jnp.where(valid, dn, UNREACH)
        dist = jnp.minimum(dist, jnp.min(dn, axis=2) + 1)
    return dist


def sep_gain_multi_ref(nbr: jax.Array, vwgt: jax.Array, part: jax.Array):
    """Batched pulled-weight gains (oracle for band_batch.sep_gain_multi)."""
    L, n, d = nbr.shape
    valid = nbr >= 0
    flat = jnp.where(valid, nbr, 0).reshape(L, n * d)
    pn = jnp.take_along_axis(part, flat, axis=1).reshape(L, n, d)
    wn = jnp.take_along_axis(vwgt.astype(jnp.float32), flat,
                             axis=1).reshape(L, n, d)
    wn = jnp.where(valid, wn, 0.0)
    return (jnp.sum(wn * (pn == 1), axis=2),
            jnp.sum(wn * (pn == 0), axis=2))


def diffusion_step_ref(nbr: jax.Array, val: jax.Array, x: jax.Array,
                       inj: jax.Array, dt: float = 0.25,
                       mu: float = 0.1) -> jax.Array:
    mask = nbr >= 0
    idx = jnp.where(mask, nbr, 0)
    xf = x.astype(jnp.float32)
    wv = jnp.where(mask, val.astype(jnp.float32), 0.0)
    flow = jnp.sum(wv * xf[idx], axis=1)
    deg = jnp.sum(wv, axis=1)
    y = (xf + dt * (flow - deg * xf) - dt * mu * jnp.sign(xf)
         + inj.astype(jnp.float32))
    return y.astype(x.dtype)
