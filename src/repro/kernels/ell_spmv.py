"""Pallas TPU kernel: ELL sparse matrix–vector product.

The compute hot-spot of the diffusion-based separator smoother (paper §4 /
ref [28]) and of spectral-style partitioning is repeated SpMV over the
band/graph adjacency.  GPU implementations use CSR + warp-per-row; the
TPU-native formulation is ELL (rectangular (n, dmax) neighbor/weight tiles,
−1 padding) so rows map onto the 8×128 VPU lanes without pointer chasing.

Tiling: the row dimension is split into ``block_rows`` tiles; the dense
vector ``x`` is kept whole in VMEM (band graphs are O(n^{2/3}) of the
problem, a few hundred KiB — far below the ~16 MiB VMEM budget; this is a
deliberate adaptation: HBM→VMEM streaming of the ELL tiles dominates, and
keeping x resident turns the gather into a VMEM-local operation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(nbr_ref, val_ref, x_ref, y_ref):
    nbr = nbr_ref[...]                        # (bn, d) int32
    val = val_ref[...]                        # (bn, d)
    x = x_ref[...]                            # (n,)   resident vector
    mask = nbr >= 0
    idx = jnp.where(mask, nbr, 0)
    xv = jnp.take(x, idx.reshape(-1), axis=0).reshape(nbr.shape)
    acc = jnp.sum(jnp.where(mask, val * xv, 0).astype(jnp.float32), axis=1)
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ell_spmv(nbr: jax.Array, val: jax.Array, x: jax.Array,
             block_rows: int = 256, interpret: bool = True) -> jax.Array:
    """y[i] = Σ_j val[i,j] * x[nbr[i,j]] over valid (nbr >= 0) slots.

    Args:
      nbr: (n, d) int32 ELL neighbor ids (-1 = padding).
      val: (n, d) edge weights.
      x:   (n,) dense vector.
      block_rows: rows per VMEM tile (multiple of 8 for TPU sublanes).
      interpret: run the kernel body in Python (CPU validation mode).
    """
    n, d = nbr.shape
    assert n % block_rows == 0, "caller pads rows to a block multiple"
    grid = (n // block_rows,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),   # ELL ids tile
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),   # ELL val tile
            pl.BlockSpec((n,), lambda i: (0,)),                # x resident
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(nbr, val, x)
