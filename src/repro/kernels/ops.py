"""Public jit'd wrappers around the device kernels (bucketed dispatch API).

Pallas wrappers handle padding to block multiples and backend selection:
``interpret=True`` (Python execution of the kernel body) on CPU hosts,
compiled Mosaic on TPU.  The batched entry points (``band_bfs_batch``,
``sep_gain_batch``, ``match_batch``) are what the service's bucketed
executors dispatch — one call per shape bucket, lanes mixing independent
subproblems.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.band_batch import bfs_multi, sep_gain_multi
from repro.kernels.diffusion import diffusion_step
from repro.kernels.ell_spmv import ell_spmv
from repro.kernels.fm_fused import fm_fused_multi


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def fm_mode_default() -> str:
    """FM refinement path: REPRO_FM_MODE=fused|hoisted|auto.

    ``fused`` runs the whole pass loop on device as one Pallas kernel
    (``kernels.fm_fused``); ``hoisted`` is the pre-fusion reference path
    (``core.fm.fm_refine_multi``: Python pass loop traced into one XLA
    program, batched gain recompute per pass).  ``auto`` resolves to
    ``fused`` on every backend — measured faster in both compile and
    steady-state dispatch even under CPU interpret mode, and the two
    paths are bit-identical (asserted in ``tests/test_fm_fused.py``).
    """
    mode = os.environ.get("REPRO_FM_MODE", "auto")
    if mode == "auto":
        return "fused"
    return mode


def fm_refine_batch(nbr, vwgt, parts_init, locked, keys, eps_frac,
                    max_moves, n_pert, passes: int = 3,
                    pos_only: bool = False, mode: str | None = None,
                    gain_mode: str | None = None,
                    interpret: bool | None = None):
    """Batched FM refinement over a bucket's lane stack (mode-switched).

    The single entry point ``core.fm.execute_fm_works`` dispatches
    through — shapes as in ``fm_refine_multi``.  ``mode`` selects the
    fused kernel vs the hoisted path (default ``fm_mode_default()``);
    ``oracle`` is the independent jnp reference (``kernels.ref``) — the
    recovery ladder's last kernel rung (DESIGN.md §8), sharing no code
    with the other two.  ``gain_mode`` only applies to the hoisted
    path's per-pass gain recompute backend.  All modes return
    bit-identical results (asserted in ``tests/test_fm_fused.py``).
    """
    if mode is None:
        mode = fm_mode_default()
    if mode == "fused":
        if interpret is None:
            interpret = _interpret_default()
        return fm_fused_multi(nbr, vwgt, parts_init, locked, keys,
                              eps_frac, max_moves, n_pert, passes=passes,
                              pos_only=pos_only, interpret=interpret)
    if mode == "oracle":
        from repro.kernels.fm_fused import fm_noise
        from repro.kernels.ref import fm_fused_ref
        nbr = jnp.asarray(nbr, jnp.int32)
        vwgt = jnp.asarray(vwgt)
        noise = fm_noise(jnp.asarray(keys), nbr.shape[1], passes)
        eps_abs = jnp.asarray(eps_frac) * \
            vwgt.astype(jnp.float32).sum(axis=1)
        return fm_fused_ref(nbr, vwgt, jnp.asarray(parts_init),
                            jnp.asarray(locked), noise, eps_abs,
                            jnp.asarray(max_moves), jnp.asarray(n_pert),
                            passes=passes, pos_only=pos_only)
    if mode != "hoisted":
        raise ValueError(f"REPRO_FM_MODE={mode!r} not in "
                         "fused|hoisted|oracle|auto")
    from repro.core.fm import fm_refine_multi, gain_mode_default
    if gain_mode is None:
        gain_mode = gain_mode_default()
    return fm_refine_multi(nbr, vwgt, parts_init, locked, keys, eps_frac,
                           max_moves, n_pert, passes=passes,
                           pos_only=pos_only, gain_mode=gain_mode)


def ell_relax_step(nbr: jax.Array, dist_ext: jax.Array, big) -> jax.Array:
    """One min-plus ELL relaxation: min over valid neighbors of ext+1.

    ``nbr`` (n, d) compact ids with -1 padding; ``dist_ext`` is any vector
    the ids index into — the distance vector itself in the centralized BFS
    (``core.band``), or the halo-extended local+ghost vector in the
    distributed sweep (``core.dgraph``).  Shared so the two sweeps relax
    identically.

    Lane-stacked form: ``nbr`` (L, n, d) with ``dist_ext`` (L, m) relaxes
    every lane against its own extended vector — the per-bucket stacked
    BFS of ``dgraph.distributed_bfs_stacked`` runs all lanes of a wave
    through one such step per relaxation.  Reductions stay within-lane,
    so each lane equals its 2-D singleton relaxation bit-for-bit.
    """
    valid = nbr >= 0
    idx = jnp.where(valid, nbr, 0)
    if nbr.ndim == 3:
        L, n, d = nbr.shape
        dn = jnp.take_along_axis(dist_ext, idx.reshape(L, n * d),
                                 axis=1).reshape(L, n, d)
        dn = jnp.where(valid, dn, big)
    else:
        dn = jnp.where(valid, dist_ext[idx], big)
    return jnp.min(dn, axis=-1) + 1


def _pad_rows(a: np.ndarray | jax.Array, block: int, fill):
    n = a.shape[0]
    pad = (-n) % block
    if pad == 0:
        return a, n
    widths = [(0, pad)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, widths, constant_values=fill), n


def spmv(nbr, val, x, block_rows: int = 256, interpret: bool | None = None):
    """ELL SpMV with automatic padding; returns (n,) like x."""
    if interpret is None:
        interpret = _interpret_default()
    n = x.shape[0]
    nbr_p, _ = _pad_rows(jnp.asarray(nbr, jnp.int32), block_rows, -1)
    val_p, _ = _pad_rows(jnp.asarray(val), block_rows, 0)
    # x stays unpadded except to match row padding (gather targets < n)
    x_p, _ = _pad_rows(jnp.asarray(x), block_rows, 0)
    y = ell_spmv(nbr_p, val_p, x_p, block_rows=block_rows,
                 interpret=interpret)
    return y[:n]


def band_bfs_batch(nbr, src, width: int, interpret: bool | None = None):
    """Batched band-distance sweep over a bucket of ELL graphs.

    nbr (L, n, d) int32 / src (L, n) bool-ish → dist (L, n) int32 clipped
    at width+1 (UNREACH beyond).  One kernel launch for the whole bucket.
    """
    if interpret is None:
        interpret = _interpret_default()
    return bfs_multi(jnp.asarray(nbr, jnp.int32),
                     jnp.asarray(src, jnp.int32), width,
                     interpret=interpret)


def match_batch(nbr, wgt, keys, rounds: int = 8):
    """Batched heavy-edge matching over a bucket of ELL graphs.

    nbr/wgt (L, n, d) int32 (-1 / 0 pad), keys (L, 2) uint32 PRNG keys →
    match (L, n) int32 (mate id, self for singletons).  One vmapped XLA
    dispatch for the whole bucket; per-lane results equal the single-graph
    ``matching.heavy_edge_matching`` with the same key.
    """
    from repro.core.matching import heavy_edge_matching_multi
    return heavy_edge_matching_multi(jnp.asarray(nbr, jnp.int32),
                                     jnp.asarray(wgt, jnp.int32),
                                     jnp.asarray(keys), rounds=rounds)


def sep_gain_batch(nbr, vwgt, part, block_rows: int = 256,
                   interpret: bool | None = None):
    """Batched separator FM gain recompute (pulled weights), (L, n) pair."""
    if interpret is None:
        interpret = _interpret_default()
    n = nbr.shape[1]
    return sep_gain_multi(jnp.asarray(nbr, jnp.int32),
                          jnp.asarray(vwgt, jnp.float32),
                          jnp.asarray(part, jnp.int32),
                          block_rows=min(block_rows, n), interpret=interpret)


def diffuse(nbr, val, x, inj, steps: int = 1, dt: float = 0.25,
            mu: float = 0.1, block_rows: int = 256,
            interpret: bool | None = None):
    """Run ``steps`` fused diffusion steps; returns final x."""
    if interpret is None:
        interpret = _interpret_default()
    n = x.shape[0]
    nbr_p, _ = _pad_rows(jnp.asarray(nbr, jnp.int32), block_rows, -1)
    val_p, _ = _pad_rows(jnp.asarray(val), block_rows, 0)
    x_p, _ = _pad_rows(jnp.asarray(x), block_rows, 0)
    inj_p, _ = _pad_rows(jnp.asarray(inj), block_rows, 0)
    for _ in range(steps):
        x_p = diffusion_step(nbr_p, val_p, x_p, inj_p, dt=dt, mu=mu,
                             block_rows=block_rows, interpret=interpret)
    return x_p[:n]
