"""Fused on-device FM pass loop (one Pallas kernel per bucket dispatch).

The hoisted path (``core.fm.fm_refine_multi``) traces the pass loop in
Python: each pass is a batched gain recompute plus a vmapped move loop,
unrolled ``passes`` times into one XLA program.  This kernel puts the
pass loop itself on device — grid ``(L,)``, one lane per FM instance,
with the per-lane ``(part, w0, w1, best)`` state resident in VMEM across
all passes:

    HBM:   nbr[l]  vwgt[l]  part0[l]  locked[l]  noise[l]  scalars[l]
             │ (Pallas grid pipeline: lane l+1's blocks stream in while
             ▼  lane l computes — automatic double-buffering)
    VMEM:  ┌────────────────────────────────────────────────┐
           │ fori_loop over passes:                         │
           │   gain recompute (take-based, O(n·d), local)   │
           │   while_loop moves (select → apply → best)     │
           │ state (part, pulled0/1, w0, w1, best) resident │
           └────────────────────────────────────────────────┘
             ▼
    HBM:   bpart[l]  sep_w[l]  imb[l]

Move budgets are **adaptive per lane**: ``max_moves`` rides in as lane
data (an ``(L, 1)`` input), so each lane's move loop terminates at its
own budget — lanes with small budgets are not serialized behind large
ones, and ``FMWork.bucket_key`` no longer needs the pow2 ``max_moves``
sub-bucket (fewer buckets ⇒ fewer compiles, wider lane stacks).

Bit-parity contract: per-pass tiebreak noise is precomputed outside the
kernel (``fm_noise``) with the exact op sequence of the hoisted path —
``jax.random`` cannot run inside a Mosaic kernel — and every float sum
here is over integer-valued float32 vertex weights, hence exact in any
reduction order.  The kernel is therefore bit-identical to the hoisted
path and to the jnp oracle (``kernels.ref.fm_fused_ref``), asserted
across the bucketing space in ``tests/test_fm_fused.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -jnp.inf
BIG_NOISE = 1e9


def fm_move_loop(nbrs, valid, vwgt_f, locked, eps_abs, part, pulled0,
                 pulled1, w0, w1, ws, bpart, bws, bimb, noise, pert,
                 max_moves, pos_only: bool = False):
    """One FM pass (a bounded sequence of moves) on a single lane.

    The per-lane data-plane primitive shared by the hoisted path (under
    ``jax.vmap`` in ``core.fm.fm_refine_multi``) and the fused kernel
    (called per grid lane inside ``_fm_fused_kernel``) — one definition,
    so the two paths cannot drift.
    """
    n, d = nbrs.shape

    def move_cond(carry):
        i, alive, *_ = carry
        return (i < max_moves) & alive

    def move_body(carry):
        """One FM move.  ``pulled0/1`` are maintained incrementally:
        selection is O(n) vector ops, the update is O(d²) scatters —
        (beyond-paper optimization vs the naive O(n·d) gain recompute)."""
        (i, alive, part, moved, pulled0, pulled1,
         w0, w1, ws, bpart, bws, bimb) = carry
        gain0 = vwgt_f - pulled0
        gain1 = vwgt_f - pulled1
        # --- feasibility (balance after move)
        imb = jnp.abs(w0 - w1)
        imb0 = jnp.abs((w0 + vwgt_f) - (w1 - pulled0))
        imb1 = jnp.abs((w0 - pulled1) - (w1 + vwgt_f))
        feas0 = imb0 <= jnp.maximum(eps_abs, imb)
        feas1 = imb1 <= jnp.maximum(eps_abs, imb)
        movable = (part == 2) & ~moved & ~locked
        amp = jnp.where(i < pert, BIG_NOISE, 1e-3)
        ok0, ok1 = movable & feas0, movable & feas1
        if pos_only:                    # ParMETIS-style strict improvement
            ok0, ok1 = ok0 & (gain0 > 0), ok1 & (gain1 > 0)
        s0 = jnp.where(ok0, gain0 + noise[0] * amp, NEG_INF)
        s1 = jnp.where(ok1, gain1 + noise[1] * amp, NEG_INF)
        scores = jnp.concatenate([s0, s1])
        idx = jnp.argmax(scores)
        ok = scores[idx] > NEG_INF
        side = (idx >= n).astype(part.dtype)
        v = (idx % n).astype(jnp.int32)
        # --- apply (masked; no-op when not ok)
        nv = nbrs[v]                                        # (d,)
        nvalid = valid[v]
        pull_slot = nvalid & (part[nv] == (1 - side)) & ok  # pulled set ⊆ N(v)
        pulled_w = jnp.sum(jnp.where(pull_slot, vwgt_f[nv], 0.0))
        # part updates
        tgt_pull = jnp.where(pull_slot, nv, n)
        part = part.at[tgt_pull].set(2, mode="drop")
        part = part.at[v].set(jnp.where(ok, side, part[v]))
        # pulled0/1 updates from v's side change (v: 2 -> side)
        tgt_v = jnp.where(nvalid & ok, nv, n)
        dv_w = vwgt_f[v]
        pulled0 = pulled0.at[tgt_v].add(
            jnp.where(side == 1, dv_w, 0.0), mode="drop")
        pulled1 = pulled1.at[tgt_v].add(
            jnp.where(side == 0, dv_w, 0.0), mode="drop")
        # pulled0/1 updates from the pulled set (u: 1-side -> 2)
        rows = nbrs[nv]                                     # (d, d)
        rvalid = valid[nv] & pull_slot[:, None]
        tgt_u = jnp.where(rvalid, rows, n).reshape(-1)
        amt = jnp.broadcast_to(vwgt_f[nv][:, None], rows.shape)
        amt = jnp.where(rvalid, amt, 0.0).reshape(-1)
        pulled0 = pulled0.at[tgt_u].add(
            jnp.where(side == 0, -amt, 0.0), mode="drop")
        pulled1 = pulled1.at[tgt_u].add(
            jnp.where(side == 1, -amt, 0.0), mode="drop")
        # weights
        dv = jnp.where(ok, dv_w, 0.0)
        w0 = w0 + jnp.where(side == 0, dv, 0.0) - jnp.where(side == 1, pulled_w, 0.0)
        w1 = w1 + jnp.where(side == 1, dv, 0.0) - jnp.where(side == 0, pulled_w, 0.0)
        ws = ws - dv + pulled_w
        moved = moved.at[v].set(moved[v] | ok)
        # --- best-seen tracking (feasible states only)
        imb_new = jnp.abs(w0 - w1)
        better = (ws < bws) & (imb_new <= jnp.maximum(eps_abs, bimb))
        bpart = jnp.where(better, part, bpart)
        bws = jnp.where(better, ws, bws)
        bimb = jnp.where(better, jnp.minimum(imb_new, bimb), bimb)
        return (i + 1, ok, part, moved, pulled0, pulled1,
                w0, w1, ws, bpart, bws, bimb)

    moved = jnp.zeros(n, bool)
    carry = (jnp.int32(0), jnp.bool_(True), part, moved, pulled0,
             pulled1, w0, w1, ws, bpart, bws, bimb)
    carry = jax.lax.while_loop(move_cond, move_body, carry)
    (_, _, part, _, _, _, w0, w1, ws, bpart, bws, bimb) = carry
    return part, w0, w1, ws, bpart, bws, bimb


def fm_noise(keys, n: int, passes: int) -> jax.Array:
    """Per-pass tiebreak noise for all lanes: (L, passes, 2, n).

    Exactly the key-split / uniform op sequence of the hoisted pass loop
    (split once per pass, draw (2, n) from the subkey), hoisted out of
    the kernel because ``jax.random`` cannot run inside Mosaic — values
    are bit-identical to what ``fm_refine_multi`` draws per pass.
    """
    noises = []
    for _ in range(passes):
        both = jax.vmap(jax.random.split)(keys)             # (L, 2, 2)
        keys, subs = both[:, 0], both[:, 1]
        noises.append(jax.vmap(lambda k: jax.random.uniform(k, (2, n)))(subs))
    return jnp.stack(noises, axis=1)


def _fm_fused_kernel(nbr_ref, vwgt_ref, part_ref, locked_ref, noise_ref,
                     eps_ref, mm_ref, np_ref, part_out, bws_out, bimb_out,
                     *, passes, pos_only):
    nbr = nbr_ref[0]                          # (n, d) int32, lane-resident
    n, d = nbr.shape
    valid = nbr >= 0
    nbrs = jnp.where(valid, nbr, 0)
    vwgt_f = vwgt_ref[0]                      # (n,) f32
    locked = locked_ref[0] != 0
    noise_all = noise_ref[0]                  # (passes, 2, n)
    eps_abs = eps_ref[0, 0]                   # per-lane scalars ride as
    max_moves = mm_ref[0, 0]                  # (1, 1) blocks (adaptive
    n_pert = np_ref[0, 0]                     # budget = lane data)
    part = part_ref[0]                        # (n,) int32

    def sums(part):
        w0 = jnp.sum(vwgt_f * (part == 0))
        w1 = jnp.sum(vwgt_f * (part == 1))
        ws = jnp.sum(vwgt_f * (part == 2))
        return w0, w1, ws

    w0, w1, ws = sums(part)
    bpart, bws, bimb = part, ws, jnp.abs(w0 - w1)

    def pass_body(p, carry):
        part, w0, w1, ws, bpart, bws, bimb = carry
        noise = jax.lax.dynamic_index_in_dim(noise_all, p, 0,
                                             keepdims=False)   # (2, n)
        pert = jnp.where(p == 0, n_pert, 0)    # perturb pass 1 only
        # gain recompute, VMEM-local (same math as sep_gain_multi)
        flat = nbrs.reshape(-1)
        pn = jnp.take(part, flat, axis=0).reshape(nbr.shape)
        wn = jnp.take(vwgt_f, flat, axis=0).reshape(nbr.shape)
        wn = jnp.where(valid, wn, 0.0)
        pulled0 = jnp.sum(wn * (pn == 1), axis=1)
        pulled1 = jnp.sum(wn * (pn == 0), axis=1)
        (part, w0, w1, ws, bpart, bws, bimb) = fm_move_loop(
            nbrs, valid, vwgt_f, locked, eps_abs, part, pulled0, pulled1,
            w0, w1, ws, bpart, bws, bimb, noise, pert, max_moves,
            pos_only=pos_only)
        part = bpart                           # revert to best
        w0, w1, ws = sums(part)
        return (part, w0, w1, ws, bpart, bws, bimb)

    carry = (part, w0, w1, ws, bpart, bws, bimb)
    carry = jax.lax.fori_loop(0, passes, pass_body, carry)
    (part, w0, w1, ws, bpart, bws, bimb) = carry
    part_out[0] = bpart
    bws_out[0, 0] = bws
    bimb_out[0, 0] = bimb


@functools.partial(jax.jit, static_argnames=("passes", "pos_only",
                                             "interpret"))
def fm_fused_multi(nbr, vwgt, parts_init, locked, keys, eps_frac,
                   max_moves, n_pert, passes: int = 3,
                   pos_only: bool = False, interpret: bool = True):
    """Fused FM over a flat lane axis — the on-device pass loop.

    Same contract and shapes as ``core.fm.fm_refine_multi`` (L = lanes):
    nbr (L, n, d) int32; vwgt (L, n); parts_init (L, n) int8; locked
    (L, n) bool; keys (L, 2) uint32; eps_frac (L,) f32; max_moves,
    n_pert (L,) int32.  Returns (parts int8, sep_w, imb), bit-identical
    to the hoisted path.  The compiled program does not depend on
    ``max_moves`` (traced lane data), so works with different budgets
    share one executable.
    """
    L, n, d = nbr.shape
    vwgt_f = vwgt.astype(jnp.float32)
    eps_abs = eps_frac.astype(jnp.float32) * vwgt_f.sum(axis=1)
    noise = fm_noise(keys, n, passes)                       # (L, passes, 2, n)
    parts, bws, bimb = pl.pallas_call(
        functools.partial(_fm_fused_kernel, passes=passes,
                          pos_only=pos_only),
        grid=(L,),
        in_specs=[
            pl.BlockSpec((1, n, d), lambda l: (l, 0, 0)),
            pl.BlockSpec((1, n), lambda l: (l, 0)),
            pl.BlockSpec((1, n), lambda l: (l, 0)),
            pl.BlockSpec((1, n), lambda l: (l, 0)),
            pl.BlockSpec((1, passes, 2, n), lambda l: (l, 0, 0, 0)),
            pl.BlockSpec((1, 1), lambda l: (l, 0)),
            pl.BlockSpec((1, 1), lambda l: (l, 0)),
            pl.BlockSpec((1, 1), lambda l: (l, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda l: (l, 0)),
            pl.BlockSpec((1, 1), lambda l: (l, 0)),
            pl.BlockSpec((1, 1), lambda l: (l, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, n), jnp.int32),
            jax.ShapeDtypeStruct((L, 1), jnp.float32),
            jax.ShapeDtypeStruct((L, 1), jnp.float32),
        ],
        interpret=interpret,
    )(nbr, vwgt_f, parts_init.astype(jnp.int32),
      locked.astype(jnp.int32), noise,
      eps_abs[:, None], max_moves[:, None], n_pert[:, None])
    return parts.astype(jnp.int8), bws[:, 0], bimb[:, 0]
