"""Pallas TPU kernels for the *bucketed* ordering service (DESIGN.md §3).

The service executes the separator pipeline breadth-first over every ND
node at the same depth, so its two hot loops see a whole bucket of graphs
at once instead of one:

* ``bfs_multi``      — band-distance sweep (paper §3.3: "spreading distance
  information from all of the separator vertices") for L graphs in one
  launch.  Grid = (L,); each step keeps one graph's ELL tile and distance
  vector resident in VMEM and runs all ``width`` min-plus relaxations
  locally, instead of ``width`` HBM round-trips per graph per step.
* ``sep_gain_multi`` — the O(n·d) separator gain recompute (``pulled``
  weights: for each vertex, the neighbor weight it would drag into the
  separator from either side) for all lanes of an FM bucket.  Grid =
  (L, row-blocks); the per-lane ``part`` / ``vwgt`` vectors stay resident
  so the neighbor gathers are VMEM-local, mirroring ``ell_spmv``.

Both kernels are reduction-order identical to their jnp references
(``repro.kernels.ref``), so CPU hosts can run the fused-XLA path while TPU
runs Mosaic with bit-equal results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

UNREACH = 2 ** 30                     # plain int: inlined into kernel bodies


def _bfs_kernel(nbr_ref, src_ref, dist_ref, *, width):
    nbr = nbr_ref[0]                          # (n, d) int32
    src = src_ref[0] != 0                     # (n,)
    valid = nbr >= 0
    idx = jnp.where(valid, nbr, 0)
    dist = jnp.where(src, 0, UNREACH).astype(jnp.int32)
    for _ in range(width):
        dn = jnp.where(valid,
                       jnp.take(dist, idx.reshape(-1), axis=0
                                ).reshape(nbr.shape),
                       UNREACH)
        dist = jnp.minimum(dist, jnp.min(dn, axis=1) + 1)
    dist_ref[0] = dist


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def bfs_multi(nbr: jax.Array, src: jax.Array, width: int,
              interpret: bool = True) -> jax.Array:
    """dist[l, v] = min(distance in graph l from src_l, width+1).

    Args:
      nbr: (L, n, d) int32 ELL neighbor ids (-1 = padding).
      src: (L, n) int32 (nonzero = source vertex).
      width: number of relaxation steps (band half-width).
      interpret: Python/XLA execution of the kernel body (CPU hosts).
    """
    L, n, d = nbr.shape
    return pl.pallas_call(
        functools.partial(_bfs_kernel, width=width),
        grid=(L,),
        in_specs=[
            pl.BlockSpec((1, n, d), lambda l: (l, 0, 0)),
            pl.BlockSpec((1, n), lambda l: (l, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda l: (l, 0)),
        out_shape=jax.ShapeDtypeStruct((L, n), jnp.int32),
        interpret=interpret,
    )(nbr, src)


def _gain_kernel(nbr_ref, vwgt_ref, part_ref, p0_ref, p1_ref):
    nbr = nbr_ref[0]                          # (bn, d) int32 row tile
    vwgt = vwgt_ref[0]                        # (n,)  f32, lane-resident
    part = part_ref[0]                        # (n,)  int32, lane-resident
    valid = nbr >= 0
    idx = jnp.where(valid, nbr, 0)
    flat = idx.reshape(-1)
    pn = jnp.take(part, flat, axis=0).reshape(nbr.shape)
    wn = jnp.take(vwgt, flat, axis=0).reshape(nbr.shape)
    wn = jnp.where(valid, wn, 0.0)
    p0_ref[0] = jnp.sum(wn * (pn == 1), axis=1)
    p1_ref[0] = jnp.sum(wn * (pn == 0), axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sep_gain_multi(nbr: jax.Array, vwgt: jax.Array, part: jax.Array,
                   block_rows: int = 256, interpret: bool = True):
    """Batched separator FM gains: (pulled_to0, pulled_to1), each (L, n).

    pulled_to0[l, v] = Σ vwgt[l, u] over u ∈ N(v) with part[l, u] == 1 —
    the weight a move of v to side 0 would pull into the separator (and
    symmetrically for side 1).  Gain of the move is vwgt[v] − pulled.

    Args:
      nbr:  (L, n, d) int32 ELL neighbor ids (-1 = padding).
      vwgt: (L, n) float32 vertex weights (0 on padded rows).
      part: (L, n) int32 state per vertex (0/1/2=separator/3=padding).
    """
    L, n, d = nbr.shape
    bn = min(block_rows, n)
    assert n % bn == 0, "caller pads rows to a power of two"
    grid = (L, n // bn)
    p0, p1 = pl.pallas_call(
        _gain_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, d), lambda l, i: (l, i, 0)),
            pl.BlockSpec((1, n), lambda l, i: (l, 0)),      # vwgt resident
            pl.BlockSpec((1, n), lambda l, i: (l, 0)),      # part resident
        ],
        out_specs=[
            pl.BlockSpec((1, bn), lambda l, i: (l, i)),
            pl.BlockSpec((1, bn), lambda l, i: (l, i)),
        ],
        out_shape=[jax.ShapeDtypeStruct((L, n), jnp.float32),
                   jax.ShapeDtypeStruct((L, n), jnp.float32)],
        interpret=interpret,
    )(nbr, vwgt.astype(jnp.float32), part.astype(jnp.int32))
    return p0, p1
