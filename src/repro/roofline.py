"""Three-term roofline analysis from compiled dry-run artifacts.

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = Σ per-collective ring-model bytes / link_bw

Collective bytes are NOT in cost_analysis: we parse the optimized HLO and
apply ring-transfer factors per op kind (bytes a single chip must push
through its ICI links):

    all-gather      result_bytes · (G−1)/G
    reduce-scatter  operand_bytes · (G−1)/G
    all-reduce      2 · operand_bytes · (G−1)/G   (RS + AG)
    all-to-all      operand_bytes · (G−1)/G
    collective-permute  operand_bytes

Hardware constants (TPU v5e-class): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:%\S+\s*=\s*)?(.*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_moved: Dict[str, float]       # ring-model per-chip bytes

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_moved.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    moved: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        kind = m.group(2)
        if "-done(" in line:            # count start ops only (async pairs)
            continue
        # group size
        g = 0
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        g = max(g, 2)
        factor = (g - 1) / g
        # result shape = first shape on the line (lhs), operands inside parens
        lhs = line.split("=", 1)[0] if "=" in line else ""
        result_b = _shape_bytes(lhs) or _shape_bytes(line.split("(")[0])
        args = line.split("(", 1)[1] if "(" in line else ""
        operand_b = _shape_bytes(args.split(")", 1)[0])
        if kind == "all-gather":
            b = result_b * factor
        elif kind == "all-reduce":
            b = 2 * (operand_b or result_b) * factor
        elif kind == "reduce-scatter":
            b = (operand_b or result_b) * factor
        elif kind == "all-to-all":
            b = (operand_b or result_b) * factor
        else:                            # collective-permute
            b = operand_b or result_b
        counts[kind] = counts.get(kind, 0) + 1
        moved[kind] = moved.get(kind, 0.0) + b
    return CollectiveStats(counts, moved)


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_detail: Dict[str, float]
    coll_counts: Dict[str, int]
    peak_mem_bytes: float

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_detail": self.coll_detail,
            "coll_counts": self.coll_counts,
            "peak_mem_bytes": self.peak_mem_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


_MEM_RE = re.compile(r"(\d+)")


def analyze_compiled(compiled) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    try:
        mem = compiled.memory_analysis()
        peak = float(getattr(mem, "temp_size_in_bytes", 0)
                     + getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "output_size_in_bytes", 0)
                     - getattr(mem, "alias_size_in_bytes", 0))
    except Exception:
        peak = 0.0
    return Roofline(flops, byts, coll.total_bytes, coll.bytes_moved,
                    coll.counts, peak)


def model_flops(cfg, shape: dict) -> float:
    """6·N_active·tokens (train) or 2·N_active·tokens (single fwd/decode)."""
    n_active = cfg.active_param_count()
    if shape["kind"] == "train":
        toks = shape["global_batch"] * shape["seq_len"]
        return 6.0 * n_active * toks
    if shape["kind"] == "prefill":
        toks = shape["global_batch"] * shape["seq_len"]
        return 2.0 * n_active * toks
    return 2.0 * n_active * shape["global_batch"]       # decode: 1 tok/seq
