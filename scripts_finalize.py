"""Final assembly: merge whisper re-runs, enrich, render tables into
EXPERIMENTS.md placeholders.

    PYTHONPATH=src python scripts_finalize.py
"""
import io
import json
import os
import sys
from contextlib import redirect_stdout

sys.path.insert(0, "src")

from repro.launch.enrich import enrich               # noqa: E402
from repro.launch.report import dryrun_table, roofline_table  # noqa: E402

MAIN = "dryrun_report.json"
WHISPER = "/tmp/whisper_cells.json"

records = json.load(open(MAIN))
if os.path.exists(WHISPER):
    fixed = {(r["arch"], r["shape"], r["mesh"]): r
             for r in json.load(open(WHISPER)) if r["status"] == "OK"}
    out = []
    for r in records:
        key = (r["arch"], r["shape"], r["mesh"])
        if key in fixed:
            if r["status"] == "FAIL":
                out.append(fixed.pop(key))     # replace failed cell
            else:
                out.append(r)                  # keep original OK
                fixed.pop(key)
        else:
            out.append(r)
    out.extend(fixed.values())                 # genuinely new cells
    records = out
records = enrich(records)
json.dump(records, open(MAIN, "w"), indent=1)

dry = dryrun_table(records)
roof_s = roofline_table(records, "single")
roof_m = roofline_table(records, "multi")

exp = open("EXPERIMENTS.md").read()
exp = exp.replace("<!-- DRYRUN_TABLE -->", dry)
exp = exp.replace("<!-- ROOFLINE_TABLE -->",
                  "### Single-pod (16×16 = 256 chips)\n\n" + roof_s
                  + "\n\n### Multi-pod (2×16×16 = 512 chips)\n\n" + roof_m)
open("EXPERIMENTS.md", "w").write(exp)
n_ok = sum(r["status"] == "OK" for r in records)
n_skip = sum(r["status"] == "SKIP" for r in records)
n_fail = sum(r["status"] == "FAIL" for r in records)
print(f"finalized: {n_ok} OK / {n_skip} SKIP / {n_fail} FAIL "
      f"({len(records)} records)")
