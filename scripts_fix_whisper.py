"""Remove FAIL records from dryrun_report.json so --append re-runs them."""
import json
import sys

path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_report.json"
records = json.load(open(path))
keep = [r for r in records if r["status"] != "FAIL"]
print(f"dropping {len(records) - len(keep)} FAIL records")
json.dump(keep, open(path, "w"), indent=1)
