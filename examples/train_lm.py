"""End-to-end training driver (deliverable (b)): train a ~100M-param LM for
a few hundred steps on synthetic data with checkpoint/restart.

Quick demo (reduced ~1M params, 60 steps):
    PYTHONPATH=src python examples/train_lm.py

The ~100M run used for EXPERIMENTS.md (mamba2-130m at 3/4 width ≈ 100M,
300 steps — several CPU-hours; run it when you mean it):
    PYTHONPATH=src python examples/train_lm.py --full
"""
import subprocess
import sys


def main():
    full = "--full" in sys.argv
    args = [sys.executable, "-m", "repro.launch.train",
            "--arch", "mamba2-130m",
            "--ckpt", "/tmp/repro_train_ckpt",
            "--ckpt-every", "50"]
    if full:
        # full mamba2-130m config (~130M params), a few hundred steps
        args += ["--steps", "300", "--batch", "8", "--seq", "512",
                 "--lr", "3e-4", "--log-every", "10"]
    else:
        args += ["--reduced", "--steps", "60", "--batch", "8",
                 "--seq", "128", "--lr", "1e-3", "--log-every", "5"]
    print("+", " ".join(args[1:]))
    raise SystemExit(subprocess.call(args))


if __name__ == "__main__":
    main()
