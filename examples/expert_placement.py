"""The paper's technique as a framework feature: Scotch static mapping
places MoE experts across pods to cut inter-pod all-to-all traffic.

    PYTHONPATH=src python examples/expert_placement.py --arch arctic-480b

Expert co-activation (which experts fire together for the same token) is
clustered in practice; recursive-bisection mapping (core/mapping.py) packs
co-firing experts into the same pod, so the expensive inter-pod hop only
carries the residual cross-cluster traffic.
"""
import argparse

import numpy as np

from repro.configs.base import get_config
from repro.core.graph import Graph
from repro.core.mapping import DeviceTier, expert_placement, traffic_cost


def synth_coactivation(E: int, n_clusters: int, seed: int = 0) -> np.ndarray:
    """Synthetic clustered co-activation (semantic expert specialization)."""
    rng = np.random.default_rng(seed)
    co = rng.random((E, E)) * 0.05
    sizes = np.full(n_clusters, E // n_clusters)
    sizes[:E % n_clusters] += 1
    lo = 0
    for s in sizes:
        co[lo:lo + s, lo:lo + s] += rng.random((s, s)) * 1.0 + 0.5
        lo += s
    return (co + co.T) / 2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="arctic-480b")
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--chips-per-pod", type=int, default=8)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    E = cfg.n_experts or 16
    co = synth_coactivation(E, n_clusters=args.pods * 2)
    assign = expert_placement(co, args.pods, args.chips_per_pod,
                              inter_pod_cost=10.0, seed=0)
    # cost accounting
    iu, ju = np.nonzero(np.triu(co, 1))
    w = np.maximum((co[iu, ju] / co.max() * 1000).astype(np.int64), 1)
    g = Graph.from_edges(E, np.stack([iu, ju], 1), ewgt=w)
    tiers = [DeviceTier(args.pods, 10.0),
             DeviceTier(args.chips_per_pod, 1.0)]
    c_scotch = traffic_cost(g, assign, tiers)
    rng = np.random.default_rng(1)
    c_rand = np.mean([traffic_cost(
        g, rng.integers(0, args.pods * args.chips_per_pod, E), tiers)
        for _ in range(10)])
    c_naive = traffic_cost(
        g, np.arange(E) % (args.pods * args.chips_per_pod), tiers)
    print(f"arch={cfg.name}: {E} experts -> "
          f"{args.pods} pods × {args.chips_per_pod} chips")
    print(f"  round-robin placement cost : {c_naive:12.0f}")
    print(f"  random placement cost      : {c_rand:12.0f}")
    print(f"  scotch mapping cost        : {c_scotch:12.0f}  "
          f"({c_rand / c_scotch:.2f}× better than random)")
    per_dev = np.bincount(assign, minlength=args.pods * args.chips_per_pod)
    print(f"  experts/device: min={per_dev.min()} max={per_dev.max()}")


if __name__ == "__main__":
    main()
