"""Serve a stream of ordering requests through the batched service.

    PYTHONPATH=src python examples/serve_orderings.py

Submits a mixed batch of FE-mesh / circuit analog graphs, drains the queue
once (all separator subproblems across all graphs execute as bucketed vmap
batches), then replays the stream to show fingerprint-cache hits resolving
in microseconds.
"""
import numpy as np

from repro.graphs.generators import circuit, grid2d, grid3d
from repro.service import OrderingService
from repro.sparse.symbolic import nnz_opc
from repro.util import enable_compile_cache


def main():
    enable_compile_cache()
    graphs = {
        "mesh2d-A": grid2d(16, 16),
        "mesh3d":   grid3d(7, 7, 7),
        "mesh2d-B": grid2d(20, 12),
        "circuit":  circuit(500, seed=7),
    }
    svc = OrderingService()

    print("— submit + drain (batched breadth-first execution) —")
    rids = {name: svc.submit(g, seed=0, nproc=16)
            for name, g in graphs.items()}
    assert svc.poll(rids["mesh2d-A"]) is None      # queued, not yet ordered
    svc.drain()
    for name, g in graphs.items():
        res = svc.poll(rids[name])
        nnz, opc = nnz_opc(g, res.perm)
        print(f"{name:10s} |V|={g.n:5d}  OPC={opc:.3e}  "
              f"latency={res.latency_s * 1e3:8.1f} ms  cached={res.cached}")

    print("\n— replay the same stream (fingerprint-cache hits) —")
    for name, g in graphs.items():
        rid = svc.submit(g, seed=0, nproc=16)
        res = svc.poll(rid)                        # resolved at submit time
        assert res.cached
        assert np.array_equal(res.perm, svc.poll(rids[name]).perm)
        print(f"{name:10s} cache hit, latency={res.latency_s * 1e6:6.0f} µs")

    print("\nservice stats:")
    for k, v in svc.stats().items():
        print(f"  {k:20s} {v}")


if __name__ == "__main__":
    main()
