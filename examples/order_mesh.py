"""Parallel ordering scaling demo + the distributed data structure at work.

    PYTHONPATH=src python examples/order_mesh.py

Part 1 sweeps the simulated process count and shows the paper's headline
result: PT-Scotch ordering quality is stable (or improves) with p while the
ParMETIS-like baseline degrades.  Part 2 runs the halo-exchange/BFS data
plane over an 8-way shard_map mesh (host devices).
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.baselines import parmetis_like, pt_scotch_like
from repro.core.dgraph import distribute, distributed_bfs
from repro.graphs.generators import grid3d
from repro.sparse.symbolic import nnz_opc
from repro.util import enable_compile_cache


def main():
    enable_compile_cache()
    g = grid3d(10, 10, 10)
    print(f"graph: |V|={g.n} |E|={g.m}")
    print(f"{'p':>4} {'O_PTS':>12} {'O_PM':>12} {'PM/PTS':>7}")
    o_ref = None
    for p in (2, 8, 32):
        o_pts = nnz_opc(g, pt_scotch_like(g, seed=0, nproc=p))[1]
        o_pm = nnz_opc(g, parmetis_like(g, seed=0, nproc=p))[1]
        if p == 8:
            o_ref = o_pts
        print(f"{p:>4} {o_pts:>12.3e} {o_pm:>12.3e} {o_pm/o_pts:>7.2f}")

    print("\ndistributed band-BFS over 8 shards (halo exchange/shard_map):")
    dg = distribute(g, 8)
    src = np.zeros((8, dg.n_loc_max), bool)
    src[0, 0] = True
    t0 = time.time()
    dist = distributed_bfs(dg, src, width=3)
    n_band = int((dist <= 3).sum())
    print(f"  band(width=3) holds {n_band} vertices "
          f"({time.time()-t0:.2f}s, {dg.nparts} shards, "
          f"ghosts/shard max {int(dg.n_ghost.max())})")

    print("\nend-to-end distributed nested dissection (8 shards):")
    from repro.core.dnd import distributed_nested_dissection
    t0 = time.time()
    perm = distributed_nested_dissection(dg, seed=0)
    opc = nnz_opc(g, perm)[1]
    print(f"  OPC {opc:.3e} in {time.time()-t0:.1f}s "
          f"(host nproc=8 reference above: {o_ref:.3e})")


if __name__ == "__main__":
    main()
