"""Quickstart: order a 3D FE-mesh-like graph with the PT-Scotch pipeline.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's full flow — multilevel coarsening with fold-dup, greedy
initial separators, band extraction (width 3), multi-sequential FM — and
compares OPC/NNZ against natural order, minimum degree, and the
ParMETIS-like strict-refinement baseline.
"""
import time

import numpy as np

from repro.core.baselines import (mindeg_ordering, natural, parmetis_like,
                                  pt_scotch_like)
from repro.core.nd import NDConfig
from repro.graphs.generators import grid3d
from repro.sparse.symbolic import nnz_opc
from repro.util import enable_compile_cache


def main():
    enable_compile_cache()
    g = grid3d(12, 12, 12)
    print(f"graph: 12×12×12 grid  |V|={g.n}  |E|={g.m}")
    rows = []
    for name, fn in [
        ("natural", lambda: natural(g)),
        ("minimum-degree", lambda: mindeg_ordering(g)),
        ("parmetis-like p=16", lambda: parmetis_like(g, seed=0, nproc=16)),
        ("pt-scotch p=16", lambda: pt_scotch_like(g, seed=0, nproc=16)),
        ("pt-scotch p=16 (no band)",
         lambda: pt_scotch_like(g, seed=0, nproc=16,
                                cfg=NDConfig(use_band=False))),
    ]:
        t0 = time.time()
        perm = fn()
        dt = time.time() - t0
        nnz, opc = nnz_opc(g, perm)
        rows.append((name, nnz, opc, dt))
        print(f"{name:28s} NNZ={nnz:>9,}  OPC={opc:.3e}  ({dt:.1f}s)")
    base = rows[0][2]
    best = min(r[2] for r in rows[1:])
    print(f"\nfill-reducing orderings cut OPC by "
          f"{base / best:.1f}× vs natural order")


if __name__ == "__main__":
    main()
