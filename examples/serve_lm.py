"""Batched serving demo: prefill + greedy decode with per-layer caches.

    PYTHONPATH=src python examples/serve_lm.py --arch jamba-v0.1-52b
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.lm import init_params
from repro.serve.engine import greedy_generate
from repro.util import enable_compile_cache


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()
    enable_compile_cache()

    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    t0 = time.time()
    out = greedy_generate(params, cfg, prompt, args.new_tokens,
                          s_max=args.prompt_len + args.new_tokens)
    dt = time.time() - t0
    toks = args.batch * args.new_tokens
    print(f"arch={cfg.name} (reduced) batch={args.batch}")
    print(f"generated {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print("sample continuation ids:", np.asarray(out)[0][:12].tolist())


if __name__ == "__main__":
    main()
