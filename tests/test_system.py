"""End-to-end behaviour tests for the paper's system.

The headline claims of PT-Scotch, on a 3D-mesh analog of the paper's test
graphs, end to end through the public API:
  1. quality does not degrade as the (simulated) process count grows;
  2. the ParMETIS-like baseline degrades with process count and is beaten;
  3. orderings are deterministic for a fixed seed (paper §4);
  4. OPC scales like the theory for nested dissection on 3D meshes.
"""
import numpy as np
import pytest

from repro.core.baselines import parmetis_like, pt_scotch_like
from repro.graphs.generators import grid3d
from repro.sparse.symbolic import nnz_opc


@pytest.fixture(scope="module")
def g():
    return grid3d(9, 9, 9)


@pytest.fixture(scope="module")
def opc_by_p(g):
    return {p: nnz_opc(g, pt_scotch_like(g, seed=2, nproc=p))[1]
            for p in (1, 8, 64)}


def test_quality_stable_with_procs(opc_by_p):
    vals = list(opc_by_p.values())
    assert max(vals) <= min(vals) * 1.25


def test_beats_parmetis_like_at_scale(g, opc_by_p):
    o_pm = nnz_opc(g, parmetis_like(g, seed=2, nproc=64))[1]
    assert o_pm > 1.5 * opc_by_p[64]       # paper: up to ~2x at p=64


def test_deterministic_fixed_seed(g):
    p1 = pt_scotch_like(g, seed=7, nproc=8)
    p2 = pt_scotch_like(g, seed=7, nproc=8)
    assert np.array_equal(p1, p2)


def test_opc_scaling_3d():
    """ND on an n-vertex 3D mesh: OPC = O(n^2) (separator O(n^{2/3}),
    dense frontal O(sep^3) = O(n^2)); natural order is far worse."""
    small, large = grid3d(6, 6, 6), grid3d(12, 12, 12)
    o_s = nnz_opc(small, pt_scotch_like(small, seed=0))[1]
    o_l = nnz_opc(large, pt_scotch_like(large, seed=0))[1]
    growth = o_l / o_s
    n_ratio = large.n / small.n               # 8
    assert growth < n_ratio ** 2.6            # clearly sub-natural-order
    o_nat = nnz_opc(large, np.arange(large.n))[1]
    assert o_l < 0.45 * o_nat
