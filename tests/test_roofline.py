"""Roofline machinery tests: HLO collective parsing + analytic flop counter
consistency against XLA cost analysis (single device, no partitioner)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.flopcount import forward_flops
from repro.roofline import parse_collectives, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("bf16[16,512,6144]") == 16 * 512 * 6144 * 2
    assert _shape_bytes("f32[8]{0}") == 32
    assert _shape_bytes("pred[4,4]") == 16
    assert _shape_bytes("(bf16[2,2], f32[2])") == 8 + 8


def test_parse_collectives_ring_model():
    hlo = """
  %ag = bf16[32,1024]{1,0} all-gather(bf16[2,1024]{1,0} %x), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %ar = f32[4096]{0} all-reduce(f32[4096]{0} %y), replica_groups=[16,16]<=[256], to_apply=%add
    """
    st = parse_collectives(hlo)
    assert st.counts == {"all-gather": 1, "all-reduce": 1}
    ag = 32 * 1024 * 2 * (15 / 16)
    ar = 2 * 4096 * 4 * (15 / 16)
    assert abs(st.bytes_moved["all-gather"] - ag) < 1
    assert abs(st.bytes_moved["all-reduce"] - ar) < 1


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-130m",
                                  "deepseek-v2-lite-16b"])
def test_analytic_flops_vs_xla(arch):
    """Unsharded single-device forward: analytic counter within 25% of XLA
    (which is reliable when there are no partitioner/scan loops)."""
    import repro.models.lm as lm
    from repro.models.lm import forward, init_params
    cfg = get_config(arch).reduced()
    params = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.random.PRNGKey(0))
    B, S = 4, 64
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    lm.FORCE_UNROLL = True
    try:
        c = jax.jit(lambda p, b: forward(p, cfg, b)).lower(
            params, batch).compile()
    finally:
        lm.FORCE_UNROLL = False
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):    # older jax: one dict per device
        ca = ca[0]
    xla = float(ca["flops"])
    ours = forward_flops(cfg, B * S, S)
    assert ours == pytest.approx(xla, rel=0.25), (ours, xla)
