"""Ordering service: bucketed execution parity, batched kernels, cache,
end-to-end equivalence with the sequential driver."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.band import (BFSWork, bfs_distance, execute_bfs_works)
from repro.core.fm import FMWork, execute_fm_works, refine_parts
from repro.core.nd import NDConfig, nested_dissection
from repro.graphs import generators as G
from repro.kernels.ops import band_bfs_batch, sep_gain_batch
from repro.kernels.ref import bfs_multi_ref, sep_gain_multi_ref
from repro.service import OrderingService, order_batch
from repro.service.cache import FingerprintCache
from repro.service.fingerprint import graph_fingerprint, request_fingerprint


def _sep_work(g, seed):
    """A valid FM work: grown initial separator on g."""
    from repro.core.initsep import grow_part
    part = grow_part(g, seed)
    nbr, _ = g.to_ell()
    return FMWork(nbr=nbr, vwgt=g.vwgt, part=part,
                  locked=np.zeros(g.n, bool), seed=seed, k_inst=4)


# ------------------------------------------------------------------ #
# bucketed executors == singleton execution
# ------------------------------------------------------------------ #
def test_fm_bucketed_matches_singleton():
    works = [_sep_work(G.grid2d(11, 11), 0),
             _sep_work(G.grid2d(10, 12), 1),       # same bucket as above
             _sep_work(G.grid3d(5, 5, 5), 2),
             _sep_work(G.circuit(100, seed=4), 3)]
    together = execute_fm_works(works)
    alone = [execute_fm_works([w])[0] for w in works]
    for (pa, wa, ia), (pb, wb, ib) in zip(together, alone):
        assert np.array_equal(pa, pb)
        assert wa == wb and ia == ib


def test_refine_parts_unchanged_contract():
    g = G.grid2d(12, 12)
    from repro.core.initsep import grow_part
    part = grow_part(g, 5)
    nbr, _ = g.to_ell()
    out, sep_w, imb = refine_parts(nbr, g.vwgt, part,
                                   np.zeros(g.n, bool), 7)
    assert out.shape == (g.n,)
    assert sep_w == g.vwgt[out == 2].sum()


def test_bfs_bucketed_matches_singleton():
    gs = [G.grid2d(9, 9), G.grid2d(8, 10), G.grid3d(4, 4, 5)]
    works = []
    for i, g in enumerate(gs):
        nbr, _ = g.to_ell()
        src = np.zeros(g.n, bool)
        src[i] = True
        works.append(BFSWork(nbr=nbr, src=src, width=3))
    batched = execute_bfs_works(works)
    for w, dist in zip(works, batched):
        ref = np.asarray(bfs_distance(jnp.asarray(w.nbr),
                                      jnp.asarray(w.src), w.width))
        assert np.array_equal(np.minimum(dist, w.width + 1),
                              np.minimum(ref, w.width + 1))


# ------------------------------------------------------------------ #
# batched Pallas kernels == jnp oracles (interpret mode on CPU)
# ------------------------------------------------------------------ #
def test_bfs_kernel_matches_ref():
    rng = np.random.default_rng(0)
    L, n, d = 4, 64, 8
    nbr = rng.integers(-1, n, (L, n, d)).astype(np.int32)
    src = (rng.random((L, n)) < 0.08).astype(np.int32)
    got = np.asarray(band_bfs_batch(nbr, src, 3))
    want = np.asarray(bfs_multi_ref(jnp.asarray(nbr), jnp.asarray(src), 3))
    assert np.array_equal(got, want)


def test_gain_kernel_matches_ref():
    rng = np.random.default_rng(1)
    L, n, d = 3, 128, 8
    nbr = rng.integers(-1, n, (L, n, d)).astype(np.int32)
    vwgt = rng.integers(1, 6, (L, n)).astype(np.float32)
    part = rng.integers(0, 3, (L, n)).astype(np.int32)
    g0, g1 = sep_gain_batch(nbr, vwgt, part)
    r0, r1 = sep_gain_multi_ref(jnp.asarray(nbr), jnp.asarray(vwgt),
                                jnp.asarray(part))
    assert np.array_equal(np.asarray(g0), np.asarray(r0))
    assert np.array_equal(np.asarray(g1), np.asarray(r1))


def test_fm_pallas_gain_mode_bit_equal():
    w = _sep_work(G.grid2d(12, 12), 3)
    a = execute_fm_works([w], gain_mode="jnp")[0]
    b = execute_fm_works([w], gain_mode="pallas")[0]
    assert np.array_equal(a[0], b[0]) and a[1:] == b[1:]


# ------------------------------------------------------------------ #
# fingerprints + cache
# ------------------------------------------------------------------ #
def test_fingerprint_sensitivity():
    g = G.grid2d(6, 6)
    g2 = G.grid2d(6, 6)
    assert graph_fingerprint(g) == graph_fingerprint(g2)
    cfg = NDConfig()
    fp = request_fingerprint(g, 0, 4, cfg)
    assert request_fingerprint(g, 1, 4, cfg) != fp         # seed
    assert request_fingerprint(g, 0, 8, cfg) != fp         # nproc
    assert request_fingerprint(g, 0, 4, NDConfig(band_width=2)) != fp
    g3 = G.grid2d(6, 6)
    g3.vwgt = g3.vwgt.copy()
    g3.vwgt[0] = 7
    assert graph_fingerprint(g3) != graph_fingerprint(g)   # weights


def test_cache_put_does_not_freeze_caller():
    """Regression: put() used to setflags(write=False) on an aliasing view
    of the caller's array, freezing the submitter's permutation in place."""
    c = FingerprintCache(capacity=4)
    mine = np.arange(6)
    c.put("k", mine)
    assert mine.flags.writeable, "caller's array was frozen by the cache"
    mine[0] = 99                                  # must not raise
    got = c.get("k")
    assert got[0] == 0, "cache entry aliases the caller's array"
    assert not got.flags.writeable                # cached copy stays frozen


def test_cache_lru_and_counters():
    c = FingerprintCache(capacity=2)
    c.put("a", np.arange(3))
    c.put("b", np.arange(4))
    assert c.get("a") is not None                          # a now MRU
    c.put("c", np.arange(5))                               # evicts b
    assert c.get("b") is None
    assert c.get("a") is not None and c.get("c") is not None
    assert c.evictions == 1 and c.hits == 3 and c.misses == 1
    assert 0 < c.hit_rate < 1


# ------------------------------------------------------------------ #
# end to end: scheduler and service vs looped sequential driver
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def mixed_graphs():
    uniq = [G.grid2d(12, 12), G.grid3d(6, 6, 6), G.grid2d(15, 10),
            G.circuit(300, seed=3), G.grid2d(13, 11), G.rgg2d(250, seed=2),
            G.grid3d(5, 5, 6), G.grid2d(11, 14)]
    return uniq


def test_order_batch_matches_sequential(mixed_graphs):
    seeds = list(range(len(mixed_graphs)))
    batched = order_batch(mixed_graphs, seeds, 4)
    for g, s, perm in zip(mixed_graphs, seeds, batched):
        ref = nested_dissection(g, seed=s, nproc=4)
        assert np.array_equal(perm, ref)


def test_service_end_to_end(mixed_graphs):
    svc = OrderingService(cache_capacity=64)
    # ≥16 requests over mixed sizes, with duplicates in the stream
    reqs = []
    for rep in range(2):
        for i, g in enumerate(mixed_graphs):
            reqs.append(svc.submit(g, seed=i, nproc=4))
    assert len(reqs) == 16
    assert svc.poll(reqs[0]) is None                       # still queued
    resolved = svc.drain()
    assert len(resolved) == 16
    st = svc.stats()
    assert st["computed"] == 8                             # dedup coalesced
    # every request got the exact sequential-driver answer
    for i, rid in enumerate(reqs):
        res = svc.poll(rid)
        g, s = mixed_graphs[i % 8], i % 8
        assert np.array_equal(np.sort(res.perm), np.arange(g.n))
        ref = nested_dissection(g, seed=s, nproc=4)
        assert np.array_equal(res.perm, ref)
    # repeated submission afterwards is a cache hit, resolved immediately
    rid = svc.submit(mixed_graphs[0], seed=0, nproc=4)
    res = svc.poll(rid)
    assert res is not None and res.cached
    st = svc.stats()
    assert st["cache_hits"] >= 1
    assert st["p95_latency_ms"] >= st["p50_latency_ms"]
    assert st["orderings_per_sec"] > 0
    assert st["queue_depth"] == 0


def test_latency_split_queue_wait_vs_exec(mixed_graphs):
    """Drained requests report queue wait and execution separately: the
    end-to-end latency decomposes instead of conflating how long the
    request sat in the drain queue with how fast the batch ran."""
    import time
    svc = OrderingService()
    rid0 = svc.submit(mixed_graphs[0], seed=0, nproc=2)
    time.sleep(0.05)                    # measurable queue wait
    rid1 = svc.submit(mixed_graphs[1], seed=1, nproc=2)
    svc.drain()
    for rid in (rid0, rid1):
        res = svc.poll(rid)
        assert res.queue_wait_s >= 0 and res.exec_s > 0
        # wait + shared-batch execution bound the end-to-end latency
        assert res.latency_s >= res.queue_wait_s
        assert res.latency_s >= res.exec_s
    # rid0 waited through the sleep; both shared one batch execution
    assert svc.poll(rid0).queue_wait_s >= 0.05
    assert svc.poll(rid0).exec_s == svc.poll(rid1).exec_s
    # a cache hit has no queue wait — its latency IS the lookup
    rid2 = svc.submit(mixed_graphs[0], seed=0, nproc=2)
    res2 = svc.poll(rid2)
    assert res2.cached and res2.queue_wait_s == 0.0
    st = svc.stats()
    for key in ("p50_queue_wait_ms", "p95_queue_wait_ms",
                "p50_exec_ms", "p95_exec_ms"):
        assert key in st and st[key] >= 0
    assert st["p95_queue_wait_ms"] >= st["p50_queue_wait_ms"]
    assert st["p95_exec_ms"] >= st["p50_exec_ms"]


def test_service_deterministic_across_drains(mixed_graphs):
    g = mixed_graphs[1]
    svc1 = OrderingService()
    svc2 = OrderingService()
    r1 = svc1.submit(g, seed=9, nproc=2)
    r2 = svc2.submit(g, seed=9, nproc=2)
    svc1.drain()
    svc2.drain()
    assert np.array_equal(svc1.poll(r1).perm, svc2.poll(r2).perm)
