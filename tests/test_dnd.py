"""Distributed nested dissection vs the host driver: permutation validity
and quality parity, run in a subprocess with 8 host devices.

The grid case (plus the fixed-seed determinism check) runs by default;
the heavier rgg case is ``slow``-marked and runs in the CI ``spmd`` job
(``--runslow``).
"""
import textwrap

import pytest

from procutil import run_json_script

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core.dgraph import distribute
    from repro.core.dnd import DNDConfig, distributed_nested_dissection
    from repro.core.nd import nested_dissection
    from repro.graphs import generators as G
    from repro.sparse.symbolic import nnz_opc

    out = {{}}
    cfg = DNDConfig(centralize_threshold=200)
    for name, g in [{graphs}]:
        dg = distribute(g, 8)
        perm_d = distributed_nested_dissection(dg, seed=0, cfg=cfg)
        perm_h = nested_dissection(g, seed=0, nproc=8)
        ok_perm = bool(np.array_equal(np.sort(perm_d), np.arange(g.n)))
        ratio = nnz_opc(g, perm_d)[1] / nnz_opc(g, perm_h)[1]
        out[name] = {{"perm": ok_perm, "ratio": round(float(ratio), 4)}}
    if {determinism}:
        # determinism: same dg + seed => identical ordering
        g = G.grid2d(18, 18)
        dg = distribute(g, 8)
        p1 = distributed_nested_dissection(dg, seed=3, cfg=cfg)
        p2 = distributed_nested_dissection(dg, seed=3, cfg=cfg)
        out["deterministic"] = bool(np.array_equal(p1, p2))
    print(json.dumps(out))
""")


def _run(graphs: str, determinism: bool) -> dict:
    script = SCRIPT.format(graphs=graphs, determinism=determinism)
    return run_json_script(script)


def _check_parity(out, names):
    for name in names:
        assert out[name]["perm"], f"{name}: not a permutation"
        # per-graph guard is loose (single-seed noise); the tracked 3%
        # mean-OPC-parity bound lives in benchmarks/dnd_bench.py
        assert out[name]["ratio"] < 1.25, \
            f"{name}: OPC ratio {out[name]['ratio']} vs host"


def test_dnd_vs_host_parity():
    out = _run('("grid2d", G.grid2d(18, 18))', determinism=True)
    assert out["deterministic"], "dnd not deterministic for fixed seed"
    _check_parity(out, ["grid2d"])


@pytest.mark.slow
def test_dnd_vs_host_parity_rgg():
    out = _run('("rgg2d", G.rgg2d(420, seed=2))', determinism=False)
    _check_parity(out, ["rgg2d"])
