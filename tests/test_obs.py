"""Observability subsystem: span-tree well-formedness, chrome-export
round-trip, tracing-on/off bit-parity (host scheduler path inline;
frontier + DFS distributed drivers in a subprocess with virtual
devices), the disabled-overhead budget, metrics registry, and the
trace_summary coverage contract."""
import json
import sys
import textwrap
import threading
import time

import numpy as np

from procutil import run_json_script
from repro import obs


# ------------------------------------------------------------------ #
# span tree
# ------------------------------------------------------------------ #
def _tree_check(spans):
    by_id = {s.span_id: s for s in spans}
    assert len(by_id) == len(spans), "duplicate span ids"
    for s in spans:
        assert s.t1 is not None and s.t1 >= s.t0, f"span {s.name} open"
        if s.parent_id is not None:
            assert s.parent_id in by_id, f"orphan span {s.name}"
            p = by_id[s.parent_id]
            # proper nesting: the child interval sits inside the parent
            assert p.t0 <= s.t0 + 1e-9 and s.t1 <= p.t1 + 1e-9, \
                f"{s.name} escapes parent {p.name}"


def test_span_tree_well_formed_nested_and_threaded():
    with obs.tracing() as tr:
        with tr.span("root", tag="r"):
            with tr.span("child_a"):
                with tr.span("leaf"):
                    pass
            with tr.span("child_b"):
                pass

            def worker():
                # a fresh thread has its own contextvar stack: its spans
                # must not parent onto the main thread's open spans
                with tr.span("thread_root"):
                    with tr.span("thread_leaf"):
                        pass
            t = threading.Thread(target=worker)
            t.start()
            t.join()
    spans = {s.name: s for s in tr.spans}
    assert set(spans) == {"root", "child_a", "child_b", "leaf",
                          "thread_root", "thread_leaf"}
    _tree_check(tr.spans)
    assert spans["child_a"].parent_id == spans["root"].span_id
    assert spans["leaf"].parent_id == spans["child_a"].span_id
    assert spans["thread_root"].parent_id is None
    assert spans["thread_leaf"].parent_id == spans["thread_root"].span_id
    assert spans["thread_root"].tid != spans["root"].tid
    # siblings are monotonic: child_b starts after child_a ends
    assert spans["child_b"].t0 >= spans["child_a"].t1 - 1e-9
    assert spans["root"].attrs["tag"] == "r"


def test_tracing_restores_previous_tracer_and_null_span():
    assert obs.current() is None
    with obs.span("noop") as sp:        # disabled: shared null context
        assert sp is None
    with obs.tracing() as outer:
        with obs.tracing() as inner:
            with obs.span("x"):
                pass
        assert obs.current() is outer
        assert all(s.name != "x" for s in outer.spans)
        assert any(s.name == "x" for s in inner.spans)
    assert obs.current() is None


def test_chrome_export_round_trip(tmp_path):
    with obs.tracing() as tr:
        with tr.span("outer", kind="demo", lanes=3):
            time.sleep(0.002)
            with tr.span("inner"):
                time.sleep(0.001)
    path = str(tmp_path / "trace.json")
    tr.export_chrome(path)
    loaded = obs.load_chrome(path)
    assert len(loaded) == len(tr.spans)
    orig = {s.span_id: s for s in tr.spans}
    base = min(s.t0 for s in tr.spans)
    for s in loaded:
        o = orig[s.span_id]
        assert s.name == o.name and s.parent_id == o.parent_id
        assert abs((s.t1 - s.t0) - (o.t1 - o.t0)) < 2e-6
        assert abs(s.t0 - (o.t0 - base)) < 2e-6
    _tree_check(loaded)
    lo = {s.name: s for s in loaded}
    assert lo["outer"].attrs["kind"] == "demo"
    assert int(lo["outer"].attrs["lanes"]) == 3
    # the file is valid chrome trace_event JSON
    with open(path) as f:
        doc = json.load(f)
    assert all(ev["ph"] == "X" for ev in doc["traceEvents"])


# ------------------------------------------------------------------ #
# bus + first-use tracking + metrics
# ------------------------------------------------------------------ #
def test_first_use_bills_compile_then_dispatch():
    key = ("test-compile-key", id(object()))
    assert obs.first_use(key)
    assert not obs.first_use(key)
    from repro.core.dgraph import instrument
    jit_key = ("test-jit-key", id(object()))
    with instrument() as ins:
        obs.timed_dispatch("teststage", "testkind", jit_key, lambda: 1)
        obs.timed_dispatch("teststage", "testkind", jit_key, lambda: 2)
    d = ins.stage_detail["teststage"]
    assert d["compile_s"] > 0.0 and d["dispatch_s"] > 0.0
    assert abs(ins.stage_s["teststage"]
               - d["compile_s"] - d["dispatch_s"]) < 1e-9


def test_metrics_registry_snapshot_and_prometheus():
    reg = obs.Registry()
    reg.inc("widgets_total", kind="a")
    reg.inc("widgets_total", 2, kind="a")
    reg.observe("latency_seconds", 0.1, cls="s")
    reg.observe("latency_seconds", 0.3, cls="s")
    snap = reg.snapshot()
    assert snap["counters"]['widgets_total{kind="a"}'] == 3
    h = snap["histograms"]['latency_seconds{cls="s"}']
    assert h["count"] == 2 and abs(h["sum"] - 0.4) < 1e-9
    text = reg.render_prometheus()
    assert '# TYPE widgets_total counter' in text
    assert 'widgets_total{kind="a"} 3' in text
    assert 'latency_seconds_count{cls="s"} 2' in text
    assert 'quantile="0.95"' in text
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "histograms": {}}


def test_default_registry_sees_launch_events():
    obs.REGISTRY.reset()
    from repro.core.nd import nested_dissection
    from repro.graphs import generators as G
    nested_dissection(G.grid2d(12, 12), seed=0)
    snap = obs.REGISTRY.snapshot()
    launches = {k: v for k, v in snap["counters"].items()
                if k.startswith("repro_launches_total")}
    assert launches, "no launch counters recorded"
    stages = {k: v for k, v in snap["counters"].items()
              if k.startswith("repro_stage_seconds_total")}
    assert any('stage="fm"' in k for k in stages)


# ------------------------------------------------------------------ #
# bit-parity + trace content on the host scheduler path
# ------------------------------------------------------------------ #
def _order_host(graphs, tracer_out=None, tmp=None):
    from repro.service.scheduler import order_batch
    if tracer_out is None:
        return order_batch(graphs, seeds=list(range(len(graphs)))), None
    with obs.tracing() as tr:
        with tr.span("session"):
            perms = order_batch(graphs, seeds=list(range(len(graphs))))
    path = str(tmp / tracer_out)
    tr.export_chrome(path)
    return perms, path


def test_tracing_bit_parity_and_summary_coverage(tmp_path):
    from repro.graphs import generators as G
    graphs = [G.grid2d(13, 11), G.rgg2d(220, seed=3), G.grid3d(5, 5, 5)]
    base, _ = _order_host(graphs)
    traced, path = _order_host(graphs, "t.json", tmp_path)
    for a, b in zip(base, traced):
        assert np.array_equal(a, b), "tracing changed the ordering"

    sys.path.insert(0, "scripts")
    try:
        import trace_summary
    finally:
        sys.path.pop(0)
    spans = obs.load_chrome(path)
    _tree_check(spans)
    names = {s.name for s in spans}
    assert {"session", "sched:batch", "router:wave"} <= names
    assert any(n.startswith("dispatch:") for n in names)
    # every router wave attributes its originating requests
    assert all("requests" in s.attrs for s in spans
               if s.name == "router:wave")
    # the session root span covers the run: >= 95% of wall-clock
    # attributed, the acceptance bar CI re-checks on the bench trace
    assert trace_summary.coverage(spans) >= 0.95
    out = trace_summary.render(spans)
    assert "router:wave" in out and "dispatch:" in out
    assert trace_summary.main([path, "--min-coverage", "0.95"]) == 0


def test_disabled_tracing_overhead_within_budget(tmp_path):
    """The ≤5% budget: the no-op span() calls and bus events the traced
    run would make must cost under 5% of the p=1 quick-graph ordering
    they decorate (measured as primitive cost × observed call count, so
    the assertion is robust to CI wall-clock jitter)."""
    from repro.graphs import generators as G
    from repro.service.scheduler import order_batch
    g = G.grid2d(24, 24)                # the quick dnd workload graph
    order_batch([g])                    # warm the jit caches

    class _Count:
        events = 0

        def on_event(self, kind, payload):
            _Count.events += 1

    counter = _Count()
    obs.register_collector(counter)
    try:
        t0 = time.perf_counter()
        with obs.tracing() as tr:
            order_batch([g])
        t_run = time.perf_counter() - t0
    finally:
        obs.unregister_collector(counter)
    n_spans, n_events = len(tr.spans), _Count.events
    assert n_spans > 0 and n_events > 0

    reps = 2000
    t0 = time.perf_counter()
    for _ in range(reps):
        with obs.span("noop"):          # disabled: shared null context
            pass
    span_cost = (time.perf_counter() - t0) / reps
    payload = {"name": "x", "seconds": 0.0, "compile": False}
    t0 = time.perf_counter()
    for _ in range(reps):
        obs.emit("stage", payload)
    emit_cost = (time.perf_counter() - t0) / reps

    overhead = n_spans * span_cost + n_events * emit_cost
    assert overhead <= 0.05 * t_run, (
        f"disabled-path overhead {overhead * 1e3:.2f}ms is more than 5% "
        f"of the {t_run * 1e3:.0f}ms ordering "
        f"({n_spans} spans, {n_events} events)")


# ------------------------------------------------------------------ #
# distributed drivers: tracing on/off × frontier/DFS (subprocess)
# ------------------------------------------------------------------ #
_DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    from repro import obs
    from repro.core.dgraph import distribute
    from repro.core.dnd import DNDConfig, distributed_nested_dissection
    from repro.graphs import generators as G

    g = G.grid2d(20, 20)
    dg = distribute(g, 4)
    kw = dict(centralize_threshold=150, band_central_threshold=96)
    perms = {}
    for frontier in (True, False):
        cfg = DNDConfig(frontier=frontier, **kw)
        perms[(frontier, False)] = distributed_nested_dissection(
            dg, seed=0, cfg=cfg)
        with obs.tracing() as tr:
            perms[(frontier, True)] = distributed_nested_dissection(
                dg, seed=0, cfg=cfg)
        if frontier:
            names = {s.name for s in tr.spans}
    ref = perms[(True, False)]
    out = {
        "perm_ok": bool(np.array_equal(np.sort(ref), np.arange(g.n))),
        "all_equal": bool(all(np.array_equal(ref, p)
                              for p in perms.values())),
        "has_wave": "router:wave" in names,
        "has_dnd": "dnd" in names,
        "dispatch_kinds": sorted({s.name for s in tr.spans
                                  if s.name.startswith("dispatch:")}),
        "wave_attrs_ok": bool(all(
            "level" in s.attrs and "works" in s.attrs
            and "requests" in s.attrs
            for s in tr.spans if s.name == "router:wave")),
    }
    print(json.dumps(out))
""")


def test_distributed_drivers_bit_identical_with_tracing():
    out = run_json_script(_DIST_SCRIPT)
    assert out["perm_ok"]
    assert out["all_equal"], \
        "tracing or driver choice changed the ordering"
    assert out["has_wave"] and out["has_dnd"]
    assert out["wave_attrs_ok"]
    assert any(k.startswith("dispatch:d") for k in out["dispatch_kinds"]), \
        f"no distributed dispatch spans: {out['dispatch_kinds']}"
