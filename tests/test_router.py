"""Unified wave router: cross-request lane stacking (DESIGN.md §5).

Host-side checks of the ``RouterConfig`` surface and the bounded
jit-builder cache run by default; the cross-request stacking contract —
N concurrent distributed orderings drained through ONE router are
bit-identical to one-at-a-time drains, per-wave launches stay bounded by
live shape buckets even when lanes come from different requests, and the
shared drain needs strictly fewer collective launches than sequential
drains — runs in a subprocess with 8 virtual host devices (slow).
"""
import textwrap

import pytest

from procutil import run_json_script


# ------------------------------------------------------------------ #
# RouterConfig + bounded jit cache (host side, no mesh needed)
# ------------------------------------------------------------------ #
def test_router_config_defaults_and_apply():
    from repro.core import dgraph as _dg
    from repro.service.router import RouterConfig, global_config
    cfg = RouterConfig()
    assert cfg.frontier_waves and cfg.max_wave_works is None
    assert cfg.mesh is None
    assert cfg.jit_cache_capacity >= 1
    assert isinstance(cfg.match_compact, bool)
    old_cap, old_compact = (global_config.jit_cache_capacity,
                            global_config.match_compact)
    try:
        cfg.jit_cache_capacity = 7
        cfg.match_compact = False
        cfg.apply()
        assert _dg._JIT_CACHE._cap == 7
        assert _dg._MATCH_COMPACT is False
    finally:
        global_config.apply()           # restore process defaults
    assert _dg._JIT_CACHE._cap == old_cap
    assert _dg._MATCH_COMPACT == old_compact


def test_jit_cache_lru_eviction_rebills_compiles_and_counts():
    from repro import obs
    from repro.core.dgraph import _JitCache
    obs.REGISTRY.reset()
    cache = _JitCache(2)
    keys = [("test-jit-cache", i, id(cache)) for i in range(3)]
    built = []
    for k in keys:
        assert obs.first_use(k)         # dispatch path bills a compile
        cache.get(k, lambda k=k: built.append(k) or k)
    assert len(cache) == 2 and len(built) == 3
    snap = obs.REGISTRY.snapshot()["counters"]
    assert snap["repro_jit_cache_evictions_total"] == 1
    assert snap["repro_jit_cache_size"] == 2
    # keys[0] was evicted (LRU): its compile key is forgotten, so the
    # next dispatch is billed as a compile again — not a slow dispatch
    assert obs.first_use(keys[0])
    assert not obs.first_use(keys[1]) and not obs.first_use(keys[2])
    # touching keys[1] makes keys[2] the LRU victim
    cache.get(keys[1], lambda: pytest.fail("hit must not rebuild"))
    cache.get(keys[0], lambda: keys[0])
    assert obs.first_use(keys[2]) and not obs.first_use(keys[1])
    # shrinking the capacity trims immediately
    cache.set_capacity(1)
    assert len(cache) == 1
    snap = obs.REGISTRY.snapshot()["counters"]
    assert snap["repro_jit_cache_size"] == 1


def test_work_kind_rejects_unknown():
    from repro.service.router import work_kind
    with pytest.raises(TypeError):
        work_kind(object())


# ------------------------------------------------------------------ #
# cross-request stacking (subprocess, 8 virtual host devices)
# ------------------------------------------------------------------ #
_SCRIPT_CACHE: dict = {}


def _run_script(script: str, timeout: int = 560) -> dict:
    if script in _SCRIPT_CACHE:
        return _SCRIPT_CACHE[script]
    out = run_json_script(script, timeout=timeout)
    _SCRIPT_CACHE[script] = out
    return out


ROUTER_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core.dgraph import (distribute, instrument,
                                   distributed_matching_stacked,
                                   set_match_compact)
    from repro.core.dnd import (DNDConfig, distributed_nested_dissection,
                                distributed_order_batch)
    from repro.graphs import generators as G
    from repro.service import OrderingService

    out = {}
    kw = dict(centralize_threshold=150, band_central_threshold=96)
    # sizes picked so the pow2 shard bucket is 64 with ~36-40 real
    # vertices per shard: the proposal cap (40) then passes its
    # 3*cap < 2*n_loc_max pay gate and compaction engages
    graphs = [G.grid2d(16, 20), G.grid2d(16, 18), G.rgg2d(320, seed=4)]
    seeds = [3, 11, 7]
    dgs = [distribute(g, 8) for g in graphs]
    cfgs = [DNDConfig(**kw) for _ in graphs]

    # --- matching proposal-gather compaction is lossless --------------
    set_match_compact(False)
    with instrument() as ins_dense:
        dense = [distributed_matching_stacked([d], [s])[0]
                 for d, s in zip(dgs, seeds)]
    set_match_compact(True)
    with instrument() as ins_comp:
        comp = [distributed_matching_stacked([d], [s])[0]
                for d, s in zip(dgs, seeds)]
    out["compact_parity"] = bool(all(
        np.array_equal(a, b) for a, b in zip(dense, comp)))
    ld = [l for l in ins_dense.launches if l["kind"] == "dmatch"]
    lc = [l for l in ins_comp.launches if l["kind"] == "dmatch"]
    out["compact_fired"] = bool(lc and all(l["cap"] > 0 for l in lc))
    out["compact_words_shrank"] = bool(
        sum(l["words"] for l in lc) < sum(l["words"] for l in ld))

    # --- sequential single-request drains (the reference) -------------
    with instrument() as ins_seq:
        singles = [distributed_nested_dissection(d, seed=s, cfg=c)
                   for d, s, c in zip(dgs, seeds, cfgs)]

    # --- one shared router over all 3 concurrent orderings ------------
    with instrument() as ins_con:
        batch = distributed_order_batch(dgs, seeds, cfgs)
    out["batch_parity"] = bool(all(
        np.array_equal(a, b) for a, b in zip(singles, batch)))

    # permutation order must not matter either
    perm = [2, 0, 1]
    batch_p = distributed_order_batch([dgs[i] for i in perm],
                                      [seeds[i] for i in perm],
                                      [cfgs[i] for i in perm])
    out["perm_parity"] = bool(all(
        np.array_equal(singles[i], p) for i, p in zip(perm, batch_p)))

    # --- per-wave budget with multi-request lanes ----------------------
    waves = ins_con.waves
    out["budget_ok"] = bool(all(
        w["launches"][k] == w["buckets"][k] <= w["works"][k]
        for w in waves for k in w["launches"]))
    out["multi_request_waves"] = sum(
        1 for w in waves if w.get("requests", 1) >= 2)
    out["shared_launches"] = sum(
        w.get("shared_launches", 0) for w in waves)

    # --- the acceptance gate: fewer launches than sequential ----------
    def dist_launches(ins):
        return sum(1 for l in ins.launches
                   if l["kind"] in ("dhalo", "dbfs", "dmatch"))
    out["launches_concurrent"] = dist_launches(ins_con)
    out["launches_sequential"] = dist_launches(ins_seq)

    # --- service front end: interleaved distributed + host submits ----
    svc = OrderingService()
    rids = []
    for dg, g, s, c in zip(dgs, graphs, seeds, cfgs):
        rids.append(svc.submit_distributed(dg, seed=s, cfg=c))
        svc.submit(g, seed=s)           # host request rides along
    svc.drain()
    out["service_parity"] = bool(all(
        np.array_equal(svc.poll(r).perm, p)
        for r, p in zip(rids, singles)))
    out["service_cached"] = bool(
        svc.poll(svc.submit_distributed(dgs[0], seed=seeds[0],
                                        cfg=cfgs[0])).cached)
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_match_compaction_is_lossless_and_shrinks_gathers():
    out = _run_script(ROUTER_SCRIPT)
    assert out["compact_parity"], \
        "compacted proposal gather changed the matching"
    assert out["compact_fired"], "compaction never engaged"
    assert out["compact_words_shrank"], \
        "compaction did not reduce gathered words"


@pytest.mark.slow
def test_concurrent_orderings_bit_identical_to_sequential_drains():
    out = _run_script(ROUTER_SCRIPT)
    assert out["batch_parity"], \
        "shared-router drain differs from single-request drains"
    assert out["perm_parity"], \
        "submission order changed an ordering"
    assert out["service_parity"], \
        "service drain differs from single-request drains"
    assert out["service_cached"], "distributed fingerprint cache missed"


@pytest.mark.slow
def test_cross_request_waves_stay_within_launch_budget():
    out = _run_script(ROUTER_SCRIPT)
    # launches == live shape buckets per wave, even when the lanes of a
    # bucket come from different requests
    assert out["budget_ok"], "a shared wave exceeded its bucket count"
    assert out["multi_request_waves"] > 0, \
        "no wave ever carried lanes from >= 2 requests"
    assert out["shared_launches"] > 0, \
        "no launch ever served >= 2 requests"
    # the ISSUE acceptance gate: draining 3 concurrent orderings issues
    # fewer collective launches than 3 sequential drains
    assert out["launches_concurrent"] < out["launches_sequential"], (
        out["launches_concurrent"], out["launches_sequential"])
