import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.graph import Graph
from repro.graphs import generators as G


def random_graph(n, p, seed):
    rng = np.random.default_rng(seed)
    a = rng.random((n, n)) < p
    a = np.triu(a, 1)
    iu, ju = np.nonzero(a)
    return Graph.from_edges(n, np.stack([iu, ju], 1))


def test_from_edges_dedup_and_symmetry():
    g = Graph.from_edges(4, [[0, 1], [1, 0], [0, 1], [2, 3]])
    g.check()
    assert g.m == 2
    # parallel edge weights accumulate
    assert g.adjwgt[g.xadj[0]:g.xadj[1]][0] == 3


def test_self_loops_dropped():
    g = Graph.from_edges(3, [[0, 0], [0, 1]])
    g.check()
    assert g.m == 1


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 40), st.floats(0.05, 0.5), st.integers(0, 10_000))
def test_invariants_random(n, p, seed):
    g = random_graph(n, p, seed)
    g.check()
    # degrees consistent
    assert g.degrees().sum() == g.nnz


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 30), st.integers(0, 1000))
def test_induced_subgraph_property(n, seed):
    g = random_graph(n, 0.3, seed)
    rng = np.random.default_rng(seed)
    keep = rng.random(n) < 0.6
    sub, old = g.induced_subgraph(keep)
    sub.check()
    assert len(old) == keep.sum()
    # every subgraph edge exists in parent
    for v in range(sub.n):
        for u in sub.neighbors(v):
            assert old[u] in g.neighbors(old[v])
    # every parent edge between kept vertices survives
    newid = -np.ones(n, dtype=int)
    newid[old] = np.arange(len(old))
    for v in range(n):
        if not keep[v]:
            continue
        for u in g.neighbors(v):
            if keep[u]:
                assert newid[u] in sub.neighbors(newid[v])


def test_ell_roundtrip():
    g = G.grid2d(5, 7)
    nbr, wgt = g.to_ell()
    for v in range(g.n):
        row = nbr[v][nbr[v] >= 0]
        assert set(row) == set(g.neighbors(v))


def test_components():
    g = Graph.from_edges(6, [[0, 1], [1, 2], [3, 4]])
    comp = g.components()
    assert comp[0] == comp[1] == comp[2]
    assert comp[3] == comp[4]
    assert comp[3] != comp[0]
    assert comp[5] not in (comp[0], comp[3])


@pytest.mark.parametrize("gen,n_expect", [
    (lambda: G.grid2d(6, 7), 42),
    (lambda: G.grid3d(4, 4, 4), 64),
    (lambda: G.grid3d(4, 4, 4, stencil=27), 64),
    (lambda: G.rgg2d(500, seed=2), 500),
    (lambda: G.circuit(800, seed=3), 800),
    (lambda: G.knn3d(300, k=8, seed=4), 300),
    (lambda: G.cage_like(600, seed=5), None),
])
def test_generators_valid(gen, n_expect):
    g = gen()
    g.check()
    if n_expect:
        assert g.n == n_expect
    assert g.m > 0
