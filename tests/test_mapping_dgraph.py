"""Static mapping (expert placement) + distributed-graph tests."""
import numpy as np
import pytest

from repro.core.dgraph import distribute, halo_reference
from repro.core.graph import Graph
from repro.core.mapping import (DeviceTier, cut_weight, edge_bisect,
                                expert_placement, static_map, traffic_cost)
from repro.graphs import generators as G


def test_edge_bisect_balanced_low_cut():
    g = G.grid2d(12, 12)
    half = edge_bisect(g, seed=0)
    w0, w1 = g.vwgt[half == 0].sum(), g.vwgt[half == 1].sum()
    assert abs(w0 - w1) <= 0.3 * g.total_vwgt()
    # a 12x12 grid bisects with cut ~12; accept up to 3x
    assert cut_weight(g, half) <= 36


def test_static_map_covers_all_devices():
    g = G.grid2d(16, 16)
    tiers = [DeviceTier(2, 10.0), DeviceTier(4, 1.0)]
    assign = static_map(g, tiers, seed=1)
    assert set(np.unique(assign)) == set(range(8))
    counts = np.bincount(assign, minlength=8)
    assert counts.min() >= 0.5 * counts.max()     # balance


def test_expert_placement_beats_random():
    """Clustered co-activation -> scotch mapping keeps clusters on-pod."""
    rng = np.random.default_rng(0)
    E = 32
    co = rng.random((E, E)) * 0.05
    for blk in range(4):                           # 4 hot cliques of 8
        idx = np.arange(blk * 8, blk * 8 + 8)
        co[np.ix_(idx, idx)] += 1.0
    co = (co + co.T) / 2
    assign = expert_placement(co, n_pods=2, chips_per_pod=4,
                              inter_pod_cost=10.0, seed=0)
    iu, ju = np.nonzero(np.triu(co, 1))
    w = co[iu, ju]
    scale = max(w.max(), 1e-9)
    g = Graph.from_edges(E, np.stack([iu, ju], 1),
                         ewgt=np.maximum((w / scale * 1000).astype(np.int64),
                                         1))
    tiers = [DeviceTier(2, 10.0), DeviceTier(4, 1.0)]
    cost_scotch = traffic_cost(g, assign, tiers)
    costs_rand = []
    for s in range(5):
        r = np.random.default_rng(s).integers(0, 8, E)
        costs_rand.append(traffic_cost(g, r, tiers))
    assert cost_scotch < 0.7 * np.mean(costs_rand)


# ------------------------------------------------------------------ #
def test_distribute_structure():
    g = G.grid2d(8, 8)
    dg = distribute(g, 4)
    assert dg.nparts == 4
    assert dg.vtxdist[-1] == g.n
    # every real adjacency slot resolves to a local or ghost index
    for p in range(4):
        nl = dg.vtxdist[p + 1] - dg.vtxdist[p]
        row = dg.nbr_gst[p, :nl]
        deg = g.degrees()[dg.vtxdist[p]:dg.vtxdist[p + 1]]
        for li in range(nl):
            real = row[li][:deg[li]]
            assert (real >= 0).all()
            ghosts = real[real >= dg.n_loc_max] - dg.n_loc_max
            assert (ghosts < dg.n_ghost[p]).all()
    # ghost ordering: ascending (owner, gid)  (§2.1 cache-friendly order)
    owner = np.searchsorted(dg.vtxdist, np.arange(g.n), side="right") - 1
    for p in range(4):
        gl = dg.ghost_gid[p][dg.ghost_gid[p] >= 0]
        keys = [(owner[u], u) for u in gl]
        assert keys == sorted(keys)


def test_halo_reference_values():
    g = G.grid2d(6, 6)
    dg = distribute(g, 3)
    rng = np.random.default_rng(0)
    x = rng.integers(0, 100, (3, dg.n_loc_max)).astype(np.int32)
    ext = halo_reference(dg, x)
    # ghost slot k of part p must equal the owner's local value
    flat = np.zeros(g.n, np.int32)
    for p in range(3):
        lo, hi = dg.vtxdist[p], dg.vtxdist[p + 1]
        flat[lo:hi] = x[p, :hi - lo]
    for p in range(3):
        for k, gid in enumerate(dg.ghost_gid[p]):
            if gid >= 0:
                assert ext[p, dg.n_loc_max + k] == flat[gid]
