"""Unit tests for the SLO pump policy (service.sched_policy).

Pure-host, no kernels: the policy is a function from (queued, inflight,
now) to a PumpPlan, so every property — priority order, EDF, preemption,
deadline rescue, park aging, deadlock freedom — is checked directly.
"""
import numpy as np
import pytest

from repro.service.api import size_class
from repro.service.sched_policy import (CLASS_ORDER, DEFAULT_SLO_S,
                                        PolicyConfig, ReqMeta, SchedPolicy,
                                        class_rank)


def _m(tag, cls, t=0.0, deadline=None):
    return ReqMeta(tag=tag, size_class=cls, t_enqueue=t, deadline=deadline)


def test_size_class_boundaries():
    assert size_class(1) == "xs"
    assert size_class(255) == "xs"
    assert size_class(256) == "s"
    assert size_class(1023) == "s"
    assert size_class(1024) == "m"
    assert size_class(8191) == "m"
    assert size_class(8192) == "l"
    assert [class_rank(c) for c in CLASS_ORDER] == [0, 1, 2, 3]
    assert class_rank("weird") == len(CLASS_ORDER)   # sorts last


def test_admit_order_class_then_edf_then_fifo():
    pol = SchedPolicy()
    queued = [
        _m("l1", "l", t=0.0),
        _m("xs_late", "xs", t=2.0),
        _m("xs_early", "xs", t=1.0),
        _m("s_tight", "s", t=3.0, deadline=3.5),
        _m("s_loose", "s", t=0.5),           # SLO deadline 0.5 + 1.0 = 1.5
    ]
    plan = pol.plan(queued, [], now=3.0)
    # class first (xs before s before l); EDF within class (explicit 3.5
    # beats s_loose's effective 1.5? no — 1.5 < 3.5, s_loose first)
    assert plan.admit == ["xs_early", "xs_late", "s_loose", "s_tight", "l1"]


def test_small_preempts_large():
    pol = SchedPolicy()
    plan = pol.plan([_m("small", "xs", t=10.0)],
                    [_m("big", "l", t=0.0, deadline=1000.0)], now=10.0)
    assert "small" in plan.active
    assert "big" in plan.parked
    # once the small class drains, the big ordering runs again
    plan = pol.plan([], [_m("big", "l", t=0.0, deadline=1000.0)], now=11.0)
    assert plan.active == {"big"}
    assert not plan.parked


def test_non_preemptible_classes_always_run():
    pol = SchedPolicy()
    plan = pol.plan([], [_m("a", "xs", t=0.0), _m("b", "s", t=0.0),
                         _m("c", "m", t=0.0, deadline=1000.0)], now=0.0)
    assert {"a", "b"} <= plan.active        # xs/s never parked
    assert "c" in plan.parked               # m parked while xs/s live


def test_deadline_rescue():
    pol = SchedPolicy(PolicyConfig(rescue_margin_s=0.25))
    big = _m("big", "l", t=0.0, deadline=10.0)
    small = _m("small", "xs", t=0.0)
    # far from deadline: parked behind the small request
    assert "big" in pol.plan([], [big, small], now=5.0).parked
    # inside the rescue margin: runs even though a smaller class is live
    assert "big" in pol.plan([], [big, small], now=9.8).active


def test_park_aging():
    pol = SchedPolicy(PolicyConfig(max_park_s=2.0))
    big = _m("big", "l", t=0.0, deadline=1e9)
    small = _m("small", "xs", t=0.0, deadline=1e9)
    assert "big" in pol.plan([], [big, small], now=0.0).parked
    assert "big" in pol.plan([], [big, small], now=1.0).parked
    # parked continuously for >= max_park_s: forced to run
    assert "big" in pol.plan([], [big, small], now=2.5).active
    # and the park clock resets once it ran
    assert "big" in pol.plan([], [big, small], now=3.0).parked


def test_never_empty_active_set():
    pol = SchedPolicy()
    # only preemptible orderings live: the smallest present class runs
    plan = pol.plan([], [_m("a", "m", t=0.0, deadline=1e9),
                         _m("b", "l", t=0.0, deadline=1e9)], now=0.0)
    assert "a" in plan.active
    assert plan.max_waves >= 1


def test_default_slo_effective_deadlines():
    for cls in CLASS_ORDER:
        m = _m("x", cls, t=5.0)
        assert m.effective_deadline() == pytest.approx(
            5.0 + DEFAULT_SLO_S[cls])
    assert _m("x", "xs", t=5.0, deadline=5.1).effective_deadline() == 5.1


def test_active_parked_partition_live_set():
    pol = SchedPolicy()
    queued = [_m(f"q{i}", "xs", t=float(i)) for i in range(3)]
    inflight = [_m(f"f{i}", "l", t=0.0, deadline=1e9) for i in range(2)]
    plan = pol.plan(queued, inflight, now=5.0)
    live = {m.tag for m in queued} | {m.tag for m in inflight}
    assert plan.active | plan.parked == live
    assert not (plan.active & plan.parked)
    assert set(plan.admit) == {m.tag for m in queued}
