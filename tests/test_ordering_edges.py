"""Edge cases: Ordering.assemble fragment bookkeeping and extract_band
anchor weighting when one side of the separator is empty."""
import numpy as np
import pytest

from repro.core.band import extract_band, project_band
from repro.core.fm import separator_is_valid
from repro.core.ordering import Ordering
from repro.graphs import generators as G


# ------------------------------------------------------------------ #
# Ordering.assemble
# ------------------------------------------------------------------ #
def test_assemble_single_fragment():
    o = Ordering(5)
    o.add_leaf(o.root, 0, np.array([4, 2, 0, 1, 3]))
    assert np.array_equal(o.assemble(), [4, 2, 0, 1, 3])


def test_assemble_multi_fragment_by_start():
    o = Ordering(6)
    n0 = o.add_internal(o.root, 0, 3)
    o.add_leaf(o.root, 3, np.array([1, 5, 0]), "sep")   # added out of order
    o.add_leaf(n0, 0, np.array([2, 4, 3]))
    assert np.array_equal(o.assemble(), [2, 4, 3, 1, 5, 0])


def test_assemble_rejects_overlapping_fragments():
    o = Ordering(5)
    o.add_leaf(o.root, 0, np.array([0, 1, 2]))
    o.add_leaf(o.root, 2, np.array([3, 4]))            # overlaps index 2
    with pytest.raises(AssertionError, match="overlap"):
        o.assemble()


def test_assemble_rejects_gap():
    o = Ordering(6)
    o.add_leaf(o.root, 0, np.array([0, 1]))
    o.add_leaf(o.root, 4, np.array([2, 3]))            # hole at 2..3
    with pytest.raises(AssertionError):
        o.assemble()


# ------------------------------------------------------------------ #
# extract_band anchors
# ------------------------------------------------------------------ #
def _column_sep(nx, ny, col):
    """Vertical separator at x == col on an nx×ny grid."""
    part = np.zeros(nx * ny, np.int8)
    xs = np.arange(nx * ny).reshape(nx, ny)
    part[xs[col + 1:].ravel()] = 1
    part[xs[col].ravel()] = 2
    return part


def test_extract_band_anchor_weights_balance():
    g = G.grid2d(20, 8)
    part = _column_sep(20, 8, 9)
    band, bpart, locked, old = extract_band(g, part, width=2)
    assert band.vwgt.sum() == g.total_vwgt()           # anchors absorb rest
    assert bpart[-2] == 0 and bpart[-1] == 1
    assert locked[-2:].all() and not locked[:-2].any()


def test_extract_band_one_side_empty():
    """Separator at the boundary: side 1 has no out-of-band weight (and at
    width≥nx no out-of-band vertices at all on either side)."""
    g = G.grid2d(12, 6)
    part = _column_sep(12, 6, 10)                      # side 1 = one column
    band, bpart, locked, old = extract_band(g, part, width=3)
    # side-1 column is entirely within the band: its anchor weight is 0
    assert band.vwgt[-1] == 0
    # side-0 anchor carries exactly the out-of-band side-0 weight
    in_band = np.zeros(g.n, bool)
    in_band[old[old >= 0]] = True
    assert band.vwgt[-2] == g.vwgt[~in_band & (part == 0)].sum()
    # total weight is still conserved through the anchors
    assert band.vwgt.sum() == g.total_vwgt()
    # band graph is a usable FM input: projection keeps a valid separator
    nbr, _ = g.to_ell()
    full = project_band(part, bpart, old)
    assert separator_is_valid(nbr, full)
    assert np.array_equal(full, part)                  # unrefined round-trip


def test_extract_band_empty_side_isolated_anchor():
    """A part vector with NO side-1 vertices: anchor 1 ends up isolated
    with zero weight, and the band build must not crash."""
    g = G.grid2d(8, 8)
    part = np.zeros(g.n, np.int8)
    part[-8:] = 2                                      # last row separator
    band, bpart, locked, old = extract_band(g, part, width=2)
    assert band.vwgt[-1] == 0                          # empty side-1 anchor
    assert bpart[-1] == 1 and locked[-1]
    assert band.vwgt.sum() == g.total_vwgt()
