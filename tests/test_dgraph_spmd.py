"""shard_map halo exchange + distributed BFS, run in a subprocess with 8
host devices (keeps the main test process at 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.core.dgraph import (distribute, distributed_bfs,
                                   halo_exchange_fn, halo_reference,
                                   make_parts_mesh)
    from repro.core.band import bfs_distance
    from repro.graphs import generators as G
    import jax.numpy as jnp

    g = G.grid2d(10, 10)
    dg = distribute(g, 8)
    mesh = make_parts_mesh(8)
    rng = np.random.default_rng(1)
    x = rng.integers(0, 1000, (8, dg.n_loc_max)).astype(np.int32)
    with mesh:
        halo = halo_exchange_fn(dg, mesh)
        got = np.asarray(halo(jnp.asarray(x)))
    want = halo_reference(dg, x)
    ok_halo = bool((got == want).all())

    # distributed BFS == centralized BFS
    src = np.zeros(g.n, bool); src[0] = True
    src_sh = np.zeros((8, dg.n_loc_max), bool)
    for p in range(8):
        lo, hi = dg.vtxdist[p], dg.vtxdist[p+1]
        src_sh[p, :hi-lo] = src[lo:hi]
    with mesh:
        dist = distributed_bfs(dg, mesh, src_sh, width=6)
    nbr, _ = g.to_ell()
    ref = np.asarray(bfs_distance(jnp.asarray(nbr), jnp.asarray(src), 6))
    flat = np.concatenate([dist[p, :dg.vtxdist[p+1]-dg.vtxdist[p]]
                           for p in range(8)])
    ok_bfs = bool((np.minimum(flat, 7) == np.minimum(ref, 7)).all())
    print(json.dumps({"halo": ok_halo, "bfs": ok_bfs}))
""")


def test_spmd_halo_and_bfs():
    # Pin the backend: without JAX_PLATFORMS the child process probes for
    # accelerator plugins, which can hang far longer than the compute.
    res = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, timeout=300,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root",
                              "JAX_PLATFORMS": os.environ.get(
                                  "JAX_PLATFORMS", "cpu")})
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["halo"], "halo exchange mismatch"
    assert out["bfs"], "distributed BFS mismatch"
