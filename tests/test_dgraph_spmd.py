"""shard_map halo exchange, distributed BFS and distributed matching, run
in a subprocess with 8 host devices (keeps the main test process at 1
device).  Host-only DGraph helpers (single-part mesh, to_host round trip)
run in-process."""
import textwrap

import numpy as np
import pytest

from procutil import run_json_script

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.core.dgraph import (distribute, distributed_bfs,
                                   distributed_matching, halo_exchange_fn,
                                   halo_reference, shard_vector,
                                   unshard_vector)
    from repro.core.band import bfs_distance
    from repro.core.matching import validate_matching
    from repro.graphs import generators as G
    import jax.numpy as jnp

    g = G.grid2d(10, 10)
    dg = distribute(g, 8)
    rng = np.random.default_rng(1)
    x = rng.integers(0, 1000, (8, dg.n_loc_max)).astype(np.int32)
    halo = halo_exchange_fn(dg)
    got = np.asarray(halo(jnp.asarray(x)))
    want = halo_reference(dg, x)
    ok_halo = bool((got == want).all())

    # distributed BFS == centralized BFS
    src = np.zeros(g.n, bool); src[0] = True
    dist = distributed_bfs(dg, shard_vector(dg, src), width=6)
    nbr, _ = g.to_ell()
    ref = np.asarray(bfs_distance(jnp.asarray(nbr), jnp.asarray(src), 6))
    flat = unshard_vector(dg, dist)
    ok_bfs = bool((np.minimum(flat, 7) == np.minimum(ref, 7)).all())

    # distributed matching: involution, edges only, decent coverage
    ok_match = True
    for seed in (0, 5):
        m = distributed_matching(dg, seed)
        ok_match &= validate_matching(m)
        v = np.arange(g.n)
        for a in v[m != v]:
            ok_match &= int(m[a]) in g.neighbors(a).tolist()
        ok_match &= bool((m != v).mean() > 0.5)

    # zero-ghost shards: two disjoint cliques split at the shard boundary
    e = [[i, j] for i in range(8) for j in range(i + 1, 8)]
    e += [[8 + i, 8 + j] for i in range(8) for j in range(i + 1, 8)]
    from repro.core.graph import Graph
    g2 = Graph.from_edges(16, np.array(e))
    dg2 = distribute(g2, 2)
    ok_zero = bool((dg2.n_ghost == 0).all())
    x2 = rng.integers(0, 100, (2, dg2.n_loc_max)).astype(np.int32)
    halo2 = halo_exchange_fn(dg2)
    ok_zero &= bool((np.asarray(halo2(jnp.asarray(x2)))
                     == halo_reference(dg2, x2)).all())
    m2 = distributed_matching(dg2, 1)
    ok_zero &= validate_matching(m2)

    print(json.dumps({"halo": ok_halo, "bfs": ok_bfs,
                      "match": ok_match, "zero_ghost": ok_zero}))
""")


def run_spmd(script):
    # Pin the backend: without JAX_PLATFORMS the child process probes for
    # accelerator plugins, which can hang far longer than the compute.
    return run_json_script(script, timeout=300)


def test_spmd_halo_bfs_matching():
    out = run_spmd(SCRIPT)
    assert out["halo"], "halo exchange mismatch"
    assert out["bfs"], "distributed BFS mismatch"
    assert out["match"], "distributed matching invalid"
    assert out["zero_ghost"], "zero-ghost shard handling broken"


# ------------------------------------------------------------------ #
# host-side edge cases (1 device is enough)
# ------------------------------------------------------------------ #
def test_halo_single_part_mesh():
    from repro.core.dgraph import distribute, halo_exchange_fn, \
        halo_reference
    from repro.graphs import generators as G
    import jax.numpy as jnp
    g = G.grid2d(6, 6)
    dg = distribute(g, 1)
    assert int(dg.n_ghost.max()) == 0          # one shard owns everything
    rng = np.random.default_rng(0)
    x = rng.integers(0, 100, (1, dg.n_loc_max)).astype(np.int32)
    got = np.asarray(halo_exchange_fn(dg)(jnp.asarray(x)))
    assert (got == halo_reference(dg, x)).all()


def test_to_host_round_trip():
    from repro.core.dgraph import distribute, to_host
    from repro.graphs import generators as G
    g = G.rgg2d(120, seed=4)
    g.adjwgt = g.adjwgt.copy()
    for nparts in (1, 3):
        dg = distribute(g, nparts)
        g2 = to_host(dg)
        assert np.array_equal(g2.xadj, g.xadj)
        assert np.array_equal(g2.adjncy, g.adjncy)
        assert np.array_equal(g2.adjwgt, g.adjwgt)
        assert np.array_equal(g2.vwgt, g.vwgt)


def test_coarse_vtxdist_shard_aligned():
    from repro.core.coarsen import coarse_vtxdist, coarsen_once, match_graph
    from repro.graphs import generators as G
    g = G.grid2d(8, 8)
    vtxdist = np.array([0, 16, 32, 48, 64])
    m = match_graph(g, 2)
    cg, cmap = coarsen_once(g, m)
    cvtx = coarse_vtxdist(vtxdist, m)
    assert cvtx[0] == 0 and cvtx[-1] == cg.n
    assert (np.diff(cvtx) >= 0).all()
    # every coarse vertex lands in the range of its representative's owner
    rep = np.minimum(np.arange(g.n), m)
    owner_f = np.searchsorted(vtxdist, rep, side="right") - 1
    for v in range(g.n):
        c = cmap[v]
        o = np.searchsorted(cvtx, c, side="right") - 1
        assert o == owner_f[v]
