"""Gradient-compression codec tests (int8 + error feedback)."""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.optim.compress import (dequantize_int8, ef_compress, ef_init,
                                  quantize_int8)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_quantize_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256).astype(np.float32) * 10)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With EF, the *accumulated* transmitted signal tracks the accumulated
    gradient (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_const = {"w": jnp.asarray(rng.standard_normal(64).astype(np.float32))}
    res = ef_init(g_const)
    sent_total = np.zeros(64, np.float32)
    for step in range(50):
        q, s, res = ef_compress(g_const, res)
        sent_total += np.asarray(dequantize_int8(q["w"], s["w"]))
    avg_sent = sent_total / 50
    np.testing.assert_allclose(avg_sent, np.asarray(g_const["w"]),
                               rtol=0.02, atol=0.02)
    assert float(jnp.max(jnp.abs(res["w"]))) < float(s["w"]) * 2
