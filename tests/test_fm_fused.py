"""Differential parity harness for the fused on-device FM pass loop.

Three implementations of the same refinement must be bit-identical
(parts, sep_w, imb — exact equality, no tolerance):

* the fused Pallas kernel (``kernels.fm_fused.fm_fused_multi``, the
  production path, run here in interpret mode on CPU);
* the hoisted reference path (``core.fm.fm_refine_multi``: Python pass
  loop, batched gain recompute per pass — the pre-fusion pipeline);
* the independent jnp oracle (``kernels.ref.fm_fused_ref``, which
  shares no code with either).

Exactness is well-defined because vertex weights are integer-valued
float32, so every sum in the pipeline is exact regardless of reduction
order, and the tiebreak noise is drawn by the same key-split sequence
(``fm_fused.fm_noise``) on both paths.

Also here: the bucket-key regression tests for the adaptive per-lane
move budget — ``max_moves`` left ``FMWork.bucket_key()``, so works with
different budgets share one dispatch and must still match their
singleton runs bit-for-bit.
"""
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.fm import (FMWork, execute_fm_works,  # noqa: E402
                           fm_refine_multi, refine_parts)
from repro.kernels.fm_fused import fm_fused_multi, fm_noise  # noqa: E402
from repro.kernels.ops import fm_mode_default  # noqa: E402
from repro.kernels.ref import fm_fused_ref  # noqa: E402


def _rand_lanes(seed: int, L: int, n: int, d: int,
                mixed_budget: bool = True):
    """A random lane stack: ELL graphs, weights, states, locks, budgets."""
    rng = np.random.default_rng(seed)
    nbr = rng.integers(0, n, (L, n, d)).astype(np.int32)
    nbr[rng.random((L, n, d)) < 0.4] = -1           # ragged rows
    vwgt = rng.integers(1, 4, (L, n)).astype(np.int32)
    part = rng.integers(0, 3, (L, n)).astype(np.int8)
    locked = rng.random((L, n)) < rng.uniform(0.0, 0.3, (L, 1))
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(seed + 1), L))
    eps = np.full(L, 0.1, np.float32)
    if mixed_budget:                                # adaptive per lane
        mm = rng.integers(3, 2 * n, L).astype(np.int32)
    else:
        mm = np.full(L, n, np.int32)
    n_pert = np.full(L, 8, np.int32)
    return tuple(jnp.asarray(a) for a in
                 (nbr, vwgt, part, locked, keys, eps, mm, n_pert))


def _run_all_three(args, passes: int, pos_only: bool):
    nbr, vwgt, parts0, locked, keys, eps, mm, n_pert = args
    hoisted = fm_refine_multi(*args, passes=passes, pos_only=pos_only,
                              gain_mode="jnp")
    fused = fm_fused_multi(*args, passes=passes, pos_only=pos_only,
                           interpret=True)
    noise = fm_noise(keys, nbr.shape[1], passes)
    eps_abs = eps * vwgt.astype(jnp.float32).sum(axis=1)
    oracle = fm_fused_ref(nbr, vwgt, parts0, locked, noise, eps_abs,
                          mm, n_pert, passes=passes, pos_only=pos_only)
    return hoisted, fused, oracle


def _assert_bit_identical(a, b, what: str):
    for name, x, y in zip(("parts", "sep_w", "imb"), a, b):
        x, y = np.asarray(x), np.asarray(y)
        assert np.array_equal(x, y), \
            f"{what}: {name} differs ({(x != y).sum()} mismatches)"


# ------------------------------------------------------------------ #
# differential sweep: fused == hoisted == oracle, bit-for-bit
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("L", [1, 3, 8])
def test_fused_parity_lane_sweep(L):
    """Seeded sweep over lane counts with mixed locks and mixed per-lane
    move budgets: all three implementations bit-identical."""
    args = _rand_lanes(seed=10 + L, L=L, n=32, d=4)
    hoisted, fused, oracle = _run_all_three(args, passes=3, pos_only=False)
    _assert_bit_identical(fused, hoisted, f"L={L} fused vs hoisted")
    _assert_bit_identical(fused, oracle, f"L={L} fused vs oracle")


@pytest.mark.parametrize("passes,pos_only",
                         [(1, False), (1, True), (3, True)])
def test_fused_parity_passes_and_pos_only(passes, pos_only):
    args = _rand_lanes(seed=7, L=3, n=32, d=4)
    hoisted, fused, oracle = _run_all_three(args, passes=passes,
                                            pos_only=pos_only)
    tag = f"passes={passes} pos_only={pos_only}"
    _assert_bit_identical(fused, hoisted, f"{tag} fused vs hoisted")
    _assert_bit_identical(fused, oracle, f"{tag} fused vs oracle")


def test_fused_parity_many_seeds_property_sweep():
    """Property-style: many random graphs through one compiled shape
    (same L/n/d keeps this sweep on the jit cache)."""
    for seed in range(6):
        args = _rand_lanes(seed=100 + seed, L=3, n=32, d=4)
        hoisted, fused, _ = _run_all_three(args, passes=3, pos_only=False)
        _assert_bit_identical(fused, hoisted, f"seed={seed}")


def test_fused_noise_matches_hoisted_key_sequence():
    """The precomputed noise block replays the hoisted path's exact
    split/uniform op sequence — the foundation of bit-parity."""
    keys = jax.random.split(jax.random.PRNGKey(3), 4)
    n, passes = 16, 3
    noise = fm_noise(keys, n, passes)
    assert noise.shape == (4, passes, 2, n)
    k = keys
    for p in range(passes):
        both = jax.vmap(jax.random.split)(k)
        k, subs = both[:, 0], both[:, 1]
        expect = jax.vmap(lambda s: jax.random.uniform(s, (2, n)))(subs)
        assert np.array_equal(np.asarray(noise[:, p]), np.asarray(expect))


# ------------------------------------------------------------------ #
# bucket-key regression: the adaptive per-lane budget
# ------------------------------------------------------------------ #
def _work(n=30, d=4, seed=5, **kw):
    rng = np.random.default_rng(seed)
    nbr = rng.integers(0, n, (n, d)).astype(np.int32)
    nbr[rng.random((n, d)) < 0.3] = -1
    kw.setdefault("vwgt", np.ones(n, np.int64))
    kw.setdefault("part", rng.integers(0, 3, n).astype(np.int8))
    kw.setdefault("locked", np.zeros(n, bool))
    return FMWork(nbr=nbr, seed=seed, **kw)


def test_bucket_key_drops_max_moves_component():
    """Works that differ only in max_moves share one bucket (the _mm
    pow2 sub-bucket is gone); the key is (n_pad, d_pad, passes,
    pos_only)."""
    w_small = _work(max_moves=5)
    w_large = _work(max_moves=500)
    w_default = _work()                     # sep_sz-derived default
    assert w_small.bucket_key() == w_large.bucket_key() \
        == w_default.bucket_key() == (64, 8, 3, False)
    assert w_small.bucket_key() != _work(passes=1).bucket_key()
    assert w_small.bucket_key() != _work(pos_only=True).bucket_key()


def test_effective_max_moves_clamp_edges():
    # n_pad boundary: a budget above the padded vertex count clamps to
    # it (pow2 padding has a floor of 64 rows)
    w = _work(n=30, max_moves=10_000)
    assert w.effective_max_moves() == 64
    w130 = _work(n=130, max_moves=10_000)
    assert w130.effective_max_moves() == 256
    # 4096 cap: huge graphs never compile a larger trip bound
    n_big = 5000
    nbr = -np.ones((n_big, 2), np.int32)
    w_big = FMWork(nbr=nbr, vwgt=np.ones(n_big, np.int64),
                   part=np.full(n_big, 2, np.int8),
                   locked=np.zeros(n_big, bool), seed=0, max_moves=9999)
    assert w_big.effective_max_moves() == 4096
    # sep_sz-derived default: 2·|sep| + 16 when max_moves is None
    part = np.zeros(30, np.int8)
    part[:5] = 2
    w_def = _work(part=part, max_moves=None)
    assert w_def.effective_max_moves() == 2 * 5 + 16
    # ... and the parts_init variant takes the max separator over starts
    starts = np.zeros((2, 30), np.int8)
    starts[1, :7] = 2
    w_multi = _work(part=part, parts_init=starts, max_moves=None)
    assert w_multi.effective_max_moves() == 2 * 7 + 16


@pytest.mark.parametrize("mode", ["fused", "hoisted"])
def test_mixed_budget_bucket_matches_singletons(mode):
    """Lanes with different max_moves share one dispatch and still match
    their singleton runs bit-for-bit — the adaptive-budget invariant."""
    works = [_work(seed=s, max_moves=m)
             for s, m in [(1, 5), (2, 40), (3, None), (4, 4096)]]
    assert len({w.bucket_key() for w in works}) == 1
    batched = execute_fm_works(works, mode=mode)
    singles = [execute_fm_works([w], mode=mode)[0] for w in works]
    for i, (b, s) in enumerate(zip(batched, singles)):
        _assert_bit_identical(b, s, f"work {i} batched vs singleton")


def test_execute_fm_works_mode_parity_and_env_switch(monkeypatch):
    """The executor's fused and hoisted paths agree end-to-end, and
    REPRO_FM_MODE drives the default."""
    works = [_work(seed=s, max_moves=m) for s, m in [(7, 9), (8, 64)]]
    fused = execute_fm_works(works, mode="fused")
    hoisted = execute_fm_works(works, mode="hoisted")
    for i, (f, h) in enumerate(zip(fused, hoisted)):
        _assert_bit_identical(f, h, f"work {i} fused vs hoisted")
    monkeypatch.setenv("REPRO_FM_MODE", "hoisted")
    assert fm_mode_default() == "hoisted"
    monkeypatch.setenv("REPRO_FM_MODE", "auto")
    assert fm_mode_default() == "fused"
    monkeypatch.setenv("REPRO_FM_MODE", "bogus")
    with pytest.raises(ValueError):
        execute_fm_works(works[:1], mode="bogus")


def test_refine_parts_contract_under_fused_default():
    """The one-work convenience wrapper keeps its contract on the fused
    path: padding rows never enter the separator, output is a valid
    3-state labeling."""
    out, sep_w, imb = refine_parts(*(lambda w: (w.nbr, w.vwgt, w.part,
                                                w.locked))(_work(seed=9)),
                                   seed=9, k_inst=4)
    assert out.shape == (30,)
    assert set(np.unique(out)) <= {0, 1, 2}
    assert sep_w >= 0.0 and imb >= 0.0
