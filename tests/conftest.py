"""Test-suite bootstrap.

Two jobs:

* a ``slow`` marker for the full-size dnd / gather-free cases (multiple
  minutes of CPU ``shard_map`` subprocess each).  They are skipped by
  default so the local tier-1 run stays fast — reduced-size unmarked
  variants cover the same code paths — and run with ``--runslow`` (or
  ``REPRO_RUN_SLOW=1``) in the CI ``spmd`` job, which keeps the
  full-size assertions on every PR.
* the property tests use ``hypothesis`` when it is installed; on
  machines without it (the CI/base image only ships jax + pytest) a
  minimal deterministic shim is registered in ``sys.modules`` *before*
  test modules import it.  The shim replays a fixed pseudo-random sample
  of each strategy (``max_examples`` draws, seeded per test name) so the
  property tests still exercise many input shapes, just without
  shrinking.
"""
from __future__ import annotations

import functools
import inspect
import os
import sys
import types
import zlib

import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run slow-marked full-size dnd/gather-free tests")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: full-size dnd/gather-free case (skipped by default; the CI "
        "spmd job runs them with --runslow)")


def pytest_collection_modifyitems(config, items):
    if (config.getoption("--runslow")
            or os.environ.get("REPRO_RUN_SLOW") == "1"):
        return
    skip = pytest.mark.skip(
        reason="full-size case: needs --runslow (CI spmd job runs these)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


def _install_hypothesis_shim() -> None:
    try:
        import hypothesis  # noqa: F401  (real library available)
        return
    except ImportError:
        pass

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(lo: int, hi: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    def floats(lo: float, hi: float, **_kw) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    _DEFAULT_EXAMPLES = 10

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # @settings sits *outside* @given, so read the example count
                # it attached to this wrapper at call time.
                n_ex = getattr(wrapper, "_shim_max_examples", _DEFAULT_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n_ex):
                    vals = tuple(s.draw(rng) for s in strategies)
                    fn(*args, *vals, **kwargs)
            # Marker object mirroring the real library: plugins (e.g. anyio)
            # introspect ``fn.hypothesis.inner_test``.
            wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
            # The strategy-supplied params are not pytest fixtures.
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.booleans = booleans
    st.sampled_from = sampled_from
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_shim()
