"""Hardened subprocess runner for the 8-virtual-device tests.

Every SPMD test forks a fresh interpreter (``XLA_FLAGS=...device_count=8``
must be set before jax imports), prints one JSON line, and exits.  The
old per-file ``subprocess.run(..., timeout=N)`` copies had a shared
hang mode: on a wedged backend, ``run`` kills the *child* but then
blocks in ``communicate()`` while any grandchild/thread keeps the
captured pipe open — CI hangs to the job timeout instead of failing
fast.  This runner starts the child in its own session and, on
timeout, SIGKILLs the whole process group before failing the test with
the stderr tail.
"""
import json
import os
import signal
import subprocess
import sys

#: default child budget, under the CI job timeout with room to report
DEFAULT_TIMEOUT = 560


def run_json_script(script: str, timeout: int = DEFAULT_TIMEOUT,
                    env: dict = None) -> dict:
    """Run ``python -c script`` hermetically; parse its last stdout
    line as JSON.  Hard timeout: the child's entire process group is
    killed and the test fails immediately (no CI hang)."""
    child_env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                 "HOME": os.environ.get("HOME", "/root"),
                 "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    if env:
        child_env.update(env)
    proc = subprocess.Popen([sys.executable, "-c", script],
                            stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True,
                            env=child_env, start_new_session=True)
    try:
        out, err = proc.communicate(timeout=timeout)
    except subprocess.TimeoutExpired:
        try:
            os.killpg(os.getpgid(proc.pid), signal.SIGKILL)
        except (ProcessLookupError, PermissionError):
            proc.kill()
        out, err = proc.communicate()
        raise AssertionError(
            f"subprocess exceeded {timeout}s and was killed (group)"
            f"\nstderr tail: {(err or '')[-2000:]}")
    assert proc.returncode == 0, (err or "")[-2000:]
    return json.loads(out.strip().splitlines()[-1])
