"""Frontier-batched distributed ND: lane-stacked collective bit-parity,
per-wave launch budgets, and frontier-vs-depth-first ordering identity
(subprocess with 8 virtual host devices), plus host-side checks of the
consolidated instrumentation entry point."""
import textwrap

import numpy as np
import pytest

from procutil import run_json_script


# ------------------------------------------------------------------ #
# consolidated instrumentation (host side, no mesh needed)
# ------------------------------------------------------------------ #
def test_instrument_channels_broadcast_to_nested_blocks():
    from repro.core.dgraph import distribute, instrument, to_host, \
        track_gathers
    from repro.graphs import generators as G
    g = G.grid2d(9, 7)
    dg = distribute(g, 4)
    with instrument() as outer:
        with track_gathers() as inner:
            to_host(dg)
        # the legacy view is a window over the same event stream: both
        # the outer instrument() block and the inner view record it
        assert inner == [("to_host", g.n)]
        assert outer.gathers == [("to_host", g.n)]
    with instrument() as fresh:
        pass
    assert fresh.gathers == [] and fresh.launches == [] \
        and fresh.stage_s == {} and fresh.waves == []


def test_instrument_nested_identical_blocks_unwind_by_identity():
    """Regression: two active blocks hold identical contents after a
    broadcast event; the inner block's exit must remove ITSELF, not the
    equal-by-value outer block (which would orphan later events)."""
    from repro.core.dgraph import distribute, instrument, to_host
    from repro.graphs import generators as G
    g = G.grid2d(5, 5)
    dg = distribute(g, 2)
    with instrument() as outer:
        with instrument() as inner:
            to_host(dg)             # outer and inner now compare equal
        to_host(dg)                 # must still reach the outer block
    assert len(inner.gathers) == 1
    assert len(outer.gathers) == 2


def test_instrument_times_rebuild_stage():
    from repro.core.dgraph import distribute, instrument
    from repro.graphs import generators as G
    g = G.grid2d(9, 7)
    with instrument() as ins:
        distribute(g, 4)
    assert ins.stage_s.get("rebuild", 0.0) > 0.0


def test_lane_pad_pow2_duplicates_lane_zero():
    from repro.core.dgraph import _lane_pad
    arrs = [np.full((2, 3), i) for i in range(3)]
    st, L = _lane_pad(arrs)
    assert L == 3 and st.shape == (4, 2, 3)
    assert np.array_equal(st[3], arrs[0])
    st1, L1 = _lane_pad(arrs[:1])
    assert L1 == 1 and st1.shape == (1, 2, 3)


# ------------------------------------------------------------------ #
# subprocess (8 virtual host devices)
# ------------------------------------------------------------------ #
_SCRIPT_CACHE: dict = {}


def _run_script(script: str, timeout: int = 560) -> dict:
    if script in _SCRIPT_CACHE:         # several tests share one run
        return _SCRIPT_CACHE[script]
    out = run_json_script(script, timeout=timeout)
    _SCRIPT_CACHE[script] = out
    return out


STACK_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core.dgraph import (dgraph_bucket, distribute,
                                   distributed_bfs_stacked,
                                   distributed_matching_stacked,
                                   halo_exchange_stacked, instrument)
    from repro.core.dnd import (DBFSWork, DHaloWork, DMatchWork,
                                _execute_one)
    from repro.service.router import execute_wave
    from repro.graphs import generators as G

    out = {}
    # grid2d(13,11) and grid2d(12,12) share a pow2 bucket; the rgg does
    # not (denser rows), so a mixed frontier really has >= 2 buckets
    graphs = [G.grid2d(13, 11), G.grid2d(12, 12), G.grid2d(10, 14),
              G.rgg2d(150, seed=1)]
    graphs[0].vwgt = (1 + np.arange(graphs[0].n) % 3).astype(np.int64)
    dgs = [distribute(g, 4) for g in graphs]
    buckets = {dgraph_bucket(d) for d in dgs}
    out["n_buckets"] = len(buckets)
    same = [d for d in dgs if dgraph_bucket(d) == dgraph_bucket(dgs[0])]
    out["n_same"] = len(same)

    rng = np.random.default_rng(0)
    def vec(d, i):
        return rng.integers(0, 9, (d.nparts, d.n_loc_max)).astype(np.int32)

    # --- stacked vs singleton bit-parity, per collective --------------
    xs = [vec(d, i) for i, d in enumerate(same)]
    halo_ok = all(
        np.array_equal(o, halo_exchange_stacked([d], [x])[0])
        for d, x, o in zip(same, xs, halo_exchange_stacked(same, xs)))
    out["halo_parity"] = bool(halo_ok)

    srcs = [(v % 5 == 0).astype(np.int32) for v in xs]
    bfs_ok = all(
        np.array_equal(o, distributed_bfs_stacked([d], [s], 4)[0])
        for d, s, o in zip(same, srcs,
                           distributed_bfs_stacked(same, srcs, 4)))
    out["bfs_parity"] = bool(bfs_ok)

    seeds = [3, 11, 12345][:len(same)]
    mt_ok = all(
        np.array_equal(o, distributed_matching_stacked([d], [s])[0])
        for d, s, o in zip(same, seeds,
                           distributed_matching_stacked(same, seeds)))
    out["match_parity"] = bool(mt_ok)

    # --- a mixed-bucket, mixed-kind wave equals singleton execution ---
    works = []
    for i, d in enumerate(dgs):
        works.append(DHaloWork(d, vec(d, i)))
        works.append(DBFSWork(d, (vec(d, i) % 3 == 0).astype(np.int32), 3))
        works.append(DMatchWork(d, seed=7 + i))
    with instrument() as ins:
        wave_out, summary = execute_wave(works)
    single_out = [_execute_one(w) for w in works]
    out["wave_parity"] = bool(all(
        np.array_equal(a, b) for a, b in zip(wave_out, single_out)))
    out["summary"] = summary
    # launch budget of the wave: one launch per bucket per kind, and a
    # bucket never launches more than once for its work list
    out["budget_ok"] = bool(all(
        summary["launches"][k] == summary["buckets"][k] <= summary["works"][k]
        for k in summary["launches"]))
    # matching gathers 3 buffers per round (unmatched halo + proposal
    # targets + proposal weights): the grant gather-back of the
    # pre-frontier protocol is gone, measured by the words counter.
    # ``words_dense`` books the uncompacted cost; the compact proposal
    # gather (cap > 0) must only ever shrink it.
    m_launches = [l for l in ins.launches if l["kind"] == "dmatch"]
    out["match_words_ok"] = bool(all(
        l["words_dense"] == l["rounds"] * 3 * l["lanes_pad"] * l["nparts"]
        * l["bucket"][0] and l["words"] <= l["words_dense"]
        and (l["cap"] > 0) == (l["words"] < l["words_dense"])
        for l in m_launches))
    out["n_match_launches"] = len(m_launches)
    print(json.dumps(out))
""")


def test_lane_stacked_collectives_bit_parity_and_wave_budget():
    out = _run_script(STACK_SCRIPT)
    assert out["n_same"] >= 2, "workload lost its same-bucket pair"
    assert out["n_buckets"] >= 2, "workload lost its mixed buckets"
    assert out["halo_parity"], "lane-stacked halo differs from singleton"
    assert out["bfs_parity"], "lane-stacked BFS differs from singleton"
    assert out["match_parity"], \
        "lane-stacked matching differs from singleton"
    assert out["wave_parity"], \
        "wave execution differs from singleton execution"
    assert out["budget_ok"], f"wave over-launched: {out['summary']}"
    # the same-bucket trio stacks: strictly fewer launches than works
    s = out["summary"]
    assert s["launches"]["dhalo"] < s["works"]["dhalo"]
    assert s["launches"]["dmatch"] < s["works"]["dmatch"]
    assert out["match_words_ok"], \
        "matching words counter disagrees with 3-gathers-per-round"


FRONTIER_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core.dgraph import distribute, instrument
    from repro.core.dnd import DNDConfig, distributed_nested_dissection
    from repro.graphs import generators as G

    out = {}
    g = G.grid2d(26, 26)
    dg = distribute(g, 8)
    # forced-sharded bands so the frontier also carries DHaloWork waves
    kw = dict(centralize_threshold=200, band_central_threshold=128)
    with instrument() as ins_f:
        pf = distributed_nested_dissection(dg, seed=0,
                                           cfg=DNDConfig(**kw))
    with instrument() as ins_d:
        pd = distributed_nested_dissection(dg, seed=0,
                                           cfg=DNDConfig(frontier=False,
                                                         **kw))
    out["perm_ok"] = bool(np.array_equal(np.sort(pf), np.arange(g.n)))
    out["frontier_eq_dfs"] = bool(np.array_equal(pf, pd))
    waves = ins_f.waves
    out["n_waves"] = len(waves)
    out["budget_ok"] = bool(all(
        w["launches"][k] == w["buckets"][k] <= w["works"][k]
        for w in waves for k in w["launches"]))
    out["stacked_waves"] = sum(
        1 for w in waves
        for k in w["launches"] if w["launches"][k] < w["works"][k])
    def dist_launches(ins):
        return sum(1 for l in ins.launches
                   if l["kind"] in ("dhalo", "dbfs", "dmatch"))
    out["launches_frontier"] = dist_launches(ins_f)
    out["launches_dfs"] = dist_launches(ins_d)
    out["kinds"] = sorted({k for w in waves for k in w["launches"]})
    out["stages"] = sorted(ins_f.stage_s)
    print(json.dumps(out))
""")


def test_frontier_bit_identical_to_depth_first_with_launch_budget():
    out = _run_script(FRONTIER_SCRIPT)
    assert out["perm_ok"]
    # the tentpole claim, part 1: wave-batched lane-stacked execution is
    # bit-identical to the depth-first one-launch-per-step driver
    assert out["frontier_eq_dfs"], \
        "frontier driver ordering differs from the depth-first oracle"
    # part 2: per wave and work kind, launches == shape buckets <= works
    assert out["budget_ok"], "a wave launched more than its bucket count"
    # lane-stacking really fired (some wave served >1 work per launch)
    # and the whole run needed fewer collective launches than the
    # depth-first driver
    assert out["stacked_waves"] > 0, "no wave ever stacked lanes"
    assert out["launches_frontier"] < out["launches_dfs"], (
        out["launches_frontier"], out["launches_dfs"])
    # the frontier carried distributed AND centralized work kinds, and
    # the per-stage wall-clock breakdown covers the device stages
    assert "dmatch" in out["kinds"] and "dbfs" in out["kinds"]
    assert "fm" in out["kinds"]
    assert {"match", "bfs", "fm", "rebuild"} <= set(out["stages"])


def test_service_task_works_join_frontier_waves():
    """Fully-folded (p=1) instances run nd.separator_task inline: their
    FM/match works must appear in the same waves as distributed works."""
    out = _run_script(FRONTIER_SCRIPT)
    kinds = set(out["kinds"])
    assert "match" in kinds or "fm" in kinds, \
        "no centralized works ever reached the frontier executor"


# ------------------------------------------------------------------ #
# fused vs hoisted FM: end-to-end permutation bit-parity
# ------------------------------------------------------------------ #
def _fm_mode_script(p_values, n_graphs: int) -> str:
    """End-to-end REPRO_FM_MODE=fused vs hoisted parity: the full
    ``distributed_order_batch`` pipeline must produce bit-identical
    permutations under either FM path, across device counts and both
    the frontier and depth-first drivers."""
    return textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import numpy as np
        from repro.core.dgraph import distribute
        from repro.core.dnd import (DNDConfig, distributed_nested_dissection,
                                    distributed_order_batch)
        from repro.graphs import generators as G

        out = {{}}
        graphs = [G.grid2d(20, 20), G.grid3d(7, 7, 7)][:{n_graphs}]
        seeds = [0, 5][:{n_graphs}]
        # lowered thresholds: the sharded band path (and its per-phase
        # FMWork batches) really executes, so the fused kernel is on
        # the hot path of every run below
        kw = dict(centralize_threshold=200, band_central_threshold=128)

        def run_batch(P, mode):
            os.environ["REPRO_FM_MODE"] = mode
            dgs = [distribute(g, P) for g in graphs]
            return distributed_order_batch(
                dgs, seeds, [DNDConfig(**kw)] * len(dgs))

        parity = {{}}
        perms = None
        for P in {list(p_values)}:
            pf = run_batch(P, "fused")
            ph = run_batch(P, "hoisted")
            parity[str(P)] = bool(all(
                np.array_equal(a, b) for a, b in zip(pf, ph)))
            perms = pf
        out["frontier_parity_by_p"] = parity
        out["perm_ok"] = bool(all(
            np.array_equal(np.sort(p), np.arange(g.n))
            for p, g in zip(perms, graphs)))

        # depth-first driver, p=8: same fused-vs-hoisted contract off
        # the frontier path
        dg = distribute(graphs[0], 8)
        dfs = {{}}
        for mode in ("fused", "hoisted"):
            os.environ["REPRO_FM_MODE"] = mode
            dfs[mode] = distributed_nested_dissection(
                dg, seed=0, cfg=DNDConfig(frontier=False, **kw))
        out["dfs_parity"] = bool(
            np.array_equal(dfs["fused"], dfs["hoisted"]))
        print(json.dumps(out))
    """)


def test_fm_mode_end_to_end_bit_parity_quick():
    """Reduced-size default-run variant: one graph, P=4, both drivers."""
    out = _run_script(_fm_mode_script((4,), n_graphs=1))
    assert out["perm_ok"]
    assert all(out["frontier_parity_by_p"].values()), \
        f"fused ordering differs from hoisted: {out['frontier_parity_by_p']}"
    assert out["dfs_parity"], \
        "depth-first driver: fused ordering differs from hoisted"


@pytest.mark.slow
def test_fm_mode_end_to_end_bit_parity_full():
    """The tentpole's end-to-end claim: REPRO_FM_MODE=fused vs hoisted
    produce identical permutations for P ∈ {1, 4, 8}, both graphs, and
    both the frontier and depth-first drivers (CI spmd job)."""
    out = _run_script(_fm_mode_script((1, 4, 8), n_graphs=2))
    assert out["perm_ok"]
    assert all(out["frontier_parity_by_p"].values()), \
        f"fused ordering differs from hoisted: {out['frontier_parity_by_p']}"
    assert out["dfs_parity"], \
        "depth-first driver: fused ordering differs from hoisted"
