"""Gather-free distributed ND: structure-rebuild parity vs the host ops,
the distributed ordering tree, and (in a subprocess with 8 host devices)
the no-centralization guarantee + band-path equivalence."""
import textwrap

import numpy as np
import pytest

from procutil import run_json_script


def _mk(seed=0):
    from repro.graphs import generators as G
    g = G.grid2d(13, 11)
    g.vwgt = (1 + np.arange(g.n) % 3).astype(np.int64)
    return g


# ------------------------------------------------------------------ #
# host-side structure rebuilds (no collectives → no device mesh needed)
# ------------------------------------------------------------------ #
def test_dgraph_induced_matches_host_induced_subgraph():
    from repro.core.dgraph import (_raster_flat, dgraph_induced, distribute,
                                   shard_gids, shard_vector, to_host)
    g = _mk()
    dg = distribute(g, 4)
    rng = np.random.default_rng(0)
    keep_flat = rng.random(g.n) < 0.6
    sub_ref, old = g.induced_subgraph(keep_flat)
    keep_sh = shard_vector(dg, keep_flat, fill=False)
    for nparts in (None, 2):            # in-place and redistributed
        sub_dg, (gids,) = dgraph_induced(dg, keep_sh, nparts=nparts,
                                         payloads=(shard_gids(dg),),
                                         fills=(-1,))
        h = to_host(sub_dg)
        assert np.array_equal(h.xadj, sub_ref.xadj)
        assert np.array_equal(h.adjncy, sub_ref.adjncy)
        assert np.array_equal(h.vwgt, sub_ref.vwgt)
        assert np.array_equal(h.adjwgt, sub_ref.adjwgt)
        # payload carries the old ids in induced (ascending-gid) order
        assert np.array_equal(_raster_flat(sub_dg, gids), old)


def test_dgraph_fold_preserves_graph_and_vectors():
    from repro.core.dgraph import (_raster_flat, dgraph_fold, distribute,
                                   reshard_vector, shard_vector, to_host)
    g = _mk()
    for P in (4, 5):                     # even and odd shard counts
        dg = distribute(g, P)
        dgf = dgraph_fold(dg)
        assert dgf.nparts == (P + 1) // 2
        h = to_host(dgf)
        assert np.array_equal(h.xadj, g.xadj)
        assert np.array_equal(h.adjncy, g.adjncy)
        assert np.array_equal(h.vwgt, g.vwgt)
        x = np.arange(g.n)
        xf = reshard_vector(dg, dgf, shard_vector(dg, x))
        assert np.array_equal(_raster_flat(dgf, xf), x)
        xb = reshard_vector(dgf, dg, xf)
        assert np.array_equal(_raster_flat(dg, xb), x)


def test_dgraph_coarsen_matches_coarsen_once():
    from repro.core.coarsen import coarse_vtxdist, coarsen_once
    from repro.core.dgraph import (_raster_flat, dgraph_coarsen, distribute,
                                   shard_vector, to_host)
    g = _mk()
    rng = np.random.default_rng(3)
    m = np.arange(g.n)
    pairs = rng.permutation(g.n)
    for i in range(0, g.n - 1, 2):
        a, b = pairs[i], pairs[i + 1]
        m[a], m[b] = b, a
    cg_ref, cmap_ref = coarsen_once(g, m)
    dg = distribute(g, 4)
    cdg, cmap_sh = dgraph_coarsen(dg, shard_vector(dg, m, fill=-1))
    assert np.array_equal(np.asarray(cdg.vtxdist),
                          coarse_vtxdist(dg.vtxdist, m))
    h = to_host(cdg)
    assert np.array_equal(h.xadj, cg_ref.xadj)
    assert np.array_equal(h.adjncy, cg_ref.adjncy)
    assert np.array_equal(h.vwgt, cg_ref.vwgt)
    assert np.array_equal(h.adjwgt, cg_ref.adjwgt)
    assert np.array_equal(_raster_flat(dg, cmap_sh), cmap_ref)


def test_track_gathers_records_sizes():
    from repro.core.dgraph import (distribute, to_host, track_gathers,
                                   unshard_vector)
    g = _mk()
    dg = distribute(g, 4)
    with track_gathers() as log:
        to_host(dg)
        unshard_vector(dg, dg.vwgt)
    assert log == [("to_host", g.n), ("unshard_vector", g.n)]
    with track_gathers() as log2:
        pass
    assert log2 == []                   # nested blocks are independent


# ------------------------------------------------------------------ #
# alternating-color schedule building blocks (host side, no mesh)
# ------------------------------------------------------------------ #
def test_boundary_mask_matches_ownership_oracle():
    from repro.core.dgraph import _raster_flat, boundary_mask, distribute
    g = _mk()
    for P in (3, 4):
        dg = distribute(g, P)
        owner = np.searchsorted(dg.vtxdist, np.arange(g.n),
                                side="right") - 1
        src = np.repeat(np.arange(g.n), g.degrees())
        cross = owner[src] != owner[g.adjncy]
        is_b = np.zeros(g.n, bool)
        is_b[src[cross]] = True
        assert np.array_equal(_raster_flat(dg, boundary_mask(dg)), is_b)


def test_color_by_gid_pure_and_consistent_across_shards():
    from repro.core.dgraph import color_by_gid, distribute, np_hash_mix
    g = _mk()
    dg = distribute(g, 4)
    nlm = dg.n_loc_max
    h, c = color_by_gid(dg, salt=3, exchange=False)
    # local colors are the gid hash parity; padding is -1
    for p in range(dg.nparts):
        lo, hi = dg.vtxdist[p], dg.vtxdist[p + 1]
        gid = np.arange(lo, hi)
        exp = (np_hash_mix(gid, 3) & 1).astype(np.int8)
        assert np.array_equal(c[p, :hi - lo], exp)
        assert np.all(c[p, hi - lo:nlm] == -1)
    # every ghost copy carries exactly its owner's color (pure gid hash:
    # no messages needed — the same argument as the matching coins)
    flat_c = np.full(g.n, -1, np.int8)
    for p in range(dg.nparts):
        lo, hi = dg.vtxdist[p], dg.vtxdist[p + 1]
        flat_c[lo:hi] = c[p, :hi - lo]
    for p in range(dg.nparts):
        for k, gid in enumerate(dg.ghost_gid[p]):
            if gid >= 0:
                assert c[p, nlm + k] == flat_c[gid]
    # rotating the salt really re-colors (the schedule's starvation fix)
    h2, c2 = color_by_gid(dg, salt=4, exchange=False)
    assert not np.array_equal(c, c2)


def test_conflict_loser_symmetric_rule():
    """Both owners of a conflicted cross-shard edge pick the same loser.

    The repair rule is evaluated independently by the two shards from
    the two gids alone, so it must be antisymmetric (exactly one of the
    two perspectives says "my endpoint loses") and deterministic in
    (round, seed).  Under the alternating-color schedule this is the
    guarded fallback path.
    """
    from repro.core.dgraph import np_hash_mix
    from repro.core.dnd import conflict_loser
    rng = np.random.default_rng(7)
    vg = rng.integers(0, 10 ** 6, 4096)
    ug = rng.integers(0, 10 ** 6, 4096)
    keep = vg != ug
    vg, ug = vg[keep], ug[keep]
    for rnd in (0, 1, 3):
        for seed in (0, 5, 1 << 40):
            mine = conflict_loser(vg, ug, rnd, seed)
            theirs = conflict_loser(ug, vg, rnd, seed)
            assert np.array_equal(mine, conflict_loser(vg, ug, rnd, seed))
            assert np.all(mine ^ theirs), \
                "shard perspectives disagree on the loser"
    # the lowbias32 chain is bijective for a fixed salt, so distinct
    # uint32 gids never collide and the (hv == hu) tie-break can only
    # fire through uint32 aliasing of int64 gids — exercise it directly:
    # aliased gids hash equal and the gid comparison decides, again
    # identically from both perspectives
    x = np.arange(200_000, dtype=np.int64)
    assert len(np.unique(np_hash_mix(x, 1, 5))) == len(x)
    a = np.array([5], dtype=np.int64)
    b = np.array([5 + (1 << 32)], dtype=np.int64)
    assert np_hash_mix(a, 1, 5)[0] == np_hash_mix(b, 1, 5)[0]
    assert bool(conflict_loser(a, b, 1, 5)[0])       # gid-smaller loses
    assert not bool(conflict_loser(b, a, 1, 5)[0])   # ... from both sides


def test_fm_bucket_mixes_distinct_locked_masks():
    """Per-phase locked masks are lane data: works whose masks differ
    still share one bucketed dispatch, bit-equal to singleton runs."""
    from repro.core.fm import FMWork, execute_fm_works
    from repro.graphs import generators as G
    rng = np.random.default_rng(3)
    works = []
    for i, g in enumerate([G.grid2d(8, 8), G.grid2d(8, 8),
                           G.grid2d(8, 8)]):
        col = np.arange(g.n) % 8
        part = np.where(col < 3, 0,
                        np.where(col > 3, 1, 2)).astype(np.int8)
        locked = rng.random(g.n) < (0.3 * i)    # distinct masks per work
        nbr, _ = g.to_ell()
        works.append(FMWork(nbr=nbr, vwgt=g.vwgt, part=part,
                            locked=locked, seed=11 + i, k_inst=2))
    singles = [execute_fm_works([w])[0] for w in works]
    batched = execute_fm_works(works)
    for (ps, ws, _), (pb, wb, _) in zip(singles, batched):
        assert np.array_equal(ps, pb) and ws == wb, \
            "bucketed result depends on lane composition"
    # locked vertices were never *moved* out of the separator (they may
    # be pulled in), so a locked separator vertex stays a separator
    for w, (pf, _, _) in zip(works, batched):
        started_sep = (w.part == 2) & w.locked
        assert np.all(pf[started_sep] == 2)


# ------------------------------------------------------------------ #
# distributed ordering tree (paper §2.2)
# ------------------------------------------------------------------ #
def test_dist_ordering_fragments_and_sharded_assembly():
    from repro.core.dnd import DistOrdering
    n, P = 20, 4
    do = DistOrdering(n, P)
    c0 = do.add_node(do.root, 0, 8)
    c1 = do.add_node(do.root, 8, 7)
    sep = do.add_node(do.root, 15, 5, "sep")
    assert do.column_block(sep) == (15, 20)
    perm_ref = np.random.default_rng(0).permutation(n)
    do.add_fragment(c0, perm_ref[0:8], shard=1)
    do.add_fragment(c1, perm_ref[8:15], shard=2)
    # sep fragments distributed over shards, offsets by prefix sum
    do.add_sharded_fragments(sep, [perm_ref[15:17], perm_ref[17:17],
                                   perm_ref[17:19], perm_ref[19:20]])
    perm = do.assemble()
    assert np.array_equal(perm, perm_ref)
    slices, vtx = do.assemble_sharded()
    flat = np.concatenate([slices[q, :vtx[q + 1] - vtx[q]]
                           for q in range(len(vtx) - 1)])
    assert np.array_equal(flat, perm)
    assert do.fragment_shards().sum() == len(do.frags)
    with pytest.raises(AssertionError):
        do.add_fragment(c0, perm_ref[:5], shard=0)   # wrong size


def test_dist_ordering_detects_gaps():
    from repro.core.dnd import DistOrdering
    do = DistOrdering(10, 2)
    c0 = do.add_node(do.root, 0, 4)
    c1 = do.add_node(do.root, 6, 4)     # leaves a gap at [4, 6)
    do.add_fragment(c0, np.arange(4), 0)
    do.add_fragment(c1, np.arange(4, 8), 1)
    with pytest.raises(AssertionError):
        do.assemble()


# ------------------------------------------------------------------ #
# bucketed matching executor
# ------------------------------------------------------------------ #
def test_execute_match_works_composition_independent():
    from repro.core.coarsen import execute_match_works, match_work_for
    from repro.core.matching import validate_matching
    from repro.graphs import generators as G
    graphs = [G.grid2d(9, 9), G.grid2d(11, 7), G.rgg2d(90, seed=1)]
    works = [match_work_for(g, seed=s) for s, g in enumerate(graphs)]
    singles = [execute_match_works([w])[0] for w in works]
    batched = execute_match_works(works)
    for g, s, b in zip(graphs, singles, batched):
        assert validate_matching(b)
        assert np.array_equal(s, b), "bucketed result depends on batch"


# ------------------------------------------------------------------ #
# subprocess (8 virtual host devices): the gather-free guarantees
# ------------------------------------------------------------------ #
def _run_script(script: str, timeout: int = 560) -> dict:
    return run_json_script(script, timeout=timeout)


ND_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core.dgraph import distribute, track_gathers
    from repro.core.dnd import (DNDConfig, distributed_nested_dissection,
                                track_band_stats)
    from repro.graphs import generators as G

    out = {{}}
    g = G.grid2d({side}, {side})
    dg = distribute(g, 8)
    cfg = DNDConfig(centralize_threshold=256, band_central_threshold=128)
    with track_gathers() as log, track_band_stats() as bstats:
        dord = distributed_nested_dissection(dg, seed=0, cfg=cfg,
                                             return_tree=True)
    perm = dord.assemble()
    sizes = [s for _, s in log]
    out["perm_ok"] = bool(np.array_equal(np.sort(perm), np.arange(g.n)))
    out["n"] = g.n
    out["max_gather"] = int(max(sizes))
    out["bound"] = max(cfg.centralize_threshold, cfg.band_central_threshold,
                       2 * cfg.fold_threshold, cfg.coarse_target)
    # sharded assembly (prefix-sum offsets) == gathered assembly
    slices, vtx = dord.assemble_sharded()
    flat = np.concatenate([slices[q, :vtx[q + 1] - vtx[q]]
                           for q in range(len(vtx) - 1)])
    out["sharded_assembly_eq"] = bool(np.array_equal(flat, perm))
    out["shards_holding_frags"] = int((dord.fragment_shards() > 0).sum())
    # alternating-color schedule: every sharded band refinement of the
    # run must have zero cross-shard conflicts / repair kicks
    out["band_refines"] = len(bstats)
    out["alt_refines"] = sum(1 for s in bstats if s["schedule"] == "alt")
    out["conflict_total"] = int(sum(sum(s["conflicts"]) for s in bstats))
    out["repair_kicks"] = int(sum(sum(s["repairs"]) for s in bstats))
    print(json.dumps(out))
""")


def _check_nd(out):
    assert out["perm_ok"], "distributed ordering is not a permutation"
    # the tentpole claim: every centralizing gather stays under the
    # configured thresholds — no full-graph adjacency / permutation on
    # one host
    assert out["max_gather"] <= out["bound"], \
        f"gather of {out['max_gather']} exceeds threshold {out['bound']}"
    assert out["max_gather"] < out["n"] // 2
    assert out["sharded_assembly_eq"], \
        "assemble_sharded() differs from the gathered assembly"
    assert out["shards_holding_frags"] > 1, \
        "ordering fragments all landed on one shard"
    # the alternating-color schedule is the default and must run
    # conflict-free: zero 0-1 arcs detected, zero repair kicks
    assert out["alt_refines"] > 0, "no sharded band refinement happened"
    assert out["conflict_total"] == 0, \
        f"{out['conflict_total']} cross-shard conflicts under the schedule"
    assert out["repair_kicks"] == 0, \
        f"{out['repair_kicks']} conflict-repair kicks under the schedule"


def test_gather_free_distributed_nd():
    """Reduced-size default variant (784 vertices, 8 shards)."""
    _check_nd(_run_script(ND_SCRIPT.format(side=28)))


@pytest.mark.slow
def test_gather_free_distributed_nd_full():
    """Full-size variant (1600 vertices, 8 shards; CI spmd job)."""
    out = _run_script(ND_SCRIPT.format(side=40))
    assert out["n"] == 1600 and out["bound"] == 256
    _check_nd(out)


BAND_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core.dgraph import (_raster_flat, distribute, distributed_bfs,
                                   shard_vector, track_halos, valid_mask)
    from repro.core.dnd import (DNDConfig, _band_refine_level_sh,
                                track_band_stats)
    from repro.core.band import extract_band, project_band
    from repro.core.fm import fm_lane_count, refine_parts
    from repro.graphs import generators as G
    from repro.util import mix_seeds

    out = {}

    # --- band paths at the fallback threshold --------------------------
    g2 = G.grid2d(24, 24)
    dg2 = distribute(g2, 4)
    col = np.arange(g2.n) % 24
    part = np.where(col < 11, 0, np.where(col > 11, 1, 2)).astype(np.int8)
    part_sh = shard_vector(dg2, part, fill=3)
    ccfg = DNDConfig(band_central_threshold=10 ** 9)   # force centralized
    scfg = DNDConfig(band_central_threshold=0)         # force sharded
    lcfg = DNDConfig(band_central_threshold=0,         # legacy schedule
                     band_alt_colors=False)
    ref_cfg = DNDConfig()
    # host reference: the centralized pipeline's band refine, same inputs
    dist_sh = np.asarray(distributed_bfs(
        dg2, (part_sh == 2).astype(np.int32), ref_cfg.band_width))
    dist = _raster_flat(dg2, np.where(valid_mask(dg2), dist_sh, 2 ** 30))
    band, bpart, locked, old_ids = extract_band(
        g2, part, width=ref_cfg.band_width, dist=dist)
    nbr_b, _ = band.to_ell()
    k_fm = fm_lane_count(4, ref_cfg.k_fm_cap, ref_cfg.fold_dup)
    bp, _, _ = refine_parts(nbr_b, band.vwgt, bpart, locked,
                            mix_seeds(5, 7), k_inst=k_fm,
                            eps_frac=ref_cfg.eps_frac,
                            passes=ref_cfg.fm_passes, n_pert=8)
    ref = project_band(part, bp, old_ids)

    def flat_part(ps):
        return _raster_flat(dg2, ps).astype(np.int8)

    def crossing(g, pf):
        src = np.repeat(np.arange(g.n), g.degrees())
        return int(((pf[src] == 0) & (pf[g.adjncy] == 1)).sum())

    cen = flat_part(_band_refine_level_sh(dg2, part_sh.copy(), 5, 4, ccfg))
    with track_band_stats() as bs_a, track_halos() as hl_a:
        shd = flat_part(_band_refine_level_sh(dg2, part_sh.copy(), 5, 4,
                                              scfg))
    with track_band_stats() as bs_l, track_halos() as hl_l:
        leg = flat_part(_band_refine_level_sh(dg2, part_sh.copy(), 5, 4,
                                              lcfg))
    out["central_eq_host"] = bool(np.array_equal(cen, ref))
    out["central_valid"] = crossing(g2, cen) == 0
    out["sharded_valid"] = crossing(g2, shd) == 0
    out["legacy_valid"] = crossing(g2, leg) == 0
    out["sep_w_central"] = int(g2.vwgt[cen == 2].sum())
    out["sep_w_sharded"] = int(g2.vwgt[shd == 2].sum())
    out["alt_conflicts"] = int(sum(bs_a[0]["conflicts"]))
    # per-phase halo budget: stats track the exchanges of one refinement;
    # cross-check against the instrumented global count
    out["alt_halos"] = len(hl_a)
    out["alt_halos_stats"] = bs_a[0]["halos"]
    out["alt_phases"] = bs_a[0]["phases"]
    out["legacy_halos"] = len(hl_l)
    out["legacy_phases"] = bs_l[0]["phases"]
    out["sync_rounds"] = scfg.band_sync_rounds

    # --- legacy-schedule repair regression (satellite bugfix) ----------
    # a gid-random rgg puts nearly every band edge across shards, so the
    # lock-all-boundary schedule reliably produces conflicts: the repair
    # fallback runs, and the run completing proves the rest-of-graph
    # anchor assertion (which replaced the silent clamp) held through
    # every repair round
    g3 = G.rgg2d(420, seed=2)
    rpart = np.where(np.arange(g3.n) < g3.n // 2, 0, 1).astype(np.int8)
    src3 = np.repeat(np.arange(g3.n), g3.degrees())
    fringe = (rpart[src3] == 1) & (rpart[g3.adjncy] == 0)
    rpart[src3[fringe]] = 2
    dg3 = distribute(g3, 4)
    rpart_sh = shard_vector(dg3, rpart, fill=3)
    with track_band_stats() as bs_r:
        leg1 = _band_refine_level_sh(dg3, rpart_sh.copy(), 0, 4, lcfg)
        leg2 = _band_refine_level_sh(dg3, rpart_sh.copy(), 0, 4, lcfg)
    out["rgg_legacy_repairs"] = int(sum(bs_r[0]["repairs"]))
    out["rgg_legacy_anchor_min"] = int(bs_r[0]["anchor_min"])
    out["rgg_legacy_deterministic"] = bool(
        np.array_equal(np.asarray(leg1), np.asarray(leg2)))
    out["rgg_legacy_valid"] = crossing(
        g3, _raster_flat(dg3, np.asarray(leg1)).astype(np.int8)) == 0
    # the alternating schedule stays conflict-free on the same adversarial
    # sharding (nearly 100% boundary vertices)
    with track_band_stats() as bs_ra:
        alt3 = _band_refine_level_sh(dg3, rpart_sh.copy(), 0, 4, scfg)
    out["rgg_alt_conflicts"] = int(sum(bs_ra[0]["conflicts"]))
    out["rgg_alt_valid"] = crossing(
        g3, _raster_flat(dg3, np.asarray(alt3)).astype(np.int8)) == 0
    print(json.dumps(out))
""")


def test_band_schedules_budget_and_repair():
    out = _run_script(BAND_SCRIPT)
    # centralized path is bit-identical to the host pipeline's band
    # refine; both sharded schedules keep the separator valid
    assert out["central_eq_host"], \
        "centralized band path diverges from host extract_band pipeline"
    assert out["central_valid"] and out["sharded_valid"] \
        and out["legacy_valid"]
    # sharded-vs-centralized band quality under the alternating schedule
    assert out["sep_w_sharded"] <= 1.5 * out["sep_w_central"] + 8, \
        (out["sep_w_sharded"], out["sep_w_central"])
    assert out["alt_conflicts"] == 0
    # halo budget: one exchange per color phase -> two per sync round,
    # exactly the PR 3 locked-ghost baseline (which exchanged twice per
    # round); the constant setup (vwgt + initial parts + round-0 color
    # validation) does not grow with rounds
    R = out["sync_rounds"]
    assert out["alt_phases"] == 2 * R
    assert out["alt_halos"] == out["alt_halos_stats"]   # tracker agrees
    assert out["alt_halos"] - 3 == 2 * R, out["alt_halos"]
    per_round_alt = (out["alt_halos"] - 3) / R
    per_round_legacy_pr3 = 2.0          # the locked-ghost baseline
    assert per_round_alt <= per_round_legacy_pr3
    assert out["legacy_halos"] - 2 <= 2 * R     # restructured legacy
    # the repair fallback: driven for real on the adversarial rgg case,
    # deterministic, validity restored, and the anchor-weight assertion
    # (no silent clamping) held through every repaired round
    assert out["rgg_legacy_repairs"] > 0, \
        "legacy schedule produced no conflicts; repair path untested"
    assert out["rgg_legacy_deterministic"] and out["rgg_legacy_valid"]
    assert out["rgg_legacy_anchor_min"] >= 0
    assert out["rgg_alt_conflicts"] == 0, \
        "alternating schedule conflicted on the adversarial sharding"
    assert out["rgg_alt_valid"]
