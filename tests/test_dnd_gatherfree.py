"""Gather-free distributed ND: structure-rebuild parity vs the host ops,
the distributed ordering tree, and (in a subprocess with 8 host devices)
the no-centralization guarantee + band-path equivalence."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest


def _mk(seed=0):
    from repro.graphs import generators as G
    g = G.grid2d(13, 11)
    g.vwgt = (1 + np.arange(g.n) % 3).astype(np.int64)
    return g


# ------------------------------------------------------------------ #
# host-side structure rebuilds (no collectives → no device mesh needed)
# ------------------------------------------------------------------ #
def test_dgraph_induced_matches_host_induced_subgraph():
    from repro.core.dgraph import (_raster_flat, dgraph_induced, distribute,
                                   shard_gids, shard_vector, to_host)
    g = _mk()
    dg = distribute(g, 4)
    rng = np.random.default_rng(0)
    keep_flat = rng.random(g.n) < 0.6
    sub_ref, old = g.induced_subgraph(keep_flat)
    keep_sh = shard_vector(dg, keep_flat, fill=False)
    for nparts in (None, 2):            # in-place and redistributed
        sub_dg, (gids,) = dgraph_induced(dg, keep_sh, nparts=nparts,
                                         payloads=(shard_gids(dg),),
                                         fills=(-1,))
        h = to_host(sub_dg)
        assert np.array_equal(h.xadj, sub_ref.xadj)
        assert np.array_equal(h.adjncy, sub_ref.adjncy)
        assert np.array_equal(h.vwgt, sub_ref.vwgt)
        assert np.array_equal(h.adjwgt, sub_ref.adjwgt)
        # payload carries the old ids in induced (ascending-gid) order
        assert np.array_equal(_raster_flat(sub_dg, gids), old)


def test_dgraph_fold_preserves_graph_and_vectors():
    from repro.core.dgraph import (_raster_flat, dgraph_fold, distribute,
                                   reshard_vector, shard_vector, to_host)
    g = _mk()
    for P in (4, 5):                     # even and odd shard counts
        dg = distribute(g, P)
        dgf = dgraph_fold(dg)
        assert dgf.nparts == (P + 1) // 2
        h = to_host(dgf)
        assert np.array_equal(h.xadj, g.xadj)
        assert np.array_equal(h.adjncy, g.adjncy)
        assert np.array_equal(h.vwgt, g.vwgt)
        x = np.arange(g.n)
        xf = reshard_vector(dg, dgf, shard_vector(dg, x))
        assert np.array_equal(_raster_flat(dgf, xf), x)
        xb = reshard_vector(dgf, dg, xf)
        assert np.array_equal(_raster_flat(dg, xb), x)


def test_dgraph_coarsen_matches_coarsen_once():
    from repro.core.coarsen import coarse_vtxdist, coarsen_once
    from repro.core.dgraph import (_raster_flat, dgraph_coarsen, distribute,
                                   shard_vector, to_host)
    g = _mk()
    rng = np.random.default_rng(3)
    m = np.arange(g.n)
    pairs = rng.permutation(g.n)
    for i in range(0, g.n - 1, 2):
        a, b = pairs[i], pairs[i + 1]
        m[a], m[b] = b, a
    cg_ref, cmap_ref = coarsen_once(g, m)
    dg = distribute(g, 4)
    cdg, cmap_sh = dgraph_coarsen(dg, shard_vector(dg, m, fill=-1))
    assert np.array_equal(np.asarray(cdg.vtxdist),
                          coarse_vtxdist(dg.vtxdist, m))
    h = to_host(cdg)
    assert np.array_equal(h.xadj, cg_ref.xadj)
    assert np.array_equal(h.adjncy, cg_ref.adjncy)
    assert np.array_equal(h.vwgt, cg_ref.vwgt)
    assert np.array_equal(h.adjwgt, cg_ref.adjwgt)
    assert np.array_equal(_raster_flat(dg, cmap_sh), cmap_ref)


def test_track_gathers_records_sizes():
    from repro.core.dgraph import (distribute, to_host, track_gathers,
                                   unshard_vector)
    g = _mk()
    dg = distribute(g, 4)
    with track_gathers() as log:
        to_host(dg)
        unshard_vector(dg, dg.vwgt)
    assert log == [("to_host", g.n), ("unshard_vector", g.n)]
    with track_gathers() as log2:
        pass
    assert log2 == []                   # nested blocks are independent


# ------------------------------------------------------------------ #
# distributed ordering tree (paper §2.2)
# ------------------------------------------------------------------ #
def test_dist_ordering_fragments_and_sharded_assembly():
    from repro.core.dnd import DistOrdering
    n, P = 20, 4
    do = DistOrdering(n, P)
    c0 = do.add_node(do.root, 0, 8)
    c1 = do.add_node(do.root, 8, 7)
    sep = do.add_node(do.root, 15, 5, "sep")
    assert do.column_block(sep) == (15, 20)
    perm_ref = np.random.default_rng(0).permutation(n)
    do.add_fragment(c0, perm_ref[0:8], shard=1)
    do.add_fragment(c1, perm_ref[8:15], shard=2)
    # sep fragments distributed over shards, offsets by prefix sum
    do.add_sharded_fragments(sep, [perm_ref[15:17], perm_ref[17:17],
                                   perm_ref[17:19], perm_ref[19:20]])
    perm = do.assemble()
    assert np.array_equal(perm, perm_ref)
    slices, vtx = do.assemble_sharded()
    flat = np.concatenate([slices[q, :vtx[q + 1] - vtx[q]]
                           for q in range(len(vtx) - 1)])
    assert np.array_equal(flat, perm)
    assert do.fragment_shards().sum() == len(do.frags)
    with pytest.raises(AssertionError):
        do.add_fragment(c0, perm_ref[:5], shard=0)   # wrong size


def test_dist_ordering_detects_gaps():
    from repro.core.dnd import DistOrdering
    do = DistOrdering(10, 2)
    c0 = do.add_node(do.root, 0, 4)
    c1 = do.add_node(do.root, 6, 4)     # leaves a gap at [4, 6)
    do.add_fragment(c0, np.arange(4), 0)
    do.add_fragment(c1, np.arange(4, 8), 1)
    with pytest.raises(AssertionError):
        do.assemble()


# ------------------------------------------------------------------ #
# bucketed matching executor
# ------------------------------------------------------------------ #
def test_execute_match_works_composition_independent():
    from repro.core.coarsen import execute_match_works, match_work_for
    from repro.core.matching import validate_matching
    from repro.graphs import generators as G
    graphs = [G.grid2d(9, 9), G.grid2d(11, 7), G.rgg2d(90, seed=1)]
    works = [match_work_for(g, seed=s) for s, g in enumerate(graphs)]
    singles = [execute_match_works([w])[0] for w in works]
    batched = execute_match_works(works)
    for g, s, b in zip(graphs, singles, batched):
        assert validate_matching(b)
        assert np.array_equal(s, b), "bucketed result depends on batch"


# ------------------------------------------------------------------ #
# subprocess (8 virtual host devices): the gather-free guarantees
# ------------------------------------------------------------------ #
SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core.dgraph import (_raster_flat, distribute, distributed_bfs,
                                   shard_vector, track_gathers, valid_mask)
    from repro.core.dnd import (DNDConfig, _band_refine_level_sh,
                                distributed_nested_dissection)
    from repro.core.band import extract_band, project_band
    from repro.core.fm import fm_lane_count, refine_parts
    from repro.graphs import generators as G
    from repro.util import mix_seeds

    out = {}

    # --- 1. no centralization above the thresholds (tentpole claim) ---
    g = G.grid2d(40, 40)
    dg = distribute(g, 8)
    cfg = DNDConfig(centralize_threshold=256, band_central_threshold=128)
    with track_gathers() as log:
        dord = distributed_nested_dissection(dg, seed=0, cfg=cfg,
                                             return_tree=True)
    perm = dord.assemble()
    sizes = [s for _, s in log]
    out["perm_ok"] = bool(np.array_equal(np.sort(perm), np.arange(g.n)))
    out["n"] = g.n
    out["max_gather"] = int(max(sizes))
    out["bound"] = max(cfg.centralize_threshold, cfg.band_central_threshold,
                       2 * cfg.fold_threshold, cfg.coarse_target)
    # sharded assembly (prefix-sum offsets) == gathered assembly
    slices, vtx = dord.assemble_sharded()
    flat = np.concatenate([slices[q, :vtx[q + 1] - vtx[q]]
                           for q in range(len(vtx) - 1)])
    out["sharded_assembly_eq"] = bool(np.array_equal(flat, perm))
    out["shards_holding_frags"] = int((dord.fragment_shards() > 0).sum())

    # --- 2. band paths at the fallback threshold -----------------------
    g2 = G.grid2d(24, 24)
    dg2 = distribute(g2, 4)
    col = np.arange(g2.n) % 24
    part = np.where(col < 11, 0, np.where(col > 11, 1, 2)).astype(np.int8)
    part_sh = shard_vector(dg2, part, fill=3)
    ccfg = DNDConfig(band_central_threshold=10 ** 9)   # force centralized
    scfg = DNDConfig(band_central_threshold=0)         # force sharded
    ref_cfg = DNDConfig()
    # host reference: the centralized pipeline's band refine, same inputs
    dist_sh = np.asarray(distributed_bfs(
        dg2, (part_sh == 2).astype(np.int32), ref_cfg.band_width))
    dist = _raster_flat(dg2, np.where(valid_mask(dg2), dist_sh, 2 ** 30))
    band, bpart, locked, old_ids = extract_band(
        g2, part, width=ref_cfg.band_width, dist=dist)
    nbr_b, _ = band.to_ell()
    k_fm = fm_lane_count(4, ref_cfg.k_fm_cap, ref_cfg.fold_dup)
    bp, _, _ = refine_parts(nbr_b, band.vwgt, bpart, locked,
                            mix_seeds(5, 7), k_inst=k_fm,
                            eps_frac=ref_cfg.eps_frac,
                            passes=ref_cfg.fm_passes, n_pert=8)
    ref = project_band(part, bp, old_ids)

    def flat_part(ps):
        return _raster_flat(dg2, ps).astype(np.int8)

    def crossing(pf):
        src = np.repeat(np.arange(g2.n), g2.degrees())
        return int(((pf[src] == 0) & (pf[g2.adjncy] == 1)).sum())

    cen = flat_part(_band_refine_level_sh(dg2, part_sh.copy(), 5, 4, ccfg))
    shd = flat_part(_band_refine_level_sh(dg2, part_sh.copy(), 5, 4, scfg))
    out["central_eq_host"] = bool(np.array_equal(cen, ref))
    out["central_valid"] = crossing(cen) == 0
    out["sharded_valid"] = crossing(shd) == 0
    w_c = int(g2.vwgt[cen == 2].sum())
    w_s = int(g2.vwgt[shd == 2].sum())
    out["sep_w_central"] = w_c
    out["sep_w_sharded"] = w_s
    print(json.dumps(out))
""")


def test_gather_free_distributed_nd():
    res = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, timeout=560,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": os.environ.get("HOME", "/root"),
                              "JAX_PLATFORMS": os.environ.get(
                                  "JAX_PLATFORMS", "cpu")})
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["perm_ok"], "distributed ordering is not a permutation"
    # the tentpole claim: every centralizing gather stays under the
    # configured thresholds — no full-graph adjacency / permutation on
    # one host (n = 1600 here, bound = 256)
    assert out["max_gather"] <= out["bound"], \
        f"gather of {out['max_gather']} exceeds threshold {out['bound']}"
    assert out["max_gather"] < out["n"] // 2
    assert out["sharded_assembly_eq"], \
        "assemble_sharded() differs from the gathered assembly"
    assert out["shards_holding_frags"] > 1, \
        "ordering fragments all landed on one shard"
    # band-path equivalence at the fallback threshold: centralized path
    # is bit-identical to the host pipeline's band refine; the sharded
    # path stays a valid separator of comparable weight
    assert out["central_eq_host"], \
        "centralized band path diverges from host extract_band pipeline"
    assert out["central_valid"] and out["sharded_valid"]
    assert out["sep_w_sharded"] <= 2 * out["sep_w_central"] + 8, \
        (out["sep_w_sharded"], out["sep_w_central"])
