"""Per-arch smoke tests (reduced configs) + layer-level correctness oracles."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_config
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.lm import (decode_step, forward, init_caches, init_params)
from repro.serve.engine import prefill


@pytest.fixture(scope="module")
def rkey():
    return jax.random.PRNGKey(42)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch, rkey):
    """Reduced config: one forward/train step, output shapes, no NaNs."""
    from repro.optim import adamw
    from repro.train.step import make_train_step
    cfg = get_config(arch).reduced()
    params = init_params(rkey, cfg)
    opt = adamw.init(params)
    B, S = 2, 16
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_len, cfg.d_model)), jnp.bfloat16)
    if cfg.frontend == "patches":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)),
            jnp.bfloat16)
    step = jax.jit(make_train_step(cfg, adamw.AdamWConfig(lr=1e-3)))
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(params2)[0]
    assert not np.array_equal(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", ["yi-6b", "deepseek-v2-lite-16b",
                                  "mamba2-130m", "jamba-v0.1-52b",
                                  "whisper-small"])
def test_decode_consistent_with_forward(arch, rkey):
    """Teacher-forced decode reproduces the full-sequence forward logits."""
    cfg = get_config(arch).reduced()
    if cfg.moe:   # avoid capacity drops changing routing between paths
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = init_params(rkey, cfg)
    B, S, S_max = 2, 8, 16
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_len, cfg.d_model)), jnp.bfloat16)
    full_logits, _ = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)

    # prefill on the first S//2 tokens, then decode the rest one by one
    half = S // 2
    pbatch = dict(batch, tokens=tokens[:, :half])
    logits_p, caches = jax.jit(
        lambda p, b: prefill(p, cfg, b, pad_to=S_max))(params, pbatch)
    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(full_logits[:, :half], np.float32),
                               rtol=0.15, atol=0.15)
    dec = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))
    for t in range(half, S):
        lg, caches = dec(params, tokens[:, t:t + 1], caches, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=0.15, atol=0.15, err_msg=f"{arch} pos {t}")


def test_moe_matches_dense_oracle(rkey):
    """Sort-based dispatch == per-token loop when capacity is unbounded."""
    cfg = dataclasses.replace(get_config("arctic-480b").reduced(),
                              capacity_factor=64.0, n_shared_experts=0)
    p = L.moe_init(rkey, cfg)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 6, cfg.d_model)), jnp.float32)
    y, aux = L.moe_apply(p, x, cfg)
    # oracle: explicit per-token top-k loop
    xt = np.asarray(x.reshape(-1, cfg.d_model), np.float32)
    router = np.asarray(p["router"], np.float32)
    w1 = np.asarray(p["w1"], np.float32)
    w3 = np.asarray(p["w3"], np.float32)
    w2 = np.asarray(p["w2"], np.float32)
    logits = xt @ router
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[:cfg.top_k]
        gates = probs[t][top] / probs[t][top].sum()
        for e, gv in zip(top, gates):
            h = (xt[t] @ w1[e])
            h = h / (1 + np.exp(-h)) * (xt[t] @ w3[e])
            out[t] += gv * (h @ w2[e])
    np.testing.assert_allclose(np.asarray(y).reshape(-1, cfg.d_model),
                               out, rtol=2e-2, atol=2e-2)
    assert float(aux) > 0


def test_mamba_decode_matches_chunked(rkey):
    cfg = get_config("mamba2-130m").reduced()
    p = M.mamba_init(rkey, cfg)
    rng = np.random.default_rng(3)
    B, S = 2, 12
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.3,
                    jnp.float32)
    y_full, (state, conv) = M.mamba_apply(p, x, cfg, return_state=True)
    # step-by-step decode over the same inputs
    inner, H, P_, N = M.ssm_dims(cfg)
    st = jnp.zeros((B, H, N, P_), jnp.float32)
    cv = jnp.zeros((B, cfg.ssm_conv - 1, inner + 2 * N), jnp.float32)
    outs = []
    for t in range(S):
        yt, st, cv = M.mamba_decode(p, x[:, t:t + 1], st, cv, cfg)
        outs.append(yt)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step, np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(st), np.asarray(state),
                               rtol=5e-2, atol=5e-2)


def test_attention_chunking_invariance(rkey):
    cfg = get_config("yi-6b").reduced()
    p = L.attn_init(rkey, cfg)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((2, 64, cfg.d_model)) * 0.3,
                    jnp.bfloat16)
    q, k, v = L.attn_qkv(p, x, cfg)
    o1 = L._attend(q, k, v, causal=True, q_chunk=16)
    o2 = L._attend(q, k, v, causal=True, q_chunk=64)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=2e-2, atol=2e-2)
