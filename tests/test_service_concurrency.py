"""Concurrent submit/drain regression (DESIGN.md §6): the service locks
its queues and latency windows so a worker-thread drain loop under
caller-thread submits / stats readers never loses or corrupts a request,
and the event bus keeps ``instrument()`` accumulation atomic while both
threads emit."""
import threading

import numpy as np

from repro.core.dgraph import instrument
from repro.core.nd import nested_dissection
from repro.graphs import generators as G
from repro.service.api import OrderingService, size_class


def test_size_class_boundaries():
    assert size_class(0) == "xs" and size_class(255) == "xs"
    assert size_class(256) == "s" and size_class(1023) == "s"
    assert size_class(1024) == "m" and size_class(8191) == "m"
    assert size_class(8192) == "l"


def test_concurrent_submit_drain_resolves_everything():
    svc = OrderingService()
    graphs = [G.grid2d(5, 5), G.grid2d(6, 4), G.grid2d(4, 7)]
    n_req = 30
    stop = threading.Event()
    errors = []

    def drainer():
        try:
            while not stop.is_set() or svc.queue_depth():
                svc.drain()
        except Exception as e:          # surface worker crashes
            errors.append(e)

    worker = threading.Thread(target=drainer)
    worker.start()
    rids = []
    try:
        with instrument() as ins:       # caller-side reader while the
            for k in range(n_req):      # drain thread emits events
                g = graphs[k % len(graphs)]
                rids.append((svc.submit(g, seed=k), g, k))
                svc.stats()             # lock-guarded concurrent read
    finally:
        stop.set()
        worker.join(timeout=120)
    assert not worker.is_alive(), "drain thread wedged"
    assert errors == [], f"drain thread raised: {errors[0]!r}"

    # every request resolved with the deterministic ordering of its
    # (graph, seed) — independent of which drain batch served it
    for rid, g, k in rids:
        res = svc.poll(rid)
        assert res is not None, f"request {rid} never resolved"
        assert np.array_equal(np.sort(res.perm), np.arange(g.n))
        assert res.size_class == "xs"
    for rid, g, k in rids[:: max(n_req // 5, 1)]:
        expect = nested_dissection(g, seed=k)
        assert np.array_equal(svc.poll(rid).perm, expect)

    st = svc.stats()
    assert st["queue_depth"] == 0
    assert st["requests"] == n_req
    assert st["by_class"]["xs"]["count"] >= 1
    # the instrument block accumulated the drain thread's stage events
    # without corruption (accumulation is atomic under the bus lock)
    assert ins.stage_s.get("fm", 0.0) >= 0.0
    assert all(isinstance(v, float) for v in ins.stage_s.values())


def test_stats_by_class_percentiles_shape():
    svc = OrderingService()
    r1 = svc.submit(G.grid2d(6, 6), seed=0)        # xs
    r2 = svc.submit(G.grid2d(20, 20), seed=1)      # s (400 vertices)
    svc.drain()
    assert svc.poll(r1).size_class == "xs"
    assert svc.poll(r2).size_class == "s"
    by_class = svc.stats()["by_class"]
    assert set(by_class) == {"xs", "s"}
    for cls, d in by_class.items():
        assert d["count"] == 1
        assert d["p95_exec_ms"] >= d["p50_exec_ms"] >= 0.0
