"""SLO serving control plane: preemption parity, warm starts, deadlines.

Host-plane tests run by default: the pump loop must park and resume
orderings **bit-identically at every wave boundary** while new requests
are admitted mid-flight, warm starts must validate / guard / fall back,
and deadline + per-class accounting must be exact.  The distributed
variant (a sharded ordering preempted between its waves by host
requests) runs in a subprocess with 8 virtual devices (slow).
"""
import textwrap

import numpy as np
import pytest

from procutil import run_json_script

from repro.core.nd import nested_dissection, valid_warm_part
from repro.graphs import generators as G
from repro.service import OrderingService
from repro.service.fingerprint import structural_fingerprint
from repro.service.sched_policy import PolicyConfig, SchedPolicy


# ------------------------------------------------------------------ #
# preempt/resume bit-parity with interleaved admissions (host plane)
# ------------------------------------------------------------------ #
def test_preempted_ordering_resumes_bit_identically():
    """A long ordering parked at every wave boundary — while new small
    requests are admitted, run and resolved — must produce the exact
    permutation of an uninterrupted run (lane purity)."""
    # make the s class preemptible so a 400-vertex "big" request keeps
    # the test fast while still being parked behind xs arrivals
    svc = OrderingService(policy=SchedPolicy(PolicyConfig(
        preemptible=("s", "m", "l"))))
    big_g = G.grid2d(20, 20)                    # n=400: class "s"
    smalls = [G.grid2d(6 + i, 7) for i in range(4)]     # class "xs"
    rid_big = svc.submit(big_g, seed=3, nproc=4, deadline_s=1000.0)

    small_rids, order, i = [], [], 0
    for _ in range(500):                        # pump-by-pump drive
        if svc.poll(rid_big) is not None:
            break
        if i < len(smalls):                     # new admission at this
            small_rids.append(                  # wave boundary
                svc.submit(smalls[i], seed=i, nproc=1, deadline_s=1000.0))
            i += 1
        order.extend(sorted(svc.pump()))
    assert svc.poll(rid_big) is not None, "pump loop did not terminate"

    # bit-parity: preempted == uninterrupted, for everyone
    assert np.array_equal(svc.poll(rid_big).perm,
                          nested_dissection(big_g, seed=3, nproc=4))
    for rid, g, seed in zip(small_rids, smalls, range(len(smalls))):
        assert np.array_equal(svc.poll(rid).perm,
                              nested_dissection(g, seed=seed, nproc=1))

    # preemption actually happened: every small resolved while the big
    # ordering was still in flight
    assert small_rids and rid_big in order
    assert max(order.index(r) for r in small_rids) < order.index(rid_big)

    # per-request attribution (not whole-batch wall): the preempted
    # ordering rode far more waves than any of the smalls it yielded to
    big_exec = svc.poll(rid_big).exec_s
    for rid in small_rids:
        assert svc.poll(rid).exec_s < big_exec


def test_pump_and_drain_on_empty_service():
    svc = OrderingService()
    assert svc.pump() == {}
    assert svc.drain() == {}
    assert svc.queue_depth() == 0


# ------------------------------------------------------------------ #
# warm starts: validation, replay, OPC guard
# ------------------------------------------------------------------ #
def test_valid_warm_part_topology_checks():
    g = G.grid2d(8, 8)
    # a proper row separator: rows 0-3 | row 4 (sep) | rows 5-7
    part = np.zeros(g.n, dtype=np.int8)
    part[4 * 8:5 * 8] = 2
    part[5 * 8:] = 1
    ok = valid_warm_part(g, part)
    assert ok is not None and ok.dtype == np.int8
    assert valid_warm_part(g, None) is None
    assert valid_warm_part(g, part[:10]) is None        # wrong length
    assert valid_warm_part(g, np.full(g.n, 2, np.int8)) is None  # empty side
    bad = part.copy()
    bad[0] = 1                                  # creates a 0-1 edge
    assert valid_warm_part(g, bad) is None
    naive = np.zeros(g.n, dtype=np.int8)        # index halves: edges cross
    naive[g.n // 2:] = 1
    assert valid_warm_part(g, naive) is None


def test_warm_start_isomorphic_repeat():
    """Same topology, different seed: the structural index warm-starts
    the repeat from the recorded splits (or exact-falls-back)."""
    svc = OrderingService(warm_starts=True)
    g = G.grid2d(14, 14)
    rid_cold = svc.submit(g, seed=0, nproc=2)
    svc.drain()
    cold = svc.poll(rid_cold)
    assert not cold.warm and len(svc.warm) == 1

    rid_warm = svc.submit(g, seed=5, nproc=2)
    svc.drain()
    warm = svc.poll(rid_warm)
    assert np.array_equal(np.sort(warm.perm), np.arange(g.n))
    st = svc.stats()
    assert st["warm_hits"] == 1
    if st["warm_fallbacks"] == 0:
        # replay accepted: flagged warm and OPC-guarded vs the source
        from repro.sparse.symbolic import nnz_opc
        assert warm.warm
        assert (nnz_opc(g, warm.perm)[1]
                <= svc.warm_opc_ratio_max * nnz_opc(g, cold.perm)[1])
    else:
        # guard fired: exact-parity fallback
        assert not warm.warm
        assert np.array_equal(warm.perm,
                              nested_dissection(g, seed=5, nproc=2))


def test_warm_opc_guard_falls_back_to_exact():
    svc = OrderingService(warm_starts=True)
    g = G.grid2d(12, 12)
    svc.submit(g, seed=0, nproc=2)
    svc.drain()
    sfp = structural_fingerprint(g)
    tree = svc.warm.get(sfp)
    assert tree is not None and tree.opc > 1.0
    # poison the entry with an impossibly good recorded OPC: any replay
    # now "degrades" and must fall back to the exact cold path
    svc.warm.put(sfp, dict(tree.parts), opc=1.0, n=tree.n,
                 source_fp="poison", replace=True)
    rid = svc.submit(g, seed=9, nproc=2)
    svc.drain()
    res = svc.poll(rid)
    assert svc.stats()["warm_fallbacks"] >= 1
    assert not res.warm
    assert np.array_equal(res.perm, nested_dissection(g, seed=9, nproc=2))


def test_warm_off_by_default_keeps_determinism_contract():
    svc = OrderingService()
    assert svc.warm_starts is False
    g = G.grid2d(10, 10)
    svc.submit(g, seed=0)
    svc.drain()
    assert len(svc.warm) == 0               # not even recording


# ------------------------------------------------------------------ #
# deadlines + per-class stats
# ------------------------------------------------------------------ #
def test_deadline_accounting_and_per_class_stats():
    # shedding off: this test wants the already-late request *computed*
    # so the miss accounting is exercised (feasibility shedding would
    # terminate it as status=shed before it ever ran)
    svc = OrderingService(policy=SchedPolicy(PolicyConfig(
        shed_infeasible=False)))
    rid_ok = svc.submit(G.grid2d(9, 9), seed=0, deadline_s=1000.0)
    svc.drain()
    assert svc.poll(rid_ok).deadline_missed is False
    rid_late = svc.submit(G.grid2d(9, 10), seed=0, deadline_s=0.0)
    svc.drain()
    assert svc.poll(rid_late).deadline_missed is True
    rid_none = svc.submit(G.grid2d(10, 10), seed=0)
    svc.drain()
    assert svc.poll(rid_none).deadline_missed is None

    st = svc.stats()
    xs = st["by_class"]["xs"]
    assert xs["deadline_total"] == 2 and xs["deadline_misses"] == 1
    assert xs["deadline_miss_rate"] == 0.5
    assert st["deadline_miss_rate"] == 0.5
    assert {"count", "p50_exec_ms", "p95_exec_ms", "p50_queue_wait_ms",
            "p95_queue_wait_ms"} <= set(xs)
    assert st["pumps"] >= 3 and st["inflight"] == 0


# ------------------------------------------------------------------ #
# distributed preempt/resume (subprocess, 8 virtual devices)
# ------------------------------------------------------------------ #
SLO_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core.dgraph import distribute
    from repro.core.dnd import DNDConfig, distributed_nested_dissection
    from repro.core.nd import nested_dissection
    from repro.graphs import generators as G
    from repro.service import OrderingService
    from repro.service.sched_policy import PolicyConfig, SchedPolicy

    out = {}
    kw = dict(centralize_threshold=150, band_central_threshold=96)
    big_g = G.grid2d(16, 20)
    dg = distribute(big_g, 8)
    cfg = DNDConfig(**kw)
    ref_big = distributed_nested_dissection(dg, seed=3, cfg=cfg)
    smalls = [G.grid2d(6 + i, 7) for i in range(3)]
    refs = [nested_dissection(g, seed=i, nproc=1)
            for i, g in enumerate(smalls)]

    svc = OrderingService(policy=SchedPolicy(PolicyConfig(
        preemptible=("s", "m", "l"))))
    rid_big = svc.submit_distributed(dg, seed=3, cfg=cfg,
                                     deadline_s=1000.0)
    rids, order, i = [], [], 0
    for _ in range(500):
        if svc.poll(rid_big) is not None:
            break
        if i < len(smalls):
            rids.append(svc.submit(smalls[i], seed=i, nproc=1,
                                   deadline_s=1000.0))
            i += 1
        order.extend(sorted(svc.pump()))
    out["terminated"] = bool(svc.poll(rid_big) is not None)
    out["big_parity"] = bool(np.array_equal(
        svc.poll(rid_big).perm, ref_big))
    out["small_parity"] = bool(all(
        np.array_equal(svc.poll(r).perm, p)
        for r, p in zip(rids, refs)))
    out["smalls_before_big"] = bool(
        rids and rid_big in order
        and max(order.index(r) for r in rids) < order.index(rid_big))
    out["attr_ok"] = bool(all(
        svc.poll(r).exec_s < svc.poll(rid_big).exec_s for r in rids))
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_distributed_ordering_preempted_by_host_requests():
    out = run_json_script(SLO_SCRIPT)
    assert out["terminated"], "distributed pump loop did not terminate"
    assert out["big_parity"], \
        "preempted distributed ordering differs from uninterrupted run"
    assert out["small_parity"], \
        "host requests admitted mid-flight lost parity"
    assert out["smalls_before_big"], \
        "small requests did not preempt the distributed ordering"
    assert out["attr_ok"], "exec attribution not per-request"
