import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.graph import Graph
from repro.graphs import generators as G
from repro.sparse.etree import etree, postorder
from repro.sparse.mindeg import min_degree
from repro.sparse.symbolic import dense_fill_oracle, nnz_opc


def random_graph(n, p, seed):
    rng = np.random.default_rng(seed)
    a = np.triu(rng.random((n, n)) < p, 1)
    iu, ju = np.nonzero(a)
    if len(iu) == 0:
        iu, ju = np.array([0]), np.array([1])
    return Graph.from_edges(n, np.stack([iu, ju], 1))


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 40), st.floats(0.05, 0.6), st.integers(0, 10**6))
def test_counts_match_dense_oracle(n, p, seed):
    g = random_graph(n, p, seed)
    perm = np.random.default_rng(seed).permutation(n)
    assert nnz_opc(g, perm) == dense_fill_oracle(g, perm)


def test_postorder_is_valid():
    g = G.grid2d(6, 6)
    parent = etree(g, np.arange(g.n))
    post = postorder(parent)
    assert np.array_equal(np.sort(post), np.arange(g.n))
    # children appear before parents
    pos = np.empty(g.n, dtype=int)
    pos[post] = np.arange(g.n)
    for v in range(g.n):
        if parent[v] != -1:
            assert pos[v] < pos[parent[v]]


def test_known_chain():
    # path graph ordered naturally: no fill, col counts = 2,2,...,2,1
    n = 10
    g = Graph.from_edges(n, np.stack([np.arange(n - 1), np.arange(1, n)], 1))
    nnz, opc = nnz_opc(g, np.arange(n))
    assert nnz == 2 * n - 1
    assert opc == 4 * (n - 1) + 1


@settings(max_examples=10, deadline=None)
@given(st.integers(10, 35), st.integers(0, 1000))
def test_mindeg_beats_random(n, seed):
    g = random_graph(n, 0.15, seed)
    perm_md = min_degree(g)
    assert np.array_equal(np.sort(perm_md), np.arange(n))
    rng = np.random.default_rng(seed + 1)
    opc_md = nnz_opc(g, perm_md)[1]
    opc_rnd = np.mean([nnz_opc(g, rng.permutation(n))[1] for _ in range(4)])
    assert opc_md <= opc_rnd * 1.05  # MD should not be worse than random


def test_mindeg_grid_quality():
    g = G.grid2d(12, 12)
    opc_md = nnz_opc(g, min_degree(g))[1]
    opc_nat = nnz_opc(g, np.arange(g.n))[1]
    assert opc_md < 0.6 * opc_nat
