"""Invariant tests for the paper's core pipeline: matching, coarsening,
initial separator, band extraction, FM, nested dissection."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.band import bfs_distance, extract_band, project_band
from repro.core.coarsen import coarsen_multilevel, coarsen_once, match_graph
from repro.core.fm import refine_parts, separator_is_valid
from repro.core.graph import Graph
from repro.core.initsep import initial_separator
from repro.core.nd import NDConfig, compute_separator, nested_dissection
from repro.core.matching import validate_matching
from repro.graphs import generators as G
from repro.sparse.symbolic import nnz_opc


def random_graph(n, p, seed):
    rng = np.random.default_rng(seed)
    a = np.triu(rng.random((n, n)) < p, 1)
    iu, ju = np.nonzero(a)
    if len(iu) == 0:
        iu, ju = np.array([0]), np.array([1])
    return Graph.from_edges(n, np.stack([iu, ju], 1))


# ------------------------------------------------------------------ #
# matching
# ------------------------------------------------------------------ #
@settings(max_examples=10, deadline=None)
@given(st.integers(4, 80), st.integers(0, 100))
def test_matching_is_involution(n, seed):
    g = random_graph(n, 0.2, seed)
    m = match_graph(g, seed)
    assert validate_matching(m)


def test_matching_respects_edges():
    g = G.grid2d(10, 10)
    m = match_graph(g, 3)
    for v in range(g.n):
        if m[v] != v:
            assert m[v] in g.neighbors(v)


def test_matching_rate():
    g = G.grid3d(8, 8, 8)
    m = match_graph(g, 0)
    frac = (m != np.arange(g.n)).mean()
    assert frac > 0.7  # paper: converges in ~5 rounds to near-complete


@pytest.mark.parametrize("n", [63, 127])
def test_matching_bucket_boundary(n):
    """Regression: with n just below the padded-ELL bucket boundary, an
    out-of-range (padded-lane) id must fall back to self-match — the old
    ``minimum(m, n-1)`` clamp silently merged it onto real vertex n-1."""
    g = G.circuit(n, seed=1)
    for seed in range(4):
        m = match_graph(g, seed)
        assert m.min() >= 0 and m.max() < g.n
        assert validate_matching(m)
        for v in np.nonzero(m != np.arange(g.n))[0]:
            assert m[v] in g.neighbors(v), \
                f"n={n} seed={seed}: {v}->{m[v]} is not an edge"


def test_mix_seeds_no_collapse():
    """Regression: seed*31 / seed*101+lvl collapse at seed=0 — every node
    at a level reused the identical FM noise stream."""
    from repro.util import mix_seeds
    # distinct across path positions at seed 0, and never the identity
    derived = {mix_seeds(0, k) for k in range(64)}
    assert len(derived) == 64 and 0 not in derived
    # sibling subtrees of a seed-0 root get distinct streams at each level
    from repro.core.nd import child_seeds
    s0, s1 = child_seeds(0)
    assert s0 != s1
    assert {mix_seeds(s0, lvl) for lvl in range(8)}.isdisjoint(
        {mix_seeds(s1, lvl) for lvl in range(8)})


# ------------------------------------------------------------------ #
# coarsening
# ------------------------------------------------------------------ #
@settings(max_examples=10, deadline=None)
@given(st.integers(8, 60), st.integers(0, 100))
def test_coarsen_conserves_weight(n, seed):
    g = random_graph(n, 0.25, seed)
    m = match_graph(g, seed)
    cg, cmap = coarsen_once(g, m)
    cg.check()
    assert cg.vwgt.sum() == g.vwgt.sum()
    assert cmap.max() == cg.n - 1
    # matched pairs map together
    for v in range(g.n):
        assert cmap[v] == cmap[m[v]]


def test_multilevel_reduces_and_folds():
    g = G.grid2d(24, 24)
    st_ = coarsen_multilevel(g, 0, nproc=8, coarse_target=60)
    sizes = [l.graph.n for l in st_.levels]
    assert sizes[0] == g.n and sizes[-1] <= max(60, sizes[-2])
    assert all(a > b for a, b in zip(sizes, sizes[1:]))
    insts = [l.n_instances for l in st_.levels]
    assert insts[-1] > 1  # fold-dup kicked in


# ------------------------------------------------------------------ #
# separators: initial, FM, band
# ------------------------------------------------------------------ #
def test_initial_separator_valid_and_balanced():
    g = G.grid2d(12, 12)
    part, sep_w = initial_separator(g, 0, k_tries=4)
    nbr, _ = g.to_ell()
    assert separator_is_valid(nbr, part)
    w = [g.vwgt[part == p].sum() for p in (0, 1, 2)]
    assert w[2] == sep_w
    assert abs(w[0] - w[1]) <= 0.25 * g.total_vwgt()


def test_fm_never_worsens_separator():
    g = G.grid2d(16, 16)
    part, sep0 = initial_separator(g, 1, k_tries=2)
    nbr, _ = g.to_ell()
    part2, sep1, _ = refine_parts(nbr, g.vwgt, part, np.zeros(g.n, bool), 7)
    assert separator_is_valid(nbr, part2)
    assert sep1 <= sep0 + 1e-6


def test_bfs_distance():
    g = G.grid2d(9, 9)
    nbr, _ = g.to_ell()
    src = np.zeros(g.n, bool)
    src[0] = True  # corner (0,0)
    d = np.asarray(bfs_distance(jnp.asarray(nbr), jnp.asarray(src), 4))
    xs, ys = np.meshgrid(np.arange(9), np.arange(9), indexing="ij")
    manhattan = (xs + ys).ravel()
    expect = np.minimum(manhattan, 5)  # clipped at width+1
    assert np.array_equal(np.minimum(d, 5), expect)


def test_band_contains_separator_and_projects():
    g = G.grid2d(20, 20)
    part, _ = initial_separator(g, 2, k_tries=4)
    band, bpart, locked, old = extract_band(g, part, width=3)
    band.check()
    # all separator vertices are in the band
    sep_ids = set(np.nonzero(part == 2)[0])
    assert sep_ids <= set(old[old >= 0])
    # anchors are last two, locked, on sides 0/1
    assert locked[-2:].all() and not locked[:-2].any()
    assert bpart[-2] == 0 and bpart[-1] == 1
    # anchor weights preserve global balance
    tot_band = band.vwgt.sum()
    assert tot_band == g.total_vwgt()
    # refined band projects to a valid separator of the full graph
    nbr_band, _ = band.to_ell()
    bpart2, _, _ = refine_parts(nbr_band, band.vwgt, bpart, locked, 5)
    full = project_band(part, bpart2, old)
    nbr, _ = g.to_ell()
    assert separator_is_valid(nbr, full)


def test_band_width3_quality_close_to_unconstrained():
    """Paper §3.3: band FM with width 3 matches (or beats) unconstrained FM."""
    g = G.grid3d(8, 8, 8)
    cfg_band = NDConfig(use_band=True)
    cfg_full = NDConfig(use_band=False)
    p_band = compute_separator(g, 3, 4, cfg_band)
    p_full = compute_separator(g, 3, 4, cfg_full)
    w_band = g.vwgt[p_band == 2].sum()
    w_full = g.vwgt[p_full == 2].sum()
    assert w_band <= w_full * 1.35


# ------------------------------------------------------------------ #
# nested dissection end-to-end
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("nproc", [1, 4])
def test_nd_is_permutation(nproc):
    g = G.grid2d(14, 14)
    perm = nested_dissection(g, seed=0, nproc=nproc)
    assert np.array_equal(np.sort(perm), np.arange(g.n))


def test_nd_beats_natural_order():
    g = G.grid3d(9, 9, 9)
    perm = nested_dissection(g, seed=0)
    opc_nd = nnz_opc(g, perm)[1]
    opc_nat = nnz_opc(g, np.arange(g.n))[1]
    assert opc_nd < 0.5 * opc_nat


def test_nd_disconnected():
    a = G.grid2d(7, 7)
    src = np.repeat(np.arange(a.n), a.degrees())
    e1 = np.stack([src, a.adjncy], 1)
    e2 = e1 + a.n
    g = Graph.from_edges(2 * a.n, np.concatenate([e1, e2]))
    perm = nested_dissection(g, seed=0)
    assert np.array_equal(np.sort(perm), np.arange(g.n))


def test_nd_quality_stable_with_nproc():
    """Paper's headline: quality does not degrade as process count grows."""
    g = G.grid3d(8, 8, 8)
    opcs = [nnz_opc(g, nested_dissection(g, seed=5, nproc=p))[1]
            for p in (1, 8, 32)]
    assert max(opcs) <= min(opcs) * 1.25
