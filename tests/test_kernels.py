"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.graph import Graph
from repro.graphs import generators as G
from repro.kernels import ops
from repro.kernels.ref import diffusion_step_ref, ell_spmv_ref


def make_ell(n, d, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    nbr = rng.integers(0, n, (n, d)).astype(np.int32)
    nbr[rng.random((n, d)) < 0.3] = -1          # ragged padding
    val = rng.standard_normal((n, d)).astype(dtype)
    x = rng.standard_normal(n).astype(dtype)
    return nbr, val, x


@pytest.mark.parametrize("n", [8, 100, 256, 1000, 4096])
@pytest.mark.parametrize("d", [1, 4, 17, 32])
def test_spmv_shapes(n, d):
    nbr, val, x = make_ell(n, d, seed=n * 131 + d)
    got = np.asarray(ops.spmv(nbr, val, x, interpret=True))
    want = np.asarray(ell_spmv_ref(jnp.asarray(nbr), jnp.asarray(val),
                                   jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype,rtol", [(np.float32, 1e-5),
                                        (jnp.bfloat16, 5e-2)])
def test_spmv_dtypes(dtype, rtol):
    nbr, val, x = make_ell(512, 8, seed=7, dtype=np.float32)
    val, x = val.astype(dtype), x.astype(dtype)
    got = np.asarray(ops.spmv(nbr, val, x, interpret=True), np.float32)
    want = np.asarray(ell_spmv_ref(jnp.asarray(nbr), jnp.asarray(val),
                                   jnp.asarray(x)), np.float32)
    np.testing.assert_allclose(got, want, rtol=rtol, atol=rtol)


@pytest.mark.parametrize("block", [8, 64, 512])
def test_spmv_block_invariance(block):
    nbr, val, x = make_ell(640, 6, seed=3)
    got = np.asarray(ops.spmv(nbr, val, x, block_rows=block, interpret=True))
    want = np.asarray(ops.spmv(nbr, val, x, block_rows=128, interpret=True))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_spmv_against_dense():
    g = G.grid2d(12, 12)
    nbr, wgt = g.to_ell()
    x = np.random.default_rng(0).standard_normal(g.n).astype(np.float32)
    dense = np.zeros((g.n, g.n), np.float32)
    src = np.repeat(np.arange(g.n), g.degrees())
    dense[src, g.adjncy] = g.adjwgt
    got = np.asarray(ops.spmv(nbr, wgt.astype(np.float32), x, interpret=True))
    np.testing.assert_allclose(got, dense @ x, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,d", [(64, 4), (300, 9), (1024, 16)])
def test_diffusion_matches_ref(n, d):
    nbr, val, x = make_ell(n, d, seed=n + d)
    val = np.abs(val)                            # diffusion wants w >= 0
    inj = np.zeros(n, np.float32)
    inj[:3], inj[-3:] = 0.5, -0.5
    got = np.asarray(ops.diffuse(nbr, val, x, inj, steps=3, interpret=True))
    ref = jnp.asarray(x)
    for _ in range(3):
        ref = diffusion_step_ref(jnp.asarray(nbr), jnp.asarray(val), ref,
                                 jnp.asarray(inj))
    np.testing.assert_allclose(got, np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_diffusion_separates_grid():
    """Sanity: diffusion from opposite anchors signs the two halves."""
    g = G.grid2d(16, 16)
    nbr, wgt = g.to_ell()
    n = g.n
    inj = np.zeros(n, np.float32)
    left = np.arange(n).reshape(16, 16)[:, 0]
    right = np.arange(n).reshape(16, 16)[:, -1]
    inj[left], inj[right] = 1.0, -1.0
    x = np.zeros(n, np.float32)
    out = np.asarray(ops.diffuse(nbr, wgt.astype(np.float32), x, inj,
                                 steps=60, dt=0.1, mu=0.02, interpret=True))
    grid = out.reshape(16, 16)
    assert (grid[:, :6] > 0).all() and (grid[:, 10:] < 0).all()
