"""Training-infrastructure tests: loss goes down, checkpoint/restart is
bit-exact, fault-tolerance primitives, data pipeline determinism."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, Pipeline, _batch_at, host_slice
from repro.models.lm import init_params
from repro.optim import adamw
from repro.train import checkpoint as ckpt
from repro.train.fault import (Heartbeat, RestartPolicy, StragglerMonitor,
                               plan_elastic_mesh)
from repro.train.step import make_train_step


def make_batch(cfg, step, B=4, S=32):
    d = DataConfig(vocab=cfg.vocab, seq_len=S, global_batch=B)
    return {k: jnp.asarray(v) for k, v in _batch_at(d, step).items()}


def test_loss_decreases():
    cfg = get_config("yi-6b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg, adamw.AdamWConfig(lr=3e-3,
                                                          warmup=5)))
    losses = []
    for i in range(30):
        params, opt, m = step(params, opt, make_batch(cfg, i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_checkpoint_restart_bit_exact(tmp_path):
    cfg = get_config("stablelm-3b").reduced()
    params = init_params(jax.random.PRNGKey(1), cfg)
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg, adamw.AdamWConfig(lr=1e-3)))
    for i in range(3):
        params, opt, _ = step(params, opt, make_batch(cfg, i))
    ckpt.save(str(tmp_path), 3, (params, opt), extra={"arch": cfg.name})
    # continue 2 more steps
    p_a, o_a = params, opt
    metrics_a = []
    for i in range(3, 5):
        p_a, o_a, m = step(p_a, o_a, make_batch(cfg, i))
        metrics_a.append(float(m["loss"]))
    # restore and replay
    st, (p_b, o_b) = ckpt.restore(str(tmp_path), (params, opt))
    assert st == 3
    metrics_b = []
    for i in range(3, 5):
        p_b, o_b, m = step(p_b, o_b, make_batch(cfg, i))
        metrics_b.append(float(m["loss"]))
    assert metrics_a == metrics_b            # bit-exact resume
    for a, b in zip(jax.tree_util.tree_leaves(p_a),
                    jax.tree_util.tree_leaves(p_b)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_atomicity(tmp_path):
    assert ckpt.latest_step(str(tmp_path)) is None
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    st, tree2 = ckpt.restore(str(tmp_path), tree)
    assert st == 7
    assert np.array_equal(np.asarray(tree2["a"]), np.arange(5))


def test_pipeline_determinism_and_sharding():
    d = DataConfig(vocab=100, seq_len=16, global_batch=8, n_hosts=2,
                   host_id=1)
    b1 = _batch_at(d, 5)
    b2 = _batch_at(d, 5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    sl = host_slice(d, b1)
    assert sl["tokens"].shape == (4, 16)
    assert np.array_equal(sl["tokens"], b1["tokens"][4:])
    # hedged read returns identical data (determinism contract)
    d_hedge = DataConfig(vocab=100, seq_len=16, global_batch=8, n_hosts=2,
                         host_id=1, hedge=True)
    pipe = Pipeline(d_hedge, start_step=5)
    step, batch = next(pipe)
    pipe.close()
    assert step == 5
    assert np.array_equal(batch["tokens"], sl["tokens"])


def test_fault_primitives():
    hb = Heartbeat(deadline_s=10)
    hb.beat(0, now=100.0)
    hb.beat(1, now=100.0)
    hb.beat(1, now=115.0)
    assert hb.dead_hosts(now=116.0) == [0]
    assert plan_elastic_mesh(512, 16) == (32, 16)
    assert plan_elastic_mesh(496, 16) == (31, 16)   # non-power-of-two OK
    with pytest.raises(ValueError):
        plan_elastic_mesh(8, 16)
    mon = StragglerMonitor(factor=2.0)
    assert not mon.observe(1.0)
    assert not mon.observe(1.1)
    assert mon.observe(5.0)                          # flagged
    pol = RestartPolicy(max_restarts=2)
    assert pol.should_restart()
    pol.record(); pol.record()
    assert not pol.should_restart()


def test_adamw_optimizes_quadratic():
    params = {"w": jnp.array([3.0, -2.0], jnp.bfloat16)}
    opt = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup=1)
    def loss(p):
        return jnp.sum(p["w"].astype(jnp.float32) ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, opt, gn = adamw.update(g, opt, params, cfg)
    assert float(loss(params)) < 0.05
    # master stays f32 while params are bf16
    assert opt.master["w"].dtype == jnp.float32
    assert params["w"].dtype == jnp.bfloat16
