"""Fault injection + the recovery ladder (DESIGN.md §8).

Chaos contract under test: with a seeded ``FaultPlan`` installed,
every submitted request reaches exactly one terminal status (``ok`` /
``shed`` / ``failed`` — no hangs), every ``ok`` permutation is
bit-identical to the fault-free run (retry, degrade, and cold
re-admission are all parity-preserving), and a corrupt result is never
written to the fingerprint cache.  Plans are pure functions of their
seed, so every scenario here is deterministic.
"""
import numpy as np
import pytest

from repro import obs
from repro.graphs import generators as G
from repro.service import faults
from repro.service.api import OrderingService
from repro.service.cache import FingerprintCache
from repro.service.fingerprint import request_fingerprint
from repro.train.fault import StragglerMonitor


def _counter_fired(name: str) -> bool:
    counters = obs.REGISTRY.snapshot()["counters"]
    return any(k == name or k.startswith(name + "{") for k in counters)


# ------------------------------------------------------------------ #
# the plan: validation, serialization, determinism
# ------------------------------------------------------------------ #
def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        faults.FaultSpec(site="gpu", kind="transient", at=(0,))
    with pytest.raises(ValueError, match="not valid at site"):
        faults.FaultSpec(site="bfs", kind="nan", at=(0,))     # fm only
    with pytest.raises(ValueError, match="not valid at site"):
        faults.FaultSpec(site="result", kind="transient", at=(0,))
    with pytest.raises(ValueError, match="`at` indices"):
        faults.FaultSpec(site="fm", kind="transient")   # no trigger
    assert not faults.is_transient(faults.PersistentFault("x"))
    assert faults.is_transient(faults.TransientFault("x"))


def test_fault_plan_json_roundtrip_and_env(tmp_path, monkeypatch):
    plan = faults.FaultPlan(seed=7, specs=[
        faults.FaultSpec(site="fm", kind="nan", at=(0, 3), count=2),
        faults.FaultSpec(site="wave", kind="delay", rate=0.25,
                         delay_s=0.02, tag="abc")])
    back = faults.FaultPlan.from_json(plan.to_json())
    assert back.seed == plan.seed and back.specs == plan.specs

    monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)
    assert faults.FaultPlan.from_env() is None
    p = tmp_path / "plan.json"
    p.write_text(plan.to_json())
    monkeypatch.setenv("REPRO_FAULT_PLAN", f"@{p}")
    assert faults.FaultPlan.from_env().specs == plan.specs
    monkeypatch.setenv("REPRO_FAULT_PLAN", plan.to_json())
    assert faults.FaultPlan.from_env().seed == 7


def test_injection_deterministic_across_injectors():
    """Fire decisions are pure functions of (seed, site, invocation):
    equal plans inject identically; a different seed does not."""
    def pattern(plan, n=64):
        inj = faults.FaultInjector(plan)
        out = []
        for _ in range(n):
            try:
                inj.check("bfs")
                out.append(0)
            except faults.TransientFault:
                out.append(1)
        return out

    plan = faults.FaultPlan(seed=0, specs=[
        faults.FaultSpec(site="bfs", kind="transient", rate=0.3)])
    first = pattern(plan)
    assert 0 < sum(first) < 64          # rate actually draws both ways
    assert pattern(faults.FaultPlan.from_json(plan.to_json())) == first
    assert pattern(faults.FaultPlan(seed=1, specs=plan.specs)) != first


def test_injection_count_cap_and_snapshot():
    plan = faults.FaultPlan(seed=0, specs=[
        faults.FaultSpec(site="bfs", kind="transient", rate=1.0, count=2)])
    inj = faults.FaultInjector(plan)
    fired = 0
    for _ in range(10):
        try:
            inj.check("bfs")
        except faults.TransientFault:
            fired += 1
    assert fired == 2 and inj.injected == 2
    assert inj.snapshot() == {"bfs:transient": 2}


# ------------------------------------------------------------------ #
# rung 1: transient retry — recovered results stay bit-identical
# ------------------------------------------------------------------ #
def test_transient_fm_retry_recovers_bit_identically():
    g = G.grid2d(12, 12)
    svc0 = OrderingService()
    rid0 = svc0.submit(g, seed=3, nproc=2)
    ref = svc0.drain()[rid0].perm

    obs.REGISTRY.reset()
    plan = faults.FaultPlan(seed=1, specs=[
        faults.FaultSpec(site="fm", kind="transient", at=(0,))])
    with faults.fault_injection(plan) as inj:
        svc = OrderingService()
        rid = svc.submit(g, seed=3, nproc=2)
        res = svc.drain()[rid]
    assert inj.injected == 1
    assert res.status == "ok" and not res.degraded
    assert res.retries >= 1
    assert np.array_equal(res.perm, ref), "retry changed the ordering"
    assert svc.stats()["fault_retries"] >= 1
    assert _counter_fired("repro_service_retries_total")
    assert _counter_fired("repro_service_faults_injected_total")


def test_wave_transient_fault_retries_within_pump():
    g = G.grid2d(10, 10)
    svc0 = OrderingService()
    rid0 = svc0.submit(g, seed=0)
    ref = svc0.drain()[rid0].perm

    plan = faults.FaultPlan(seed=0, specs=[
        faults.FaultSpec(site="wave", kind="transient", at=(0,))])
    with faults.fault_injection(plan):
        svc = OrderingService()
        rid = svc.submit(g, seed=0)
        res = svc.drain()[rid]
    assert res.status == "ok" and res.retries >= 1
    assert np.array_equal(res.perm, ref)


# ------------------------------------------------------------------ #
# rung 2: kernel degrade — NaN corruption takes the validation path
# ------------------------------------------------------------------ #
def test_nan_corruption_degrades_and_recovers():
    g = G.grid2d(12, 12)
    svc0 = OrderingService()
    rid0 = svc0.submit(g, seed=3, nproc=2)
    ref = svc0.drain()[rid0].perm

    plan = faults.FaultPlan(seed=1, specs=[
        faults.FaultSpec(site="fm", kind="nan", at=(0,))])
    with faults.fault_injection(plan):
        svc = OrderingService()
        rid = svc.submit(g, seed=3, nproc=2)
        res = svc.drain()[rid]
        # a later clean request must not inherit the degrade (it is
        # per-request-sticky, not process-global)
        g2 = G.grid2d(9, 9)
        rid2 = svc.submit(g2, seed=0)
        res2 = svc.drain()[rid2]
    assert res.status == "ok" and res.degraded
    assert np.array_equal(res.perm, ref), \
        "degraded kernel path lost bit-parity"
    assert svc.stats()["degraded"] == 1
    assert _counter_fired("repro_service_degraded_total")
    assert res2.status == "ok" and not res2.degraded


# ------------------------------------------------------------------ #
# rung 3: excision + cold re-admission
# ------------------------------------------------------------------ #
def test_persistent_fm_excises_and_readmits_cold():
    g = G.grid2d(12, 12)
    svc0 = OrderingService()
    rid0 = svc0.submit(g, seed=3, nproc=2)
    ref = svc0.drain()[rid0].perm

    # the whole first fm chain fails: the group ladder burns fused /
    # hoisted / oracle (fm invocations 0-2), the isolation singleton
    # burns one more (3) — the tree is excised and re-admitted cold,
    # whose dispatches (4+) run clean
    plan = faults.FaultPlan(seed=1, specs=[
        faults.FaultSpec(site="fm", kind="persistent", at=(0, 1, 2, 3))])
    with faults.fault_injection(plan):
        svc = OrderingService()
        rid = svc.submit(g, seed=3, nproc=2)
        res = svc.drain()[rid]
    assert res.status == "ok"
    assert np.array_equal(res.perm, ref), \
        "excise + cold readmit lost bit-parity"
    assert svc._router.recovery.isolations >= 1
    assert _counter_fired("repro_service_readmits_total")


def test_unrecoverable_failure_fans_out_to_all_riders():
    """Satellite: a fingerprint that fails beyond the readmit budget
    resolves EVERY coalesced rider ``status=failed`` — none hang in
    ``poll()`` — while co-riding fingerprints of the same drain stay
    ``ok``, and nothing corrupt reaches the cache."""
    g_bad = G.grid2d(11, 11)
    g_ok = G.grid2d(9, 9)
    svc0 = OrderingService()
    rid0 = svc0.submit(g_ok, seed=0)
    ref_ok = svc0.drain()[rid0].perm

    svc = OrderingService()
    fp_bad = request_fingerprint(g_bad, 0, 1, svc.default_cfg)
    # tag-filtered unbounded corruption: every assembled result of the
    # doomed fingerprint is invalidated, exhausting its readmits
    plan = faults.FaultPlan(seed=0, specs=[
        faults.FaultSpec(site="result", kind="corrupt_perm", rate=1.0,
                         tag=fp_bad)])
    with faults.fault_injection(plan):
        rid_a = svc.submit(g_bad, seed=0)
        rid_b = svc.submit(g_bad, seed=0)       # coalesced duplicate
        rid_c = svc.submit(g_ok, seed=0)        # innocent co-rider
        svc.drain()
    for rid in (rid_a, rid_b, rid_c):
        assert svc.poll(rid) is not None, "rider hung in poll()"
    for rid in (rid_a, rid_b):
        res = svc.poll(rid)
        assert res.status == "failed" and res.perm is None
    assert svc.poll(rid_c).status == "ok"
    assert np.array_equal(svc.poll(rid_c).perm, ref_ok)
    assert fp_bad not in svc.cache, "corrupt fingerprint was cached"
    assert svc.stats()["failed"] == 2
    assert _counter_fired("repro_service_failed_total")


# ------------------------------------------------------------------ #
# rung 4: validation — never cache corrupt
# ------------------------------------------------------------------ #
def test_corrupt_result_readmits_and_never_caches():
    g = G.grid2d(10, 10)
    svc0 = OrderingService()
    rid0 = svc0.submit(g, seed=0)
    ref = svc0.drain()[rid0].perm

    plan = faults.FaultPlan(seed=0, specs=[
        faults.FaultSpec(site="result", kind="corrupt_perm", at=(0,))])
    with faults.fault_injection(plan):
        svc = OrderingService()
        rid = svc.submit(g, seed=0)
        res = svc.drain()[rid]
    assert res.status == "ok"
    assert np.array_equal(res.perm, ref)
    # the cached entry is the VALID re-run, not the corrupted first try
    fp = request_fingerprint(g, 0, 1, svc.default_cfg)
    cached = svc.cache.get(fp)
    assert cached is not None and np.array_equal(cached, ref)


def test_cache_put_rejects_non_permutation():
    cache = FingerprintCache(4)
    with pytest.raises(ValueError, match="refusing to cache"):
        cache.put("fp1", np.array([0, 0, 2]))           # duplicate
    with pytest.raises(ValueError, match="refusing to cache"):
        cache.put("fp2", np.array([0.5, 1.5]))          # not integers
    with pytest.raises(ValueError, match="refusing to cache"):
        cache.put("fp3", np.array([[0, 1]]))            # not 1-d
    assert len(cache) == 0
    cache.put("fp4", np.array([2, 0, 1]))
    assert len(cache) == 1


# ------------------------------------------------------------------ #
# satellite: pump unwind safety (the frontier survives a raise)
# ------------------------------------------------------------------ #
def test_pump_exception_restores_frontier(monkeypatch):
    import repro.service.router as router_mod
    g = G.grid2d(10, 10)
    svc0 = OrderingService()
    rid0 = svc0.submit(g, seed=1)
    ref = svc0.drain()[rid0].perm

    real = router_mod.execute_wave
    state = {"raised": False}

    def wedged(*args, **kwargs):
        if not state["raised"]:
            state["raised"] = True
            raise RuntimeError("wedged backend")
        return real(*args, **kwargs)

    monkeypatch.setattr(router_mod, "execute_wave", wedged)
    svc = OrderingService()
    rid = svc.submit(g, seed=1)
    with pytest.raises(RuntimeError, match="wedged backend"):
        svc.pump()
    # the frontier was restored on unwind: the suspended generator is
    # still resumable and the next drain completes bit-identically
    # (before the unwind fix this tripped "router finished with live
    # tasks" — the wave's tasks had been popped off the frontier)
    res = svc.drain()[rid]
    assert res.status == "ok"
    assert np.array_equal(res.perm, ref)


# ------------------------------------------------------------------ #
# satellite: straggler waves flagged via the router EWMA
# ------------------------------------------------------------------ #
def test_straggler_wave_flagged_and_counted():
    svc = OrderingService()
    rid0 = svc.submit(G.grid2d(10, 10), seed=0)
    svc.drain()                         # absorb compile-heavy waves
    assert svc.poll(rid0).status == "ok"
    assert svc._router.stats()["waves"] >= 1
    # re-seed the wave EWMA at steady-state scale, then inject one
    # delayed wave: 0.3s against a ~0.1ms EWMA is far beyond any factor
    svc._router._stragglers = StragglerMonitor(
        factor=svc._router.cfg.straggler_factor)
    svc._router._stragglers.observe(1e-4)
    obs.REGISTRY.reset()
    plan = faults.FaultPlan(seed=0, specs=[
        faults.FaultSpec(site="wave", kind="delay", delay_s=0.3,
                         rate=1.0, count=1)])
    with faults.fault_injection(plan):
        rid = svc.submit(G.grid2d(12, 12), seed=0)
        svc.drain()
    assert svc.poll(rid).status == "ok"
    st = svc.stats()["router"]
    assert st["straggler_waves"] >= 1
    assert st["wave_ewma_s"] > 0.0 and st["waves"] > 0
    assert _counter_fired("repro_router_straggler_waves_total")


# ------------------------------------------------------------------ #
# rung 5: deadline-feasibility shedding
# ------------------------------------------------------------------ #
def test_infeasible_deadline_shed_deterministically():
    svc = OrderingService()
    rid0 = svc.submit(G.grid2d(9, 9), seed=0, deadline_s=1000.0)
    svc.drain()                         # xs exec estimate now exists
    assert svc.poll(rid0).status == "ok"
    n_cache = len(svc.cache)

    shed_rids = [svc.submit(G.grid2d(9, 9 + k), seed=0, deadline_s=0.0)
                 for k in range(1, 4)]
    svc.drain()
    for rid in shed_rids:
        res = svc.poll(rid)
        assert res is not None, "shed rider hung in poll()"
        assert res.status == "shed" and res.perm is None
        assert res.deadline_missed is None      # never ran, never missed
        assert res.exec_s == 0.0
    st = svc.stats()
    assert st["shed"] == 3
    assert len(svc.cache) == n_cache, "shed request produced work"
    # shed never pollutes the SLO ledger
    assert st["deadline_miss_rate"] == 0.0
    assert _counter_fired("repro_service_shed_total")
    # feasible work still flows afterwards
    rid = svc.submit(G.grid2d(8, 8), seed=0, deadline_s=1000.0)
    svc.drain()
    assert svc.poll(rid).status == "ok"


def test_shedding_disabled_by_policy_config():
    from repro.service.sched_policy import PolicyConfig, SchedPolicy
    svc = OrderingService(policy=SchedPolicy(PolicyConfig(
        shed_infeasible=False)))
    svc.submit(G.grid2d(9, 9), seed=0, deadline_s=1000.0)
    svc.drain()
    rid = svc.submit(G.grid2d(9, 10), seed=0, deadline_s=0.0)
    svc.drain()
    res = svc.poll(rid)
    assert res.status == "ok" and res.deadline_missed is True


def test_small_class_zero_miss_under_mixed_chaos_and_slo_load():
    """The PR 9 CI invariant, now under chaos: with transient faults
    and stragglers injected, feasible small-class requests still make
    their deadlines (recovery is bounded), infeasible ones shed
    cleanly, and every request reaches a terminal status."""
    # n ≥ 100: small enough to stay class xs, big enough that each
    # ordering rides real router waves the plan can actually hit
    graphs = [G.grid2d(10 + k, 10) for k in range(4)]
    svc0 = OrderingService()
    rids0 = [svc0.submit(g, seed=5) for g in graphs]
    svc0.drain()
    refs = [svc0.poll(r).perm for r in rids0]

    svc = OrderingService()
    svc.submit(G.grid2d(9, 9), seed=0, deadline_s=1000.0)
    svc.drain()                         # estimate for the shed check
    plan = faults.FaultPlan(seed=11, specs=[
        faults.FaultSpec(site="fm", kind="transient", rate=0.1, count=3),
        faults.FaultSpec(site="bfs", kind="delay", rate=0.1,
                         delay_s=0.01, count=5)])
    with faults.fault_injection(plan):
        ok_rids = [svc.submit(g, seed=5, deadline_s=1000.0)
                   for g in graphs]
        bad_rids = [svc.submit(G.grid2d(13, 9 + k), seed=0,
                               deadline_s=0.0) for k in range(2)]
        svc.drain()
    for rid in ok_rids + bad_rids:
        assert svc.poll(rid) is not None, "request hung under chaos"
    for rid, ref in zip(ok_rids, refs):
        res = svc.poll(rid)
        assert res.status == "ok"
        assert res.deadline_missed is False
        assert np.array_equal(res.perm, ref), \
            "chaos-recovered ordering lost bit-parity"
    for rid in bad_rids:
        assert svc.poll(rid).status == "shed"
    st = svc.stats()
    assert st["shed"] == 2 and st["failed"] == 0
    assert st["deadline_miss_rate"] == 0.0, \
        "small-class zero-miss invariant broken under chaos"
