"""Paper Tables 2–3 / Figures 6–9: OPC and time vs process count,
PT-Scotch-like vs ParMETIS-like.

Claims under test: O_PTS stays ~flat (sometimes improves) with p; O_PM
degrades severely; O_PM/O_PTS grows with p (paper: up to ~2× on 64 procs).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import quick, row, timer
from repro.core.baselines import parmetis_like, pt_scotch_like
from repro.graphs import generators as G
from repro.sparse.symbolic import nnz_opc


def suite():
    if quick():
        return {
            "altr4-like":   lambda: G.grid3d(11, 11, 11),
            "audikw1-like": lambda: G.grid3d(10, 10, 10, stencil=27),
            "qimonda-like": lambda: G.circuit(6_000, seed=7),
            "cage-like":    lambda: G.cage_like(3_000, seed=5),
        }
    return {
        "altr4-like":   lambda: G.grid3d(30, 30, 30),
        "audikw1-like": lambda: G.grid3d(21, 21, 21, stencil=27),
        "qimonda-like": lambda: G.circuit(120_000, seed=7),
        "cage-like":    lambda: G.cage_like(40_000, seed=5),
    }


def procs():
    return (2, 8, 64) if quick() else (2, 4, 8, 16, 32, 64)


def main() -> None:
    for name, ctor in suite().items():
        g = ctor()
        for p in procs():
            with timer() as t_pts:
                perm = pt_scotch_like(g, seed=0, nproc=p)
            o_pts = nnz_opc(g, perm)[1]
            with timer() as t_pm:
                perm_pm = parmetis_like(g, seed=0, nproc=p)
            o_pm = nnz_opc(g, perm_pm)[1]
            row(f"table2/{name}/p{p}", t_pts.us,
                O_PTS=f"{o_pts:.3e}", O_PM=f"{o_pm:.3e}",
                t_PTS_s=round(t_pts.us / 1e6, 2),
                t_PM_s=round(t_pm.us / 1e6, 2),
                ratio=round(o_pm / o_pts, 3))


if __name__ == "__main__":
    main()
