"""Ordering-service throughput, SLO preemption and warm starts.

Measures orderings/sec over a mixed-size request stream containing
duplicate submissions (the realistic traffic shape the fingerprint cache
exists for), and verifies the service returns *identical* permutations —
hence identical OPC — to looped ``core.nd.nested_dissection`` calls, on
the paper's Table-2-style graphs as well.

Two SLO sections exercise the serving control plane (DESIGN.md §7):

* **mixed-deadline workload** — small interactive requests arrive while
  a cage-like ordering is already in flight; the pump loop must park
  the big ordering between waves so the small classes keep their p95
  attributed exec under the gate (and miss no deadlines) instead of
  queuing behind ~seconds of cage waves;
* **warm start** — an isomorphic-modulo-weights repeat must either cost
  < 0.5x its cold run (replaying the cached separator tree) or fall
  back to the exact path.

A **chaos** section (DESIGN.md §8; standalone via ``--chaos``) replays
a request stream under a seeded ``FaultPlan`` and gates the recovery
ladder: every request reaches a terminal status (zero hangs), every
``ok`` permutation is bit-identical to the fault-free run, and the
fingerprint cache holds zero faulted entries.

Emits ``BENCH_service.json`` next to the CWD so the perf trajectory is
tracked from this PR onward; the SLO gates are asserted *after* the
artifact is written so a failed bound still leaves the numbers behind.
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import quick, row
from repro.core.nd import nested_dissection
from repro.graphs import generators as G
from repro.service import OrderingService
from repro.service import faults
from repro.service.fingerprint import request_fingerprint
from repro.sparse.symbolic import nnz_opc


def workload():
    """(unique graphs, request stream of (graph_idx, seed, nproc))."""
    if quick():
        uniq = [G.grid2d(14, 14), G.grid3d(6, 6, 6), G.grid2d(16, 12),
                G.circuit(420, seed=3), G.grid2d(13, 11),
                G.rgg2d(300, seed=2), G.grid3d(7, 7, 7), G.grid2d(18, 9)]
        reps = 3                         # 24 requests over 8 unique graphs
    else:
        uniq = [G.grid3d(12, 12, 12), G.grid2d(48, 48), G.circuit(4000, seed=3),
                G.rgg2d(3000, seed=2), G.grid3d(10, 10, 14),
                G.cage_like(2500, seed=5), G.grid2d(40, 52),
                G.grid3d(11, 11, 11)]
        reps = 3
    stream = [(i, i, 4) for _ in range(reps) for i in range(len(uniq))]
    return uniq, stream


def quality_graphs():
    """Table-2-style graphs for the OPC-identity check."""
    if quick():
        return {"altr4-like": G.grid3d(9, 9, 9),
                "cage-like": G.cage_like(1000, seed=5)}
    return {"altr4-like": G.grid3d(11, 11, 11),
            "qimonda-like": G.circuit(6000, seed=7),
            "cage-like": G.cage_like(3000, seed=5)}


def run_service(uniq, stream):
    """Submit the stream in arrival waves, draining between waves.

    The first wave computes every unique problem (bucketed); later waves
    of the stream repeat fingerprints and resolve from the cache at
    submit time — the traffic pattern the service is built for.
    """
    svc = OrderingService()
    wave = max(len(uniq), 1)
    t0 = time.perf_counter()
    rids = []
    for k in range(0, len(stream), wave):
        for i, s, p in stream[k:k + wave]:
            rids.append(svc.submit(uniq[i], seed=s, nproc=p))
        svc.drain()
    dt = time.perf_counter() - t0
    perms = [svc.poll(r).perm for r in rids]
    return perms, dt, svc.stats()


def run_loop(uniq, stream):
    t0 = time.perf_counter()
    perms = [nested_dissection(uniq[i], seed=s, nproc=p)
             for i, s, p in stream]
    return perms, time.perf_counter() - t0


def run_slo():
    """Mixed-deadline workload: smalls preempt an in-flight cage.

    A cage-like ordering (class ``m``) is admitted first; small
    xs/s-class requests with tight deadlines then arrive at successive
    pump boundaries.  The policy parks the cage whenever a smaller
    class is live, so the smalls' attributed exec stays bounded by
    their own (tiny) waves — the conflated number this replaces billed
    every small request the cage's full ~5.6s batch wall.
    """
    big = (G.cage_like(1200, seed=5) if quick()
           else G.cage_like(3000, seed=5))
    smalls = ([G.grid2d(10 + i, 10) for i in range(4)]       # xs
              + [G.grid2d(17, 16), G.grid2d(18, 15)])        # s
    warm = OrderingService()                 # compile both shapes' jits
    for i, g in enumerate(smalls):
        warm.submit(g, seed=i, nproc=2)
    warm.submit(big, seed=0, nproc=8)
    warm.drain()

    svc = OrderingService()
    t0 = time.perf_counter()
    rid_big = svc.submit(big, seed=0, nproc=8, deadline_s=120.0)
    rids, i = [], 0
    for _ in range(10000):
        if svc.poll(rid_big) is not None:
            break
        if i < len(smalls):                  # arrival at a wave boundary
            rids.append(svc.submit(smalls[i], seed=i, nproc=2,
                                   deadline_s=2.0))
            i += 1
        svc.pump()
    svc.drain()
    wall = time.perf_counter() - t0
    for rid, g, seed in zip(rids, smalls, range(len(smalls))):
        assert np.array_equal(svc.poll(rid).perm,
                              nested_dissection(g, seed=seed, nproc=2)), \
            "preempted small request lost parity"
    assert np.array_equal(svc.poll(rid_big).perm,
                          nested_dissection(big, seed=0, nproc=8)), \
        "preempted cage ordering lost parity"

    st = svc.stats()
    by = st["by_class"]
    small = [c for c in ("xs", "s") if c in by]
    out = {
        "n_small": len(rids),
        "big_n": big.n,
        "wall_s": round(wall, 3),
        "pumps": st["pumps"],
        "p95_exec_ms_by_class": {c: by[c]["p95_exec_ms"] for c in by},
        "deadline_miss_rate_by_class": {
            c: by[c]["deadline_miss_rate"] for c in by},
        "deadline_miss_rate": st["deadline_miss_rate"],
        "small_p95_exec_ms": max(by[c]["p95_exec_ms"] for c in small),
        "small_deadline_misses": sum(by[c]["deadline_misses"]
                                     for c in small),
        "big_exec_ms": round(svc.poll(rid_big).exec_s * 1e3, 3),
    }
    row("service/slo", wall / max(len(rids), 1) * 1e6,
        small_p95_exec_ms=out["small_p95_exec_ms"],
        big_exec_ms=out["big_exec_ms"],
        misses=out["small_deadline_misses"], pumps=out["pumps"])
    return out


def run_warm():
    """Isomorphic-modulo-weights repeat: warm replay vs cold cost."""
    g = G.grid3d(9, 9, 9) if quick() else G.grid3d(12, 12, 12)
    svc = OrderingService(warm_starts=True)
    rid0 = svc.submit(g, seed=0, nproc=4)
    svc.drain()
    cold = svc.poll(rid0)
    rid1 = svc.submit(g, seed=11, nproc=4)   # same topology, new seed
    svc.drain()
    wres = svc.poll(rid1)
    assert np.array_equal(np.sort(wres.perm), np.arange(g.n)), \
        "warm-started result is not a permutation"
    st = svc.stats()
    ratio = wres.exec_s / max(cold.exec_s, 1e-9)
    out = {
        "cold_exec_ms": round(cold.exec_s * 1e3, 3),
        "warm_exec_ms": round(wres.exec_s * 1e3, 3),
        "cost_ratio": round(ratio, 4),
        "hits": st["warm_hits"],
        "fallbacks": st["warm_fallbacks"],
        "opc_cold": float(nnz_opc(g, cold.perm)[1]),
        "opc_warm": float(nnz_opc(g, wres.perm)[1]),
    }
    row("service/warm", wres.exec_s * 1e6,
        cost_ratio=out["cost_ratio"], hits=out["hits"],
        fallbacks=out["fallbacks"])
    return out


def chaos_plan() -> faults.FaultPlan:
    """The bench's seeded chaos schedule: one of every fault type, at
    every site layer — dispatch raises, kernel corruption, result
    corruption, a wave-level transient, and stragglers."""
    return faults.FaultPlan(seed=11, specs=[
        faults.FaultSpec(site="fm", kind="transient", rate=0.15, count=4),
        faults.FaultSpec(site="fm", kind="nan", at=(2,)),
        faults.FaultSpec(site="bfs", kind="delay", rate=0.1,
                         delay_s=0.01, count=6),
        faults.FaultSpec(site="wave", kind="transient", at=(1,)),
        faults.FaultSpec(site="result", kind="corrupt_perm", at=(0,)),
    ])


def run_chaos():
    """Fault-injected replay of a mixed stream (the chaos gate).

    The same requests run fault-free first (the parity reference and
    the jit warm-up), then again — new seeds, so nothing resolves from
    the cache — under ``chaos_plan()``, plus a duplicate pair (failure
    fan-out coverage) and two infeasible-deadline requests (the shed
    rung).  Gates: 100% terminal statuses, ``ok`` ⇒ bit-identical,
    cache clean.
    """
    graphs = [G.grid2d(14, 14), G.grid3d(6, 6, 6), G.grid2d(16, 12),
              G.grid2d(13, 11), G.grid2d(12, 12), G.grid3d(5, 5, 6)]
    seeds = [100 + k for k in range(len(graphs))]
    refs = [nested_dissection(g, seed=s, nproc=2)
            for g, s in zip(graphs, seeds)]

    svc = OrderingService()
    # estimate warm-up: one request per class so the feasibility check
    # has measured exec percentiles to shed against
    for g in (G.grid2d(10, 10), G.grid2d(18, 15)):
        svc.submit(g, seed=0, nproc=2)
    svc.drain()

    t0 = time.perf_counter()
    with faults.fault_injection(chaos_plan()) as inj:
        rids = [svc.submit(g, seed=s, nproc=2)
                for g, s in zip(graphs, seeds)]
        dup_rids = [svc.submit(graphs[0], seed=seeds[0], nproc=2)
                    for _ in range(2)]          # coalesced duplicates
        shed_rids = [svc.submit(G.grid2d(15, 13 + k), seed=0, nproc=2,
                                deadline_s=0.0) for k in range(2)]
        svc.drain()
    wall = time.perf_counter() - t0

    all_rids = rids + dup_rids + shed_rids
    assert all(svc.poll(r) is not None for r in all_rids), \
        "chaos gate: a request hung without a terminal status"
    statuses = [svc.poll(r).status for r in all_rids]
    assert all(s in ("ok", "shed", "failed") for s in statuses)
    ok_identical = True
    for rid, ref in zip(rids + dup_rids, refs + [refs[0]] * 2):
        res = svc.poll(rid)
        if res.status == "ok":
            ok_identical &= bool(np.array_equal(res.perm, ref))
    assert ok_identical, \
        "chaos gate: an ok result differs from the fault-free run"
    cache_clean = True
    for g, s, ref in zip(graphs, seeds, refs):
        cached = svc.cache.get(request_fingerprint(
            g, s, 2, svc.default_cfg))
        if cached is not None:
            cache_clean &= bool(np.array_equal(cached, ref))
    assert cache_clean, "chaos gate: a faulted entry reached the cache"
    assert inj.injected > 0, "chaos plan injected nothing (vacuous gate)"
    assert all(svc.poll(r).status == "shed" for r in shed_rids), \
        "infeasible-deadline requests were not shed"

    st = svc.stats()
    out = {
        "n_requests": len(all_rids),
        "wall_s": round(wall, 3),
        "n_injected": inj.injected,
        "injected_by": inj.snapshot(),
        "terminal": {s: statuses.count(s)
                     for s in ("ok", "shed", "failed")},
        "ok_bit_identical": ok_identical,
        "cache_clean": cache_clean,
        "retries": st["fault_retries"],
        "degraded": st["degraded"],
        "isolations": st["router"]["isolations"],
        "straggler_waves": st["router"]["straggler_waves"],
    }
    row("service/chaos", wall / len(all_rids) * 1e6,
        injected=out["n_injected"], ok=out["terminal"]["ok"],
        shed=out["terminal"]["shed"], failed=out["terminal"]["failed"],
        retries=out["retries"], degraded=out["degraded"])
    return out


def main() -> None:
    uniq, stream = workload()
    # one warmup pass per path builds the jit caches both will reuse
    run_service(uniq, stream[:len(uniq)])
    run_loop(uniq, stream[:4])

    perms_svc, dt_svc, stats = run_service(uniq, stream)
    perms_loop, dt_loop = run_loop(uniq, stream)
    for k, (a, b) in enumerate(zip(perms_svc, perms_loop)):
        assert np.array_equal(a, b), f"service != loop on request {k}"

    n_req = len(stream)
    ops_svc = n_req / dt_svc
    ops_loop = n_req / dt_loop
    speedup = ops_svc / ops_loop
    row("service/throughput", dt_svc / n_req * 1e6,
        ops_svc=round(ops_svc, 2), ops_loop=round(ops_loop, 2),
        speedup=round(speedup, 2),
        hit_rate=stats["cache_hit_rate"],
        p50_ms=stats["p50_latency_ms"], p95_ms=stats["p95_latency_ms"],
        p95_wait_ms=stats["p95_queue_wait_ms"],
        p95_exec_ms=stats["p95_exec_ms"])

    opc = {}
    for name, g in quality_graphs().items():
        svc = OrderingService()
        rid = svc.submit(g, seed=0, nproc=8)
        svc.drain()
        perm_svc = svc.poll(rid).perm
        perm_seq = nested_dissection(g, seed=0, nproc=8)
        assert np.array_equal(perm_svc, perm_seq), f"OPC drift on {name}"
        o = nnz_opc(g, perm_svc)[1]
        opc[name] = o
        row(f"service/opc/{name}", 0.0, OPC=f"{o:.3e}", identical=True)

    slo = run_slo()
    warm = run_warm()
    chaos = run_chaos()

    out = {
        "n_requests": n_req,
        "n_unique": len(uniq),
        "orderings_per_sec_service": round(ops_svc, 3),
        "orderings_per_sec_loop": round(ops_loop, 3),
        "speedup": round(speedup, 3),
        "cache_hit_rate": stats["cache_hit_rate"],
        # end-to-end latency plus its components: queue wait (drain
        # cadence) and batched execution time — the old conflated p95
        # mostly measured how long the first wave sat in the queue
        "p50_latency_ms": stats["p50_latency_ms"],
        "p95_latency_ms": stats["p95_latency_ms"],
        "p50_queue_wait_ms": stats["p50_queue_wait_ms"],
        "p95_queue_wait_ms": stats["p95_queue_wait_ms"],
        "p50_exec_ms": stats["p50_exec_ms"],
        "p95_exec_ms": stats["p95_exec_ms"],
        # per-size-class exec percentiles (xs/s/m/l, see api.size_class):
        # the SLO-queue work needs p95 attribution by request size, not
        # one pooled percentile dominated by the biggest graphs
        "exec_ms_by_class": stats["by_class"],
        # SLO control-plane sections (see run_slo/run_warm docstrings);
        # the top-level mirrors are the keys CI's service-slo job gates
        "slo": slo,
        "warm": warm,
        # fault-injected replay (run_chaos docstring): its hard gates
        # are asserted inside the section; these keys are the recorded
        # evidence (and what CI's chaos job reads)
        "chaos": chaos,
        "p95_exec_ms_by_class": slo["p95_exec_ms_by_class"],
        "deadline_miss_rate": slo["deadline_miss_rate"],
        "opc": {k: float(v) for k, v in opc.items()},
        "quick": quick(),
    }
    with open("BENCH_service.json", "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote BENCH_service.json (speedup {speedup:.2f}x)")

    # SLO gates, asserted after the artifact dump so a failed bound
    # still leaves the numbers behind (the dnd_bench idiom):
    # small-class requests must keep their attributed p95 exec under
    # 100ms and miss no deadlines while a cage-like ordering is in
    # flight, and a warm-started structural repeat must either cost
    # < 0.5x its cold run or have fallen back to the exact path
    assert slo["small_p95_exec_ms"] <= 100.0, (
        f"small-class p95 exec {slo['small_p95_exec_ms']}ms > 100ms "
        "with a cage-like ordering in flight")
    assert slo["small_deadline_misses"] == 0, (
        f"{slo['small_deadline_misses']} small-class deadline misses")
    assert warm["cost_ratio"] < 0.5 or warm["fallbacks"] > 0, (
        f"warm repeat cost {warm['cost_ratio']}x cold without fallback")


def chaos_main() -> None:
    """Standalone chaos gate (CI's ``chaos`` job): only the
    fault-injected section, written to ``BENCH_service_chaos.json``."""
    out = {"chaos": run_chaos(), "quick": quick()}
    with open("BENCH_service_chaos.json", "w") as f:
        json.dump(out, f, indent=2)
    print("# wrote BENCH_service_chaos.json "
          f"({out['chaos']['n_injected']} faults injected, "
          f"terminal={out['chaos']['terminal']})")


if __name__ == "__main__":
    if "--chaos" in sys.argv:
        chaos_main()
    else:
        main()
