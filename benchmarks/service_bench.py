"""Ordering-service throughput vs looped sequential driver.

Measures orderings/sec over a mixed-size request stream containing
duplicate submissions (the realistic traffic shape the fingerprint cache
exists for), and verifies the service returns *identical* permutations —
hence identical OPC — to looped ``core.nd.nested_dissection`` calls, on
the paper's Table-2-style graphs as well.

Emits ``BENCH_service.json`` next to the CWD so the perf trajectory is
tracked from this PR onward.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import quick, row
from repro.core.nd import nested_dissection
from repro.graphs import generators as G
from repro.service import OrderingService
from repro.sparse.symbolic import nnz_opc


def workload():
    """(unique graphs, request stream of (graph_idx, seed, nproc))."""
    if quick():
        uniq = [G.grid2d(14, 14), G.grid3d(6, 6, 6), G.grid2d(16, 12),
                G.circuit(420, seed=3), G.grid2d(13, 11),
                G.rgg2d(300, seed=2), G.grid3d(7, 7, 7), G.grid2d(18, 9)]
        reps = 3                         # 24 requests over 8 unique graphs
    else:
        uniq = [G.grid3d(12, 12, 12), G.grid2d(48, 48), G.circuit(4000, seed=3),
                G.rgg2d(3000, seed=2), G.grid3d(10, 10, 14),
                G.cage_like(2500, seed=5), G.grid2d(40, 52),
                G.grid3d(11, 11, 11)]
        reps = 3
    stream = [(i, i, 4) for _ in range(reps) for i in range(len(uniq))]
    return uniq, stream


def quality_graphs():
    """Table-2-style graphs for the OPC-identity check."""
    if quick():
        return {"altr4-like": G.grid3d(9, 9, 9),
                "cage-like": G.cage_like(1000, seed=5)}
    return {"altr4-like": G.grid3d(11, 11, 11),
            "qimonda-like": G.circuit(6000, seed=7),
            "cage-like": G.cage_like(3000, seed=5)}


def run_service(uniq, stream):
    """Submit the stream in arrival waves, draining between waves.

    The first wave computes every unique problem (bucketed); later waves
    of the stream repeat fingerprints and resolve from the cache at
    submit time — the traffic pattern the service is built for.
    """
    svc = OrderingService()
    wave = max(len(uniq), 1)
    t0 = time.perf_counter()
    rids = []
    for k in range(0, len(stream), wave):
        for i, s, p in stream[k:k + wave]:
            rids.append(svc.submit(uniq[i], seed=s, nproc=p))
        svc.drain()
    dt = time.perf_counter() - t0
    perms = [svc.poll(r).perm for r in rids]
    return perms, dt, svc.stats()


def run_loop(uniq, stream):
    t0 = time.perf_counter()
    perms = [nested_dissection(uniq[i], seed=s, nproc=p)
             for i, s, p in stream]
    return perms, time.perf_counter() - t0


def main() -> None:
    uniq, stream = workload()
    # one warmup pass per path builds the jit caches both will reuse
    run_service(uniq, stream[:len(uniq)])
    run_loop(uniq, stream[:4])

    perms_svc, dt_svc, stats = run_service(uniq, stream)
    perms_loop, dt_loop = run_loop(uniq, stream)
    for k, (a, b) in enumerate(zip(perms_svc, perms_loop)):
        assert np.array_equal(a, b), f"service != loop on request {k}"

    n_req = len(stream)
    ops_svc = n_req / dt_svc
    ops_loop = n_req / dt_loop
    speedup = ops_svc / ops_loop
    row("service/throughput", dt_svc / n_req * 1e6,
        ops_svc=round(ops_svc, 2), ops_loop=round(ops_loop, 2),
        speedup=round(speedup, 2),
        hit_rate=stats["cache_hit_rate"],
        p50_ms=stats["p50_latency_ms"], p95_ms=stats["p95_latency_ms"],
        p95_wait_ms=stats["p95_queue_wait_ms"],
        p95_exec_ms=stats["p95_exec_ms"])

    opc = {}
    for name, g in quality_graphs().items():
        svc = OrderingService()
        rid = svc.submit(g, seed=0, nproc=8)
        svc.drain()
        perm_svc = svc.poll(rid).perm
        perm_seq = nested_dissection(g, seed=0, nproc=8)
        assert np.array_equal(perm_svc, perm_seq), f"OPC drift on {name}"
        o = nnz_opc(g, perm_svc)[1]
        opc[name] = o
        row(f"service/opc/{name}", 0.0, OPC=f"{o:.3e}", identical=True)

    out = {
        "n_requests": n_req,
        "n_unique": len(uniq),
        "orderings_per_sec_service": round(ops_svc, 3),
        "orderings_per_sec_loop": round(ops_loop, 3),
        "speedup": round(speedup, 3),
        "cache_hit_rate": stats["cache_hit_rate"],
        # end-to-end latency plus its components: queue wait (drain
        # cadence) and batched execution time — the old conflated p95
        # mostly measured how long the first wave sat in the queue
        "p50_latency_ms": stats["p50_latency_ms"],
        "p95_latency_ms": stats["p95_latency_ms"],
        "p50_queue_wait_ms": stats["p50_queue_wait_ms"],
        "p95_queue_wait_ms": stats["p95_queue_wait_ms"],
        "p50_exec_ms": stats["p50_exec_ms"],
        "p95_exec_ms": stats["p95_exec_ms"],
        # per-size-class exec percentiles (xs/s/m/l, see api.size_class):
        # the SLO-queue work needs p95 attribution by request size, not
        # one pooled percentile dominated by the biggest graphs
        "exec_ms_by_class": stats["by_class"],
        "opc": {k: float(v) for k, v in opc.items()},
        "quick": quick(),
    }
    with open("BENCH_service.json", "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote BENCH_service.json (speedup {speedup:.2f}x)")


if __name__ == "__main__":
    main()
