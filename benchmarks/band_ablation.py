"""§3.3 claim: band FM (width 3) matches or beats unconstrained FM, and
width 3 is the right default (width 1 over-constrains, width ≥ 3 plateaus).
"""
from __future__ import annotations

from benchmarks.common import quick, row, timer
from repro.core.nd import NDConfig, nested_dissection
from repro.graphs import generators as G
from repro.sparse.symbolic import nnz_opc


def main() -> None:
    g = G.grid3d(10, 10, 10) if quick() else G.grid3d(24, 24, 24)
    variants = {
        "band1": NDConfig(use_band=True, band_width=1),
        "band2": NDConfig(use_band=True, band_width=2),
        "band3": NDConfig(use_band=True, band_width=3),
        "band5": NDConfig(use_band=True, band_width=5),
        "unconstrained": NDConfig(use_band=False),
    }
    for name, cfg in variants.items():
        with timer() as t:
            perm = nested_dissection(g, seed=3, nproc=8, cfg=cfg)
        nnz, opc = nnz_opc(g, perm)
        row(f"band_ablation/{name}", t.us, OPC=f"{opc:.4e}", NNZ=nnz)


if __name__ == "__main__":
    main()
