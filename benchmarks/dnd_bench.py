"""Distributed nested dissection: OPC parity vs the host driver and
wall-clock across virtual device counts.

Needs multiple host devices; when the current process has fewer than 8 it
re-execs itself in a subprocess with ``XLA_FLAGS=
--xla_force_host_platform_device_count=8`` (the flag must be set before
jax initializes).  Emits ``BENCH_dnd.json``:

  * per-graph OPC of ``distributed_nested_dissection`` on 8 shards vs host
    ``nested_dissection`` at nproc=8 (same seed) — the mean ratio is
    asserted ≤ 1.03 (the tracked quality-parity bound, tightened from
    1.05 with the alternating-color band schedule);
  * wall-clock of the distributed driver on 1 / 2 / 4 / 8 virtual devices
    (CPU shard_map collectives: this tracks dispatch overhead trends, not
    real-accelerator speedup);
  * ``max_gather``: the largest centralizing gather (``to_host`` /
    ``unshard_vector`` element count) observed during the p=8 runs —
    the gather-free pipeline keeps it bounded by the configured
    thresholds, independent of graph size;
  * ``band``: a forced-sharded-band run of the first workload graph
    (``band_central_threshold`` lowered so the §3.3 sharded path really
    executes) reporting the band-path OPC ratio and the per-round
    conflict / repair-kick / ghost-pull counts of every sharded band
    refinement — the alternating-color schedule (the default) is
    asserted conflict-free.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

DEVICE_COUNTS = (1, 2, 4, 8)


def _reexec_with_devices() -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-m", "benchmarks.dnd_bench"],
                         env=env)
    if res.returncode:
        raise SystemExit(res.returncode)


def workload():
    from benchmarks.common import quick
    from repro.graphs import generators as G
    if quick():
        return {"grid2d-24": G.grid2d(24, 24),
                "grid3d-9": G.grid3d(9, 9, 9)}
    return {"grid2d-48": G.grid2d(48, 48),
            "grid3d-12": G.grid3d(12, 12, 12),
            "rgg2d-3000": G.rgg2d(3000, seed=2)}


def main() -> None:
    import jax
    if len(jax.devices()) < max(DEVICE_COUNTS):
        _reexec_with_devices()
        return
    import numpy as np
    from benchmarks.common import row
    from repro.core.dgraph import distribute, track_gathers
    from repro.core.dnd import (DNDConfig, distributed_nested_dissection,
                                track_band_stats)
    from repro.core.nd import nested_dissection
    from repro.sparse.symbolic import nnz_opc
    from repro.util import enable_compile_cache
    enable_compile_cache()

    graphs = workload()
    per_graph = {}
    wall = {p: 0.0 for p in DEVICE_COUNTS}
    ratios = []
    max_gather = 0
    for name, g in graphs.items():
        perm_h = nested_dissection(g, seed=0, nproc=8)
        opc_h = nnz_opc(g, perm_h)[1]
        entry = {"n": g.n, "opc_host": opc_h}
        for p in DEVICE_COUNTS:
            dg = distribute(g, p)
            t0 = time.perf_counter()
            with track_gathers() as gathers:
                perm_d = distributed_nested_dissection(dg, seed=0)
            dt = time.perf_counter() - t0
            wall[p] += dt
            entry[f"t_p{p}_s"] = round(dt, 3)
            if p == max(DEVICE_COUNTS):
                opc_d = nnz_opc(g, perm_d)[1]
                entry["opc_dnd"] = opc_d
                entry["opc_ratio"] = round(opc_d / opc_h, 4)
                ratios.append(opc_d / opc_h)
                entry["max_gather"] = max(s for _, s in gathers)
                max_gather = max(max_gather, entry["max_gather"])
        per_graph[name] = entry
        row(f"dnd/{name}", entry[f"t_p8_s"] * 1e6,
            n=g.n, opc_ratio=entry["opc_ratio"],
            max_gather=entry["max_gather"],
            **{f"t_p{p}": entry[f"t_p{p}_s"] for p in DEVICE_COUNTS})

    # forced-sharded-band run (§3.3 alternating-color schedule): lower
    # the centralization threshold so bands really refine sharded, and
    # report the schedule's per-round conflict accounting + band OPC
    band_name, band_g = next(iter(graphs.items()))
    band_cfg = DNDConfig(centralize_threshold=256,
                         band_central_threshold=128)
    dg = distribute(band_g, max(DEVICE_COUNTS))
    t0 = time.perf_counter()
    with track_band_stats() as bstats:
        perm_b = distributed_nested_dissection(dg, seed=0, cfg=band_cfg)
    band_dt = time.perf_counter() - t0
    opc_b = nnz_opc(band_g, perm_b)[1]
    conflicts_by_round = [s["conflicts"] for s in bstats]
    band = {
        "graph": band_name,
        "opc_ratio": round(opc_b / per_graph[band_name]["opc_host"], 4),
        "t_s": round(band_dt, 3),
        "band_refines": len(bstats),
        "conflicts_by_round": conflicts_by_round,
        "conflict_total": int(sum(sum(c) for c in conflicts_by_round)),
        "repair_kicks": int(sum(sum(s["repairs"]) for s in bstats)),
        "ghost_pulls": int(sum(sum(s["pulls"]) for s in bstats)),
    }
    row(f"dnd/band/{band_name}", band_dt * 1e6,
        opc_ratio=band["opc_ratio"], conflicts=band["conflict_total"],
        kicks=band["repair_kicks"], pulls=band["ghost_pulls"])

    ratio_mean = float(np.mean(ratios))
    out = {
        "graphs": per_graph,
        "wallclock_s": {str(p): round(wall[p], 3) for p in DEVICE_COUNTS},
        "opc_ratio_mean": round(ratio_mean, 4),
        "max_gather": max_gather,
        "band": band,
    }
    with open("BENCH_dnd.json", "w") as f:
        json.dump(out, f, indent=2)
    row("dnd/opc_ratio_mean", 0.0, ratio=round(ratio_mean, 4))
    # asserts run after the dump so a failing bound still leaves the
    # artifact around for debugging
    assert band["band_refines"] > 0, "no sharded band refinement ran"
    assert band["conflict_total"] == 0 and band["repair_kicks"] == 0, (
        "alternating-color schedule reported conflicts: "
        f"{band['conflicts_by_round']}")
    assert ratio_mean <= 1.03, (
        f"distributed ND mean OPC ratio {ratio_mean:.3f} > 1.03 vs host")


if __name__ == "__main__":
    main()
