"""Distributed nested dissection: OPC parity vs the host driver and
wall-clock across virtual device counts.

Needs multiple host devices; when the current process has fewer than 8 it
re-execs itself in a subprocess with ``XLA_FLAGS=
--xla_force_host_platform_device_count=8`` (the flag must be set before
jax initializes).  Emits ``BENCH_dnd.json``:

  * per-graph OPC of ``distributed_nested_dissection`` on 8 shards vs host
    ``nested_dissection`` at nproc=8 (same seed) — the mean ratio is
    asserted ≤ 1.03 (the tracked quality-parity bound, tightened from
    1.05 with the alternating-color band schedule);
  * wall-clock of the distributed driver on 1 / 2 / 4 / 8 virtual devices
    (CPU shard_map collectives: this tracks dispatch overhead trends, not
    real-accelerator speedup), plus ``p8_over_p1`` — the ratio the
    frontier driver is accountable for (launch latency used to grow with
    tree width; lane-stacking caps per-wave launches at the bucket
    count, asserted ≤ the bound the CI spmd job also re-checks).  The
    two ratio endpoints are min-of-3 timings with the first sample
    discarded as warmup — virtual devices oversubscribe small CPU
    runners and the cold sample carries compile/cache-load, so
    min-of-2 still swung ~1.7x; ``timing_jitter`` (and
    ``timing_jitter_fm`` for the gated FM stage) track the residual
    post-warmup swing;
  * ``launches_by_level`` (per graph): the frontier driver's per-wave
    outstanding works / shape buckets / collective launches by kind,
    with ``launch_budget_ok`` asserting launches == buckets on every
    wave — O(buckets × rounds) per level, not O(siblings × rounds);
  * ``stage_s``: per-stage wall-clock of the p=8 runs (match / bfs /
    halo / band-FM / rebuild / endgame) from ``dgraph.instrument()``;
  * ``match_gather_words``: total all_gather words of the matching
    launches — 3 buffers per round since the grant gather-back
    compaction (was 4), with the proposal buffers gathered at the
    lossless proposer cap when the compact path pays for itself;
    ``match_gather_words_dense`` books the counterfactual dense cost,
    so the compaction win is the gap between the two;
  * ``router``: the unified-router multi-request section — N=3
    concurrent distributed orderings drained through ONE shared
    ``WaveRouter`` vs 3 sequential single-request drains:
    ``router_launches_per_wave`` (mean launches per shared wave),
    ``cross_request_share_rate`` (launches that served lanes of ≥ 2
    requests), and the gated claims that the concurrent drain is
    bit-identical to the sequential drains while issuing strictly fewer
    collective launches;
  * ``max_gather``: the largest centralizing gather (``to_host`` /
    ``unshard_vector`` element count) observed during the p=8 runs —
    the gather-free pipeline keeps it bounded by the configured
    thresholds, independent of graph size;
  * ``band``: a forced-sharded-band run of the first workload graph
    (``band_central_threshold`` lowered so the §3.3 sharded path really
    executes) reporting the band-path OPC ratio and the per-round
    conflict / repair-kick / ghost-pull counts of every sharded band
    refinement — the alternating-color schedule (the default) is
    asserted conflict-free.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

DEVICE_COUNTS = (1, 2, 4, 8)


def _reexec_with_devices() -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-m", "benchmarks.dnd_bench"],
                         env=env)
    if res.returncode:
        raise SystemExit(res.returncode)


def workload():
    from benchmarks.common import quick
    from repro.graphs import generators as G
    if quick():
        return {"grid2d-24": G.grid2d(24, 24),
                "grid3d-9": G.grid3d(9, 9, 9)}
    return {"grid2d-48": G.grid2d(48, 48),
            "grid3d-12": G.grid3d(12, 12, 12),
            "rgg2d-3000": G.rgg2d(3000, seed=2)}


def main() -> None:
    import jax
    if len(jax.devices()) < max(DEVICE_COUNTS):
        _reexec_with_devices()
        return
    # REPRO_TRACE_OUT=path captures a span trace of the whole bench run
    # (the re-exec subprocess inherits the env, so the child writes the
    # file); the root ``bench`` span covers the full session, which is
    # what makes scripts/trace_summary.py report >= 95% coverage
    trace_out = os.environ.get("REPRO_TRACE_OUT")
    if not trace_out:
        _bench()
        return
    from repro import obs
    with obs.tracing() as tracer:
        with tracer.span("bench", bench="dnd"):
            _bench()
    tracer.export_chrome(trace_out)
    print(f"trace written to {trace_out} ({len(tracer.spans)} spans)")


def _bench() -> None:
    import numpy as np
    from benchmarks.common import row
    from repro.core.dgraph import distribute, instrument, jit_cache_size
    from repro.core.dnd import (DNDConfig, distributed_nested_dissection,
                                distributed_order_batch, track_band_stats)
    from repro.core.nd import nested_dissection
    from repro.sparse.symbolic import nnz_opc
    from repro.util import enable_compile_cache
    enable_compile_cache()

    graphs = workload()
    per_graph = {}
    wall = {p: 0.0 for p in DEVICE_COUNTS}
    ratios = []
    max_gather = 0
    stage_s = {}
    stage_detail = {}
    match_words = 0
    match_words_dense = 0
    budget_ok = True
    timing_jitter = 1.0
    timing_jitter_fm = 1.0
    for name, g in graphs.items():
        perm_h = nested_dissection(g, seed=0, nproc=8)
        opc_h = nnz_opc(g, perm_h)[1]
        entry = {"n": g.n, "opc_host": opc_h}
        for p in DEVICE_COUNTS:
            dg = distribute(g, p)
            # the endpoints of the gated p8/p1 ratio are timed as the
            # min of THREE runs with the first discarded as warmup:
            # virtual host devices oversubscribe small CPU runners, so
            # min-of-2 endpoint samples still swung ~1.7x run-to-run
            # (the first sample carries compile / cache-load, e.g.
            # grid2d-24 t_p8 10.8 vs 2.4).  The steady-state reps
            # measure the dispatch cost the frontier claim is about
            reps = 3 if p in (min(DEVICE_COUNTS), max(DEVICE_COUNTS)) \
                else 1
            samples = []
            fm_rep_s = []
            for rep in range(reps):
                t0 = time.perf_counter()
                with instrument() as ins_rep:
                    perm_d = distributed_nested_dissection(dg, seed=0)
                samples.append(time.perf_counter() - t0)
                fm_rep_s.append(ins_rep.stage_s.get("fm", 0.0))
                if rep == 0:
                    ins = ins_rep
            steady = samples[1:] if len(samples) > 2 else samples
            dt = min(steady)
            wall[p] += dt
            entry[f"t_p{p}_s"] = round(dt, 3)
            # raw samples stay in the artifact so the gated p8/p1 ratio
            # is debuggable when a CI runner swings; timing_jitter is
            # the worst max/min swing over the post-warmup endpoint
            # samples (the warmup sample is recorded but not gated on)
            entry[f"t_p{p}_samples"] = [round(s, 3) for s in samples]
            if len(steady) > 1:
                timing_jitter = max(timing_jitter,
                                    max(steady) / max(min(steady), 1e-9))
            # FM-section jitter, tracked separately: the fm stage gate
            # below compares against a wall-clock baseline, so its own
            # run-to-run swing must be visible in the artifact
            if p == max(DEVICE_COUNTS) and len(fm_rep_s) > 2:
                fm_steady = fm_rep_s[1:]
                timing_jitter_fm = max(
                    timing_jitter_fm,
                    max(fm_steady) / max(min(fm_steady), 1e-9))
            if p == max(DEVICE_COUNTS):
                opc_d = nnz_opc(g, perm_d)[1]
                entry["opc_dnd"] = opc_d
                entry["opc_ratio"] = round(opc_d / opc_h, 4)
                ratios.append(opc_d / opc_h)
                entry["max_gather"] = max(s for _, s in ins.gathers)
                max_gather = max(max_gather, entry["max_gather"])
                # frontier wave accounting: works vs buckets vs launches
                entry["launches_by_level"] = ins.waves
                entry["launch_budget_ok"] = all(
                    w["launches"][k] == w["buckets"][k] <= w["works"][k]
                    for w in ins.waves for k in w["launches"])
                budget_ok &= entry["launch_budget_ok"]
                for k, v in ins.stage_s.items():
                    stage_s[k] = stage_s.get(k, 0.0) + v
                for k, d in ins.stage_detail.items():
                    sd = stage_detail.setdefault(
                        k, {"compile_s": 0.0, "dispatch_s": 0.0})
                    sd["compile_s"] += d["compile_s"]
                    sd["dispatch_s"] += d["dispatch_s"]
                match_words += sum(l["words"] for l in ins.launches
                                   if l["kind"] == "dmatch")
                match_words_dense += sum(
                    l["words_dense"] for l in ins.launches
                    if l["kind"] == "dmatch")
        per_graph[name] = entry
        row(f"dnd/{name}", entry[f"t_p8_s"] * 1e6,
            n=g.n, opc_ratio=entry["opc_ratio"],
            max_gather=entry["max_gather"],
            budget_ok=entry["launch_budget_ok"],
            **{f"t_p{p}": entry[f"t_p{p}_s"] for p in DEVICE_COUNTS})

    # unified-router multi-request drain: N=3 concurrent distributed
    # orderings through ONE shared WaveRouter vs 3 sequential drains —
    # same permutations, strictly fewer collective launches (the wave
    # router's reason to exist)
    p_hi0 = max(DEVICE_COUNTS)
    r_items = (list(graphs.items()) * 3)[:3]
    r_seeds = [11, 23, 37]
    r_dgs = [distribute(g, p_hi0) for _, g in r_items]
    with instrument() as ins_rseq:
        seq_perms = [distributed_nested_dissection(d, seed=s)
                     for d, s in zip(r_dgs, r_seeds)]
    t0 = time.perf_counter()
    with instrument() as ins_rcon:
        con_perms = distributed_order_batch(r_dgs, r_seeds)
    router_dt = time.perf_counter() - t0

    def _dist_launches(ins):
        return sum(1 for l in ins.launches
                   if l["kind"] in ("dhalo", "dbfs", "dmatch"))

    r_waves = ins_rcon.waves
    r_total_launches = sum(sum(w["launches"].values()) for w in r_waves)
    r_shared = sum(w.get("shared_launches", 0) for w in r_waves)
    router = {
        "requests": len(r_dgs),
        "graphs": [name for name, _ in r_items],
        "bit_identical": bool(all(
            np.array_equal(a, b)
            for a, b in zip(seq_perms, con_perms))),
        "launches_concurrent": _dist_launches(ins_rcon),
        "launches_sequential": _dist_launches(ins_rseq),
        "waves": len(r_waves),
        "router_launches_per_wave": round(
            r_total_launches / max(len(r_waves), 1), 3),
        "cross_request_share_rate": round(
            r_shared / max(r_total_launches, 1), 4),
        "multi_request_waves": sum(
            1 for w in r_waves if w.get("requests", 1) >= 2),
        "t_s": round(router_dt, 3),
        "jit_cache_size": jit_cache_size(),
    }
    row("dnd/router", router_dt * 1e6,
        launches_concurrent=router["launches_concurrent"],
        launches_sequential=router["launches_sequential"],
        share_rate=router["cross_request_share_rate"],
        per_wave=router["router_launches_per_wave"])

    # forced-sharded-band run (§3.3 alternating-color schedule): lower
    # the centralization threshold so bands really refine sharded, and
    # report the schedule's per-round conflict accounting + band OPC
    band_name, band_g = next(iter(graphs.items()))
    band_cfg = DNDConfig(centralize_threshold=256,
                         band_central_threshold=128)
    dg = distribute(band_g, max(DEVICE_COUNTS))
    t0 = time.perf_counter()
    with track_band_stats() as bstats:
        perm_b = distributed_nested_dissection(dg, seed=0, cfg=band_cfg)
    band_dt = time.perf_counter() - t0
    opc_b = nnz_opc(band_g, perm_b)[1]
    conflicts_by_round = [s["conflicts"] for s in bstats]
    band = {
        "graph": band_name,
        "opc_ratio": round(opc_b / per_graph[band_name]["opc_host"], 4),
        "t_s": round(band_dt, 3),
        "band_refines": len(bstats),
        "conflicts_by_round": conflicts_by_round,
        "conflict_total": int(sum(sum(c) for c in conflicts_by_round)),
        "repair_kicks": int(sum(sum(s["repairs"]) for s in bstats)),
        "ghost_pulls": int(sum(sum(s["pulls"]) for s in bstats)),
    }
    row(f"dnd/band/{band_name}", band_dt * 1e6,
        opc_ratio=band["opc_ratio"], conflicts=band["conflict_total"],
        kicks=band["repair_kicks"], pulls=band["ghost_pulls"])

    ratio_mean = float(np.mean(ratios))
    p_lo, p_hi = min(DEVICE_COUNTS), max(DEVICE_COUNTS)
    p8_over_p1 = wall[p_hi] / wall[p_lo] if wall[p_lo] else 0.0
    out = {
        "graphs": per_graph,
        "wallclock_s": {str(p): round(wall[p], 3) for p in DEVICE_COUNTS},
        "p8_over_p1": round(p8_over_p1, 3),
        "timing_jitter": round(timing_jitter, 3),
        "timing_jitter_fm": round(timing_jitter_fm, 3),
        # every stage decomposed into first-call compile (trace + lower
        # + XLA compile or persistent-cache load) vs steady-state
        # dispatch, split by jit-cache-key first use (DESIGN.md §6);
        # per-wave rollups (t_s + stage_s per frontier wave) live in
        # graphs.*.launches_by_level
        "stage_s": {k: {"total_s": round(v, 3),
                        "compile_s": round(stage_detail.get(
                            k, {}).get("compile_s", 0.0), 3),
                        "dispatch_s": round(stage_detail.get(
                            k, {}).get("dispatch_s", 0.0), 3)}
                    for k, v in sorted(stage_s.items())},
        "launch_budget_ok": budget_ok,
        "match_gather_words": match_words,
        "match_gather_words_dense": match_words_dense,
        "opc_ratio_mean": round(ratio_mean, 4),
        "max_gather": max_gather,
        "router": router,
        "band": band,
    }
    with open("BENCH_dnd.json", "w") as f:
        json.dump(out, f, indent=2)
    row("dnd/opc_ratio_mean", 0.0, ratio=round(ratio_mean, 4))
    row("dnd/wallclock", wall[p_hi] * 1e6, p8_over_p1=round(p8_over_p1, 3),
        **{f"stage_{k}": round(v, 2) for k, v in sorted(stage_s.items())})
    # asserts run after the dump so a failing bound still leaves the
    # artifact around for debugging
    assert budget_ok, \
        "frontier wave launched more collectives than shape buckets"
    # lane-stacking caps per-wave launches at the bucket count, so the
    # wall-clock must stop growing with virtual device count the way the
    # depth-first driver's did (pre-frontier baseline: 3.03x).  The
    # fused FM pass loop re-based this ratio: it removed most of the
    # p=1 wall (18.8s -> 3.5s steady across the workload) while the
    # p=8 endpoint stays dominated by shard_map collective overhead on
    # oversubscribed virtual devices, so the same absolute overhead now
    # divides a much smaller denominator (measured 6.2x here vs 1.9x
    # pre-fusion — p=8 absolute wall IMPROVED 36.1s -> 21.9s).  The
    # structural per-sibling-launch regression is asserted directly by
    # the launch-budget checks above; this bound (measured 6.2x, jitter
    # <= 1.3x) only catches wholesale launch-growth blowups
    assert p8_over_p1 <= 7.5, (
        f"p=8 wall-clock is {p8_over_p1:.2f}x p=1 — frontier batching "
        "regressed toward per-sibling launch growth "
        "(post-fusion baseline 6.2x)")
    # the router acceptance gates: concurrent == sequential bit-for-bit,
    # with strictly fewer collective launches and real cross-request
    # sharing
    assert router["bit_identical"], \
        "shared-router drain differs from sequential single drains"
    assert (router["launches_concurrent"]
            < router["launches_sequential"]), (
        f"concurrent drain launched {router['launches_concurrent']}x, "
        f"sequential {router['launches_sequential']}x — no sharing")
    assert router["cross_request_share_rate"] > 0.0, \
        "no launch ever served lanes from >= 2 requests"
    assert band["band_refines"] > 0, "no sharded band refinement ran"
    assert band["conflict_total"] == 0 and band["repair_kicks"] == 0, (
        "alternating-color schedule reported conflicts: "
        f"{band['conflicts_by_round']}")
    assert ratio_mean <= 1.03, (
        f"distributed ND mean OPC ratio {ratio_mean:.3f} > 1.03 vs host")
    # the fused-FM acceptance gate: the on-device pass loop (plus the
    # bucket merge from dropping the max_moves sub-bucket) must at
    # least halve the p=8 FM stage versus the pre-fusion baseline.
    # 69.334 is the committed stage_s.fm.total_s of the PR 7 artifact
    # (cold rep: compile 31.571 + dispatch 37.763 on the same
    # 8-virtual-device CPU runner class this bench targets)
    fm_total = stage_s.get("fm", 0.0)
    assert fm_total <= 0.55 * 69.334, (
        f"stage_s.fm {fm_total:.1f}s > 0.55x the 69.334s pre-fusion "
        "baseline — the fused FM pass loop regressed")


if __name__ == "__main__":
    main()
