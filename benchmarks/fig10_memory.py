"""Paper Figures 10–11: memory per process vs process count.

Accounting model from the implementation's actual data structures (§2.1 +
§3.2): per-process bytes = local adjacency (ELL rows + weights) + ghost
values + one coarse level (~half) + fold-dup duplicates once n/p drops
below the fold threshold (logarithmic overhead — the paper's trade-off).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import quick, row
from repro.core.coarsen import coarsen_multilevel
from repro.core.dgraph import distribute
from repro.graphs import generators as G


def mem_per_process(g, p: int, fold_threshold: int = 100) -> float:
    # bucket=False: this models the mesh/ghost structure itself; pow2
    # jit-cache padding would turn the memory curve into a step function
    dg = distribute(g, p, bucket=False)
    # 4-byte ids + weights for local ELL, plus ghost value arrays
    base = dg.nbr_gst[0].size * 8 + dg.ghost_gid.shape[1] * 8
    # multilevel pyramid: geometric ~2x, fold-dup adds a copy per fold level
    n = g.n
    total = float(base) * 2.0
    p_cur, dup = p, 1.0
    while n > 120:
        n //= 2
        if p_cur > 1 and n / p_cur < fold_threshold:
            p_cur = (p_cur + 1) // 2
            dup += (n / max(g.n, 1)) * base * 8   # duplicated coarse copy
    return total + dup


def main() -> None:
    g = G.grid3d(12, 12, 12) if quick() else G.grid3d(30, 30, 30)
    base = None
    for p in (2, 4, 8, 16, 32, 64):
        m = mem_per_process(g, p)
        base = base or m * p
        row(f"fig10/audikw1-like/p{p}", 0.0,
            mb_per_proc=round(m / 1e6, 3),
            scaled_total=round(m * p / base, 2))


if __name__ == "__main__":
    main()
