"""Shared benchmark helpers.  CSV rows: name,us_per_call,derived."""
from __future__ import annotations

import os
import time


def row(name: str, us: float, **derived) -> str:
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    line = f"{name},{us:.1f},{d}"
    print(line, flush=True)
    return line


def quick() -> bool:
    """REPRO_BENCH_FULL=1 switches to paper-scale graphs."""
    return os.environ.get("REPRO_BENCH_FULL", "0") != "1"


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6
