"""Paper Table 1: graph inventory + sequential-ordering OPC (O_SS analog).

The UF matrices are not available offline; the suite regenerates the same
application families at benchmark scale (DESIGN.md §'graphs').
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import quick, row, timer
from repro.core.baselines import pt_scotch_like
from repro.graphs import generators as G
from repro.sparse.symbolic import nnz_opc


def suite():
    if quick():
        return {
            "altr4-like":      lambda: G.grid3d(14, 14, 14),
            "bmw32-like":      lambda: G.grid3d(18, 18, 18),
            "audikw1-like":    lambda: G.grid3d(12, 12, 12, stencil=27),
            "conesphere-like": lambda: G.rgg2d(10_000, seed=3),
            "qimonda-like":    lambda: G.circuit(10_000, seed=7),
            "thread-like":     lambda: G.knn3d(3_000, k=48, seed=1),
            "cage-like":       lambda: G.cage_like(5_000, seed=5),
        }
    return G.SUITE


def main() -> None:
    for name, ctor in suite().items():
        g = ctor()
        with timer() as t:
            perm = pt_scotch_like(g, seed=0, nproc=1)
        nnz, opc = nnz_opc(g, perm)
        row(f"table1/{name}", t.us, V=g.n, E=g.m,
            avg_degree=round(2 * g.m / g.n, 2), NNZ=nnz, O_SS=f"{opc:.3e}")


if __name__ == "__main__":
    main()
