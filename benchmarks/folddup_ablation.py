"""§3.2 claim: fold-dup (independent duplicated multilevel instances)
improves quality as p grows, for a logarithmic memory overhead."""
from __future__ import annotations

from benchmarks.common import quick, row, timer
from repro.core.nd import NDConfig, nested_dissection
from repro.graphs import generators as G
from repro.sparse.symbolic import nnz_opc


def main() -> None:
    g = G.grid3d(10, 10, 10) if quick() else G.grid3d(24, 24, 24)
    for p in (1, 8, 64):
        for fold in (True, False):
            cfg = NDConfig(fold_dup=fold)
            with timer() as t:
                perm = nested_dissection(g, seed=5, nproc=p, cfg=cfg)
            opc = nnz_opc(g, perm)[1]
            row(f"folddup/{'on' if fold else 'off'}/p{p}", t.us,
                OPC=f"{opc:.4e}")


if __name__ == "__main__":
    main()
