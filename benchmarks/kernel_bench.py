"""Pallas kernel microbenchmarks (interpret mode on CPU: correctness-scale
timing only; Mosaic numbers come from real TPUs).  Includes the jnp
reference for a like-for-like comparison and derived bytes/roofline."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import row, timer
from repro.kernels import ops
from repro.kernels.ref import diffusion_step_ref, ell_spmv_ref


def bench(fn, *args, iters=5):
    fn(*args)                                    # warmup/compile
    with timer() as t:
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
    return t.us / iters


def main() -> None:
    rng = np.random.default_rng(0)
    for n, d in ((4096, 8), (16384, 16)):
        nbr = rng.integers(0, n, (n, d)).astype(np.int32)
        nbr[rng.random((n, d)) < 0.2] = -1
        val = rng.standard_normal((n, d)).astype(np.float32)
        x = rng.standard_normal(n).astype(np.float32)
        nbr_j, val_j, x_j = map(jnp.asarray, (nbr, val, x))
        us_ref = bench(jax.jit(ell_spmv_ref), nbr_j, val_j, x_j)
        us_pal = bench(lambda a, b, c: ops.spmv(a, b, c, interpret=True),
                       nbr_j, val_j, x_j)
        bytes_moved = (nbr.size * 4 + val.size * 4 + x.size * 4 + n * 4)
        row(f"kernel/ell_spmv/n{n}d{d}", us_pal,
            jnp_ref_us=round(us_ref, 1),
            bytes=bytes_moved,
            note="interpret-mode; Mosaic timing requires TPU")
        inj = np.zeros(n, np.float32)
        us_dif = bench(lambda a, b, c, i: ops.diffuse(a, b, c, i, steps=1,
                                                      interpret=True),
                       nbr_j, jnp.abs(val_j), x_j, jnp.asarray(inj))
        row(f"kernel/diffusion/n{n}d{d}", us_dif,
            fused_passes=1, bytes=bytes_moved + n * 4)


if __name__ == "__main__":
    main()
