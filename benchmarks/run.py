"""Benchmark driver — one module per paper table/figure + TPU-adaptation
extras.  Prints ``name,us_per_call,derived`` CSV rows.

Default is the quick suite (CI-scale graphs); set REPRO_BENCH_FULL=1 for
paper-scale runs.  Select subsets: ``python -m benchmarks.run table2 fig10``.
"""
from __future__ import annotations

import sys

from repro.util import enable_compile_cache

MODULES = [
    ("table1", "benchmarks.table1_graphs"),
    ("table2", "benchmarks.table2_opc"),
    ("fig10", "benchmarks.fig10_memory"),
    ("band", "benchmarks.band_ablation"),
    ("folddup", "benchmarks.folddup_ablation"),
    ("kernel", "benchmarks.kernel_bench"),
    ("service", "benchmarks.service_bench"),
    ("dnd", "benchmarks.dnd_bench"),
]


def main() -> None:
    enable_compile_cache()
    want = set(sys.argv[1:])
    print("name,us_per_call,derived")
    for key, module in MODULES:
        if want and key not in want:
            continue
        print(f"# --- {module} ---", flush=True)
        __import__(module, fromlist=["main"]).main()


if __name__ == "__main__":
    main()
