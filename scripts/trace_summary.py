#!/usr/bin/env python
"""Render a top-down time tree from a captured Chrome trace.

Usage:
    python scripts/trace_summary.py TRACE_dnd.json [--depth N]
        [--min-coverage 0.95] [--top K]

Reads a trace written by ``obs.Tracer.export_chrome`` (the span tree
round-trips through the ``span_id`` / ``parent_id`` args), aggregates
sibling spans by name, and prints, per node: total seconds, share of the
trace, call count, and self time (total minus child total).  The
``coverage`` line is the union of root-span intervals over the trace
extent — ``--min-coverage`` turns it into an exit status for CI, which
asserts the trace accounts for >= 95% of the measured wall-clock.
"""
from __future__ import annotations

import argparse
import sys
from collections import defaultdict
from typing import Dict, List, Optional

sys.path.insert(0, "src")

from repro.obs import Span, load_chrome  # noqa: E402


def build_tree(spans: List[Span]) -> Dict[Optional[int], List[Span]]:
    """children[parent_id] -> spans, sorted by start time."""
    by_id = {s.span_id: s for s in spans}
    children: Dict[Optional[int], List[Span]] = defaultdict(list)
    for s in spans:
        pid = s.parent_id if s.parent_id in by_id else None
        children[pid].append(s)
    for v in children.values():
        v.sort(key=lambda s: s.t0)
    return children


def coverage(spans: List[Span]) -> float:
    """Union of root-span intervals over the whole trace extent."""
    if not spans:
        return 0.0
    t_lo = min(s.t0 for s in spans)
    t_hi = max(s.t1 for s in spans)
    if t_hi <= t_lo:
        return 1.0
    by_id = {s.span_id for s in spans}
    roots = sorted(((s.t0, s.t1) for s in spans
                    if s.parent_id not in by_id), key=lambda iv: iv[0])
    covered, cur_lo, cur_hi = 0.0, None, None
    for lo, hi in roots:
        if cur_hi is None or lo > cur_hi:
            if cur_hi is not None:
                covered += cur_hi - cur_lo
            cur_lo, cur_hi = lo, hi
        else:
            cur_hi = max(cur_hi, hi)
    if cur_hi is not None:
        covered += cur_hi - cur_lo
    return covered / (t_hi - t_lo)


def _dur(s: Span) -> float:
    return (s.t1 if s.t1 is not None else s.t0) - s.t0


def render(spans: List[Span], max_depth: int = 6, top: int = 12) -> str:
    """The top-down tree: siblings aggregated by name, heaviest first."""
    children = build_tree(spans)
    total = sum(_dur(s) for s in children.get(None, [])) or 1e-12
    lines = []

    def walk(parent_ids: List[int], depth: int, prefix: str) -> None:
        groups: Dict[str, List[Span]] = defaultdict(list)
        for pid in parent_ids:
            for c in children.get(pid, []):
                groups[c.name].append(c)
        rows = sorted(groups.items(),
                      key=lambda kv: -sum(_dur(s) for s in kv[1]))
        for name, group in rows[:top]:
            tot = sum(_dur(s) for s in group)
            kid_ids = [s.span_id for s in group]
            child_tot = sum(_dur(c) for sid in kid_ids
                            for c in children.get(sid, []))
            self_s = max(tot - child_tot, 0.0)
            lines.append(
                f"{prefix}{name:<28s} {tot:9.3f}s {100 * tot / total:5.1f}%"
                f"  x{len(group):<5d} self {self_s:8.3f}s")
            if depth + 1 < max_depth:
                walk(kid_ids, depth + 1, prefix + "  ")
        dropped = len(rows) - top
        if dropped > 0:
            rest = sum(_dur(s) for _, g in rows[top:] for s in g)
            lines.append(f"{prefix}... {dropped} more groups"
                         f" {rest:9.3f}s")

    root_groups: Dict[str, List[Span]] = defaultdict(list)
    by_id = {s.span_id for s in spans}
    for s in spans:
        if s.parent_id not in by_id:
            root_groups[s.name].append(s)
    lines.append(f"{'TOTAL (root spans)':<28s} {total:9.3f}s 100.0%"
                 f"  x{sum(len(g) for g in root_groups.values())}")
    walk([None], 0, "  ")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="chrome trace JSON from export_chrome")
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--top", type=int, default=12)
    ap.add_argument("--min-coverage", type=float, default=None,
                    help="exit 1 if root spans cover less of the trace "
                         "extent than this fraction")
    args = ap.parse_args(argv)
    spans = load_chrome(args.trace)
    print(render(spans, max_depth=args.depth, top=args.top))
    cov = coverage(spans)
    print(f"\ncoverage: {100 * cov:.2f}% of trace extent "
          f"({len(spans)} spans)")
    if args.min_coverage is not None and cov < args.min_coverage:
        print(f"FAIL: coverage {cov:.4f} < {args.min_coverage}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
